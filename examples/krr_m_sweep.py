"""Paper Fig. 2 in miniature: approximation error ‖f̂_S − f̂_n‖²_n versus the
accumulation count m, on the paper's bimodal high-incoherence distribution.

  PYTHONPATH=src python examples/krr_m_sweep.py

Expected output: error drops orders of magnitude from m=1 (Nyström) toward
the Gaussian-sketch (m=∞) floor by m≈8–32, while the sketch stays m·d-sparse.
"""
import sys
from pathlib import Path

import jax
import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
from benchmarks.common import bimodal_data  # the paper's appendix-D generator

from repro.core import (
    get_kernel, insample_error, krr_exact_fitted, krr_sketched_fit,
    krr_sketched_fit_dense, make_accum_sketch, make_gaussian_sketch,
)

n, gamma = 2000, 0.6
key = jax.random.PRNGKey(0)
X, y, f_star = bimodal_data(key, n, gamma=gamma)
lam = 0.5 * n ** (-4 / 7)
d = int(1.0 * n ** (3 / 7))
kern = get_kernel("gaussian", bandwidth=1.5 * n ** (-1 / 7))
K = kern(X, X)

fit_exact = krr_exact_fitted(K, y, lam)
reps = 5

print(f"n={n} d={d} λ={lam:.4f}   (‖f̂_S − f̂_n‖²_n, avg of {reps})")
for m in [1, 2, 4, 8, 16, 32]:
    errs = []
    for r in range(reps):
        sk = make_accum_sketch(jax.random.fold_in(key, 100 * m + r), n, d, m=m)
        errs.append(float(insample_error(
            krr_sketched_fit(K, y, lam, sk).fitted, fit_exact)))
    print(f"  m={m:3d}: {np.mean(errs):.3e}")

errs = []
for r in range(reps):
    S = make_gaussian_sketch(jax.random.fold_in(key, 999 + r), n, d)
    errs.append(float(insample_error(
        krr_sketched_fit_dense(K, y, lam, S).fitted, fit_exact)))
print(f"  m=∞ (gaussian): {np.mean(errs):.3e}")
