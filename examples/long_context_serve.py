"""Long-context serving with the AccumSketch-compressed KV cache.

  PYTHONPATH=src python examples/long_context_serve.py

Decodes the same prompts twice — once with the exact KV cache (memory grows
linearly with context) and once with the paper's sketched cache (fixed
d_slots landmark slots; memory independent of context length) — and reports
cache bytes + agreement of the generated continuations.

This is the mechanism that makes the long_500k production shape feasible for
full-attention architectures: a 500k-token exact cache for qwen1.5-110b would
be ~10 GB/layer-group per request, while the sketched cache is a few MB.

Prefill is ONE jitted dispatch (`Engine.prefill_tokens` → chunked forward +
bulk cache write) and decode is one `lax.scan` dispatch — the script also
times the batched prefill against the token-by-token loop it replaced
(`prefill_tokens_sequential`, kept as the equivalence oracle).
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import SketchAttnCfg
from repro.models.model import init_params
from repro.serve.engine import Engine, ServeConfig

ARCH = "stablelm-3b"
BATCH, PROMPT_LEN, NEW = 2, 48, 16


def cache_mb(cache) -> float:
    return sum(
        np.prod(x.shape) * x.dtype.itemsize
        for x in jax.tree_util.tree_leaves(cache)
    ) / 1e6


def main():
    cfg = reduced(get_config(ARCH))
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (BATCH, PROMPT_LEN), dtype=np.int32)

    # f32 caches so the d_slots ≥ max_len rows are EXACT (greedy agreement
    # 100%) — with bf16 caches the two paths round identical math differently
    sc = dict(max_len=PROMPT_LEN + NEW, cache_dtype=jnp.float32)
    eng = Engine(cfg, params, ServeConfig(**sc))
    cache_e = eng.new_cache(BATCH)
    cache_e, logits_exact = eng.prefill_tokens(cache_e, prompts)   # compile
    t0 = time.perf_counter()
    jax.block_until_ready(eng.prefill_tokens(eng.new_cache(BATCH), prompts)[1])
    t_batched = time.perf_counter() - t0
    t0 = time.perf_counter()
    jax.block_until_ready(
        eng.prefill_tokens_sequential(eng.new_cache(BATCH), prompts)[1]
    )
    t_seq = time.perf_counter() - t0
    print(f"[prefill     ] batched {t_batched * 1e3:7.1f} ms  "
          f"sequential {t_seq * 1e3:7.1f} ms  ({t_seq / t_batched:.0f}x)")
    exact, _ = eng.generate(prompts, NEW)
    print(f"[exact       ] cache={cache_mb(cache_e):8.3f} MB  "
          f"tokens[0,:8]={exact[0][:8].tolist()}")

    # projection dimension d_slots is the memory/accuracy knob: cache bytes are
    # O(d_slots) regardless of context length; logit error → 0 as d grows.
    sig = float(np.std(np.asarray(logits_exact)))
    for d_slots in [16, 64, 256]:
        c = dataclasses.replace(
            cfg, sketch_attn=SketchAttnCfg(d_slots=d_slots, m=2, m_r=2))
        eng = Engine(c, params, ServeConfig(use_sketch=True, **sc))
        cache_s = eng.new_cache(BATCH)
        cache_s, logits_s = eng.prefill_tokens(cache_s, prompts)
        out, _ = eng.generate(prompts, NEW)
        agree = float(np.mean(exact == out))
        rel = float(np.sqrt(np.mean(
            (np.asarray(logits_s) - np.asarray(logits_exact)) ** 2))) / sig
        print(f"[sketch d={d_slots:4d}] cache={cache_mb(cache_s):8.3f} MB  "
              f"rel-logit-RMSE={rel:6.3f}  greedy agreement={agree:.0%}")


if __name__ == "__main__":
    main()
