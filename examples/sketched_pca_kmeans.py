"""The paper's proposed §5 extension: accumulation-sketched AMM applied to
classical ML — PCA (sketched covariance) and k-means (sketched centroid sums).

  PYTHONPATH=src python examples/sketched_pca_kmeans.py

PCA:     Cov = XᵀX/n ≈ (SᵀX)ᵀ(SᵀX)/n — top eigenspace from an (m·d)-row sketch.
k-means: the centroid update C_j = Σ_{a_i=j} x_i / |{a_i=j}| is an AMM
         (onehotᵀ X) over the big n axis — sketched per Lloyd iteration.

Expected: on well-conditioned (low-incoherence) data even m=1 suffices — the
accumulation knob m pays off exactly where the paper's theory says: when a few
heavy rows dominate (high incoherence), m·d samples cut the AMM variance that
uniform sub-sampling (m=1) suffers. Part 1 shows that directly; parts 2–3 show
the downstream PCA/k-means quality at a fraction of the row reads.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import amm, make_accum_sketch

key = jax.random.PRNGKey(0)
n, p, rank = 4000, 32, 4

# ---- AMM error vs m under high incoherence (the paper's regime) ----------- #
Xh = jax.random.normal(key, (n, p)) * jnp.where(jnp.arange(n) < 20, 15.0, 1.0)[:, None]
exact = Xh.T @ Xh
print("AMM ‖ÂᵀB − AᵀB‖_F/‖AᵀB‖_F, 20 heavy rows (high incoherence), d=64:")
for m in [1, 4, 16]:
    errs = [
        float(jnp.linalg.norm(amm(Xh, Xh, make_accum_sketch(
            jax.random.fold_in(key, 100 * m + r), n, 64, m=m)) - exact)
            / jnp.linalg.norm(exact))
        for r in range(20)
    ]
    print(f"  m={m:3d}: rel err {np.mean(errs):.2f}")
print()

# data with a planted rank-4 signal subspace
U = jnp.linalg.qr(jax.random.normal(key, (p, rank)))[0]
Z = jax.random.normal(jax.random.fold_in(key, 1), (n, rank)) * jnp.asarray([6.0, 5.0, 4.0, 3.0])
X = Z @ U.T + 0.3 * jax.random.normal(jax.random.fold_in(key, 2), (n, p))
X = X - X.mean(0)

# ---- PCA ------------------------------------------------------------------ #
cov_exact = (X.T @ X) / n
_, V_exact = jnp.linalg.eigh(cov_exact)
top_exact = V_exact[:, -rank:]

print(f"sketched PCA   (n={n}, p={p}, top-{rank} subspace affinity vs exact):")
d = 64
for m in [1, 2, 8]:
    affs = []
    for r in range(5):
        sk = make_accum_sketch(jax.random.fold_in(key, 10 * m + r), n, d, m=m)
        cov_s = amm(X, X, sk) / n
        _, V_s = jnp.linalg.eigh(0.5 * (cov_s + cov_s.T))
        top_s = V_s[:, -rank:]
        # mean squared canonical correlation between the two subspaces
        s = jnp.linalg.svd(top_exact.T @ top_s, compute_uv=False)
        affs.append(float(jnp.mean(s**2)))
    print(f"  m={m}: affinity={np.mean(affs):.4f}   ({m * d} of {n} rows touched)")

# ---- k-means -------------------------------------------------------------- #
k, iters = 4, 10
Xc = jnp.concatenate(
    [jax.random.normal(jax.random.fold_in(key, 7 + j), (n // k, p)) * 0.5
     + 4.0 * jnp.eye(p)[j] for j in range(k)]
)


def assign(X, C):
    d2 = jnp.sum(X**2, 1)[:, None] - 2 * X @ C.T + jnp.sum(C**2, 1)[None]
    return jnp.argmin(d2, 1)


def inertia(X, C):
    return float(jnp.sum((X - C[assign(X, C)]) ** 2))


C0 = Xc[jax.random.choice(jax.random.fold_in(key, 99), n, (k,), replace=False)]

# exact Lloyd reference
C = C0
for _ in range(iters):
    a = assign(Xc, C)
    onehot = jax.nn.one_hot(a, k)
    C = (onehot.T @ Xc) / jnp.maximum(onehot.sum(0), 1.0)[:, None]
print(f"\nsketched k-means (k={k}; exact-Lloyd inertia={inertia(Xc, C):.0f}):")

for m in [1, 8]:
    C = C0
    for it in range(iters):
        sk = make_accum_sketch(jax.random.fold_in(key, 1000 * m + it), n, d, m=m)
        a = assign(Xc, C)
        onehot = jax.nn.one_hot(a, k)
        sums = amm(onehot, Xc, sk)                               # ≈ onehotᵀ X
        counts = jnp.maximum(amm(onehot, jnp.ones((n, 1)), sk)[:, 0], 1e-3)
        C = sums / counts[:, None]
    print(f"  m={m}: inertia={inertia(Xc, C):.0f} "
          f"(centroid updates from {m * d} sampled rows/iter)")
