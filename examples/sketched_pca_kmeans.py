"""The paper's §5 extensions on classical ML, ported to the core API:
accumulation-sketched AMM for PCA, k-means via the shared ``spectral.kmeans``
solver, and the new flagship — SKETCHED SPECTRAL CLUSTERING driven by the
progressive accumulation engine (``core.spectral``).

  PYTHONPATH=src python examples/sketched_pca_kmeans.py

PCA:      Cov = XᵀX/n ≈ (SᵀX)ᵀ(SᵀX)/n — top eigenspace from an (m·d)-row sketch.
k-means:  centroid updates are AMMs over the big n axis — sketched per Lloyd
          iteration, assignments by ``repro.core.spectral.kmeans`` machinery.
spectral: top-k eigenvectors of the sketched affinity K̂ = C W⁺ Cᵀ, where the
          engine grows m until a holdout error target is met, then k-means in
          the eigenspace — never an O(n³) eigendecomposition.

Expected: on well-conditioned (low-incoherence) data even m=1 suffices — the
accumulation knob m pays off exactly where the paper's theory says: when a few
heavy rows dominate (high incoherence), m·d samples cut the AMM variance that
uniform sub-sampling (m=1) suffers.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import amm, make_accum_sketch, spectral_cluster
from repro.core.kernels_math import gaussian_kernel
from repro.core.spectral import kmeans

key = jax.random.PRNGKey(0)
n, p, rank = 4000, 32, 4

# ---- AMM error vs m under high incoherence (the paper's regime) ----------- #
Xh = jax.random.normal(key, (n, p)) * jnp.where(jnp.arange(n) < 20, 15.0, 1.0)[:, None]
exact = Xh.T @ Xh
print("AMM ‖ÂᵀB − AᵀB‖_F/‖AᵀB‖_F, 20 heavy rows (high incoherence), d=64:")
for m in [1, 4, 16]:
    errs = [
        float(jnp.linalg.norm(amm(Xh, Xh, make_accum_sketch(
            jax.random.fold_in(key, 100 * m + r), n, 64, m=m)) - exact)
            / jnp.linalg.norm(exact))
        for r in range(20)
    ]
    print(f"  m={m:3d}: rel err {np.mean(errs):.2f}")
print()

# data with a planted rank-4 signal subspace
U = jnp.linalg.qr(jax.random.normal(key, (p, rank)))[0]
Z = jax.random.normal(jax.random.fold_in(key, 1), (n, rank)) * jnp.asarray([6.0, 5.0, 4.0, 3.0])
X = Z @ U.T + 0.3 * jax.random.normal(jax.random.fold_in(key, 2), (n, p))
X = X - X.mean(0)

# ---- PCA ------------------------------------------------------------------ #
cov_exact = (X.T @ X) / n
_, V_exact = jnp.linalg.eigh(cov_exact)
top_exact = V_exact[:, -rank:]

print(f"sketched PCA   (n={n}, p={p}, top-{rank} subspace affinity vs exact):")
d = 64
for m in [1, 2, 8]:
    affs = []
    for r in range(5):
        sk = make_accum_sketch(jax.random.fold_in(key, 10 * m + r), n, d, m=m)
        cov_s = amm(X, X, sk) / n
        _, V_s = jnp.linalg.eigh(0.5 * (cov_s + cov_s.T))
        top_s = V_s[:, -rank:]
        # mean squared canonical correlation between the two subspaces
        s = jnp.linalg.svd(top_exact.T @ top_s, compute_uv=False)
        affs.append(float(jnp.mean(s**2)))
    print(f"  m={m}: affinity={np.mean(affs):.4f}   ({m * d} of {n} rows touched)")

# ---- k-means (sketched-AMM Lloyd, assignments via the shared solver) ------ #
k, iters = 4, 10
Xc = jnp.concatenate(
    [jax.random.normal(jax.random.fold_in(key, 7 + j), (n // k, p)) * 0.5
     + 4.0 * jnp.eye(p)[j] for j in range(k)]
)


def assign(X, C):
    d2 = jnp.sum(X**2, 1)[:, None] - 2 * X @ C.T + jnp.sum(C**2, 1)[None]
    return jnp.argmin(d2, 1)


def inertia(X, C):
    return float(jnp.sum((X - C[assign(X, C)]) ** 2))


# Reference: the jit-compiled shared solver (k-means++ seeding + restarts).
# Note it is a BETTER-initialized baseline than the sketched runs below, which
# iterate exact Lloyd's update from random rows (C0) — the gap between m=1/m=8
# and this line mixes init quality with sketching error; compare m=1 vs m=8.
_, C_ref, inert_ref = kmeans(jax.random.fold_in(key, 99), Xc, k, iters=iters)
print(f"\nsketched k-means (k={k}; "
      f"best-of-restarts Lloyd inertia={float(inert_ref):.0f}):")

C0 = Xc[jax.random.choice(jax.random.fold_in(key, 99), n, (k,), replace=False)]
for m in [1, 8]:
    C = C0
    for it in range(iters):
        sk = make_accum_sketch(jax.random.fold_in(key, 1000 * m + it), n, d, m=m)
        a = assign(Xc, C)
        onehot = jax.nn.one_hot(a, k)
        sums = amm(onehot, Xc, sk)                               # ≈ onehotᵀ X
        counts = jnp.maximum(amm(onehot, jnp.ones((n, 1)), sk)[:, 0], 1e-3)
        C = sums / counts[:, None]
    print(f"  m={m}: inertia={inertia(Xc, C):.0f} "
          f"(centroid updates from {m * d} sampled rows/iter)")

# ---- sketched spectral clustering (progressive engine) -------------------- #
# Four planted clusters; the affinity is only ever touched through (C, W).
# Blob data is LOW-incoherence — uniform sampling is already near-optimal —
# so the engine's value here is the opposite direction: it stops at m=1
# instead of overspending, while matching a fixed m=8 sketch's clustering.
ns = 1200
Xs = Xc[jax.random.choice(jax.random.fold_in(key, 123), n, (ns,), replace=False)]
truth = np.asarray(jnp.argmax(Xs, axis=1))  # cluster j is centered at 4·e_j
K = gaussian_kernel(Xs, Xs, bandwidth=4.0)


def pairwise_agreement(lab):
    # label-permutation-free: co-clustering indicator accuracy
    same_t = truth[:, None] == truth[None, :]
    same_l = lab[:, None] == lab[None, :]
    return float((same_t == same_l).mean())


print(f"\nsketched spectral clustering (n={ns}, k={k}):")
res_fix = spectral_cluster(jax.random.fold_in(key, 321), K, k, d=32, m=8)
print(f"  fixed m=8   : pairwise agreement={pairwise_agreement(np.asarray(res_fix.labels)):.3f}"
      f"  ({8 * 32} rows touched)")
res_ad = spectral_cluster(jax.random.fold_in(key, 321), K, k, d=32,
                          tol=0.2, m_max=16)
print(f"  adaptive    : engine stopped at m={int(res_ad.info['m'])} "
      f"(est err {float(res_ad.info['err']):.3f}), pairwise agreement="
      f"{pairwise_agreement(np.asarray(res_ad.labels)):.3f}"
      f"  ({int(res_ad.info['m']) * 32} rows touched)")
