"""Quickstart: the paper's method in 30 lines.

Builds an accumulation sketch (Algorithm 1), solves sketched KRR without ever
forming the n×n kernel matrix, and compares against exact KRR and Nyström.
Then ADAPTIVE accumulation (an error target instead of m, the progressive
engine grows the sketch one O(n·d) slab at a time) and the MATRIX-FREE
operator: dataset in, predictions out, at an n where the dense kernel matrix
could not even be allocated.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (
    KernelOperator, get_kernel, insample_error, krr_exact_fitted,
    krr_sketched_fit, krr_sketched_fit_adaptive, krr_sketched_fit_matfree,
    make_accum_sketch, make_nystrom_sketch,
)

key = jax.random.PRNGKey(0)
n, d = 2000, 40

# synthetic regression data
X = jax.random.uniform(key, (n, 3))
f_true = jnp.sin(3 * X[:, 0]) + X[:, 1] ** 2 - X[:, 2]
y = f_true + 0.3 * jax.random.normal(jax.random.fold_in(key, 1), (n,))

kern = get_kernel("gaussian", bandwidth=0.5)
lam = 1e-3

# exact KRR (O(n³)) — the reference
fitted_exact = krr_exact_fitted(kern(X, X), y, lam)

for name, sk in {
    "nystrom (m=1)": make_nystrom_sketch(key, n, d),
    "accumulation m=8": make_accum_sketch(key, n, d, m=8),
}.items():
    model = krr_sketched_fit_matfree(X, y, lam, sk, kern)   # O(n·m·d), K-free
    err = insample_error(model.fitted, fitted_exact)
    print(f"{name:20s} ‖f̂_S − f̂_n‖²_n = {float(err):.3e}")

print("→ accumulation (medium m) ≈ Gaussian-sketch accuracy at Nyström cost.")

# ---- adaptive accumulation (batched, doubling schedule) -------------------- #
# The progressive engine rescues a cheap sampling scheme by GROWING m.  Since
# PR 5 it grows in BATCHES on a doubling schedule: draw B new sub-sampling
# matrices, fold all B into the running (C, W) with ONE pass over the data
# (the survivor rescales telescope into a single scalar), check the plug-in
# holdout estimate, B ← 2B — O(log m) data passes where the unit schedule
# paid one pass per slab (info["passes"] counts them; schedule="unit" brings
# the old loop back).  Callers still specify a tolerance, not m.
# (Sharper kernel + smaller d than above, so the error target actually bites.)
kern_hard = get_kernel("gaussian", bandwidth=0.4)
K = kern_hard(X, X)  # adaptive path works on a precomputed K (engine gathers cols)
fitted_hard = krr_exact_fitted(K, y, lam)
print("\nadaptive accumulation (error target instead of m, d=32):")
for tol in [0.2, 0.05, 0.02]:
    model = krr_sketched_fit_adaptive(K, y, lam, key, 32, tol=tol, m_max=32)
    err = insample_error(model.fitted, fitted_hard)
    # info's m/err are jax scalars (the driver stays jittable) — convert at
    # the printing edge only
    print(f"  tol={tol:5.2f} → engine chose m={int(model.info['m']):2d} "
          f"in {int(model.info['passes'])} data passes "
          f"(est err {float(model.info['err']):.3f}), ‖f̂_S − f̂_n‖²_n = {float(err):.3e}")
# Kernel block sizes come from a measured autotune cache: the first eager
# call at a new (shape, dtype, backend) key times candidate tilings and
# persists the winner to REPRO_AUTOTUNE_CACHE (default
# ~/.cache/repro/autotune.json); REPRO_AUTOTUNE=0/1 gates the measuring.

# ---- matrix-free: sketch the DATASET, not a matrix ------------------------- #
# KernelOperator = data + kernel name. C = K S and W = SᵀKS stream from X in
# row tiles (fused kernel-eval → GEMM on TPU, lax.scan on CPU); the n×n kernel
# matrix never exists, so n is bounded by O(n·d) — not O(n²) — memory.
# Here: n = 50_000, where dense K alone would be 10 GB (op.dense() refuses
# above n = 32768; see BENCH_matfree.json for the n = 131072 numbers).
n_big = 50_000
kb = jax.random.fold_in(key, 2)
X_big = jax.random.uniform(kb, (n_big, 3))
y_big = (jnp.sin(3 * X_big[:, 0]) + X_big[:, 1] ** 2 - X_big[:, 2]
         + 0.3 * jax.random.normal(jax.random.fold_in(kb, 1), (n_big,)))
op = KernelOperator(X_big, "gaussian", bandwidth=0.5)
sk_big = make_accum_sketch(kb, n_big, 64, m=4)
model = krr_sketched_fit(op, y_big, lam, sk_big)      # dataset in — no K
pred = model.predict(X_big[:5])                       # K(x, landmarks)·θ only
print(f"\nmatrix-free KRR at n={n_big:,}: dense K would be "
      f"{4 * n_big**2 / 1e9:.0f} GB; the operator held "
      f"{4 * n_big * (3 + 64) / 1e6:.0f} MB. predictions: {pred[:3]}")

# ---- distributed: row-shard X (and C) over a device mesh ------------------- #
# Pass mesh= to any operator-taking entry point and the fit runs data-parallel
# under shard_map: each device computes its (n/D, d) tile of C; W, CᵀC, Cᵀy
# reduce via psum; the sketch draw is bitwise identical to single-device.
# One CPU process shows D=1; force more with
#   XLA_FLAGS=--xla_force_host_platform_device_count=8  (before jax imports).
from repro.core import make_data_mesh

mesh = make_data_mesh()                               # 1-D ("data",) mesh
model_sh = krr_sketched_fit(op, y_big, lam, sk_big, mesh=mesh)
pred_sh = model_sh.predict(X_big[:5], mesh=mesh)
rel = float(jnp.linalg.norm(pred_sh - pred) / jnp.linalg.norm(pred))
print(f"sharded over {jax.device_count()} device(s): per-device C slab "
      f"{4 * (n_big // jax.device_count()) * 64 / 1e6:.1f} MB; "
      f"predictions agree to {rel:.1e} relative (psum reduction order)")
