"""Quickstart: the paper's method in 30 lines.

Builds an accumulation sketch (Algorithm 1), solves sketched KRR without ever
forming the n×n kernel matrix, and compares against exact KRR and Nyström.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (
    get_kernel, insample_error, krr_exact_fitted,
    krr_sketched_fit_matfree, make_accum_sketch, make_nystrom_sketch,
)

key = jax.random.PRNGKey(0)
n, d = 2000, 40

# synthetic regression data
X = jax.random.uniform(key, (n, 3))
f_true = jnp.sin(3 * X[:, 0]) + X[:, 1] ** 2 - X[:, 2]
y = f_true + 0.3 * jax.random.normal(jax.random.fold_in(key, 1), (n,))

kern = get_kernel("gaussian", bandwidth=0.5)
lam = 1e-3

# exact KRR (O(n³)) — the reference
fitted_exact = krr_exact_fitted(kern(X, X), y, lam)

for name, sk in {
    "nystrom (m=1)": make_nystrom_sketch(key, n, d),
    "accumulation m=8": make_accum_sketch(key, n, d, m=8),
}.items():
    model = krr_sketched_fit_matfree(X, y, lam, sk, kern)   # O(n·m·d), K-free
    err = insample_error(model.fitted, fitted_exact)
    print(f"{name:20s} ‖f̂_S − f̂_n‖²_n = {float(err):.3e}")

print("→ accumulation (medium m) ≈ Gaussian-sketch accuracy at Nyström cost.")
