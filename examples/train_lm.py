"""End-to-end driver: train a small LM for a few hundred steps, with a
mid-run preemption + restart to demonstrate the fault-tolerance contract.

  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--arch gemma3-12b]

The model is the same-family reduced config of the chosen architecture; the
data pipeline is the deterministic Markov synthetic stream (stateless in
`step`, so the post-restart token stream is bit-identical to an uninterrupted
run). The paper's technique appears twice: AccumAttention is available inside
the model for long contexts, and the sketched gradient compressor
(accumulation-of-sub-sampling over gradient coordinates) can be enabled with
--compress.
"""
import argparse
import shutil
import tempfile

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig
from repro.models.model import init_params
from repro.optim.adamw import AdamWConfig
from repro.optim.compress import CompressConfig
from repro.train.loop import LoopConfig, run
from repro.train.step import TrainConfig, init_train_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-12b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--compress", action="store_true")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    tc = TrainConfig(
        optimizer=AdamWConfig(lr_peak=3e-3, warmup_steps=20, total_steps=args.steps),
        compress=CompressConfig() if args.compress else None,
    )
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=args.batch)
    ckpt_dir = tempfile.mkdtemp(prefix="train_lm_ckpt_")
    mid = args.steps // 2

    def init():
        return init_train_state(init_params(jax.random.PRNGKey(0), cfg), tc)

    # --- phase 1: run to the midpoint, checkpointing ----------------------- #
    lc1 = LoopConfig(total_steps=mid, ckpt_dir=ckpt_dir, ckpt_every=25,
                     log_every=25)
    r1 = run(cfg, tc, dc, lc1, init_params_fn=init)
    print(f"[phase1] stopped at step {mid} (simulated preemption), "
          f"loss={r1.final_loss:.4f}")

    # --- phase 2: "restart" — fresh process state, resumes from checkpoint - #
    lc2 = LoopConfig(total_steps=args.steps, ckpt_dir=ckpt_dir, ckpt_every=50,
                     log_every=25)
    r2 = run(cfg, tc, dc, lc2, init_params_fn=init)
    assert r2.resumed_from == mid, (r2.resumed_from, mid)
    print(f"[phase2] resumed from {r2.resumed_from}, "
          f"final loss={r2.final_loss:.4f}")

    losses = r1.losses + r2.losses
    first = float(np.mean(losses[: len(losses) // 5]))
    last = float(np.mean(losses[-len(losses) // 5:]))
    print(f"[result] loss {first:.4f} → {last:.4f} "
          f"({'learning ✓' if last < first else 'NOT learning ✗'})")
    shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
