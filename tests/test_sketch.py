"""Unit + property tests for the core sketch construction (paper Algorithm 1)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core import (
    AccumSketch,
    gram_sketch,
    make_accum_sketch,
    make_gaussian_sketch,
    make_nystrom_sketch,
    make_sparse_rp,
    sketch_left,
    sketch_right,
    sketch_vec,
    unsketch_mat,
    unsketch_vec,
)

KEY = jax.random.PRNGKey(0)


def test_shapes_and_structure():
    sk = make_accum_sketch(KEY, n=100, d=10, m=3)
    assert sk.indices.shape == (3, 10) and sk.signs.shape == (3, 10)
    assert sk.m == 3 and sk.d == 10 and sk.n == 100
    S = sk.dense()
    assert S.shape == (100, 10)
    # each column has at most m non-zeros (fewer on index collisions)
    assert int(jnp.max(sk.nnz_per_column())) <= 3


def test_column_norm_scaling():
    """E[‖col‖²] = n/d for Algorithm-1 columns (uniform P): tr E[SSᵀ] = n and
    the d columns are exchangeable. (Collisions subtract a little: two draws
    hitting the same row with opposite signs cancel, hence the tolerance.)"""
    n, d = 200, 20
    norms = []
    for i in range(30):
        sk = make_accum_sketch(jax.random.fold_in(KEY, i), n=n, d=d, m=4)
        S = sk.dense()
        norms.append(np.asarray(jnp.sum(S**2, axis=0)))
    mean_sq = float(np.mean(np.concatenate(norms)))
    assert abs(mean_sq - n / d) < 0.15 * n / d


def test_unbiasedness_E_SST_is_identity():
    """E[S Sᵀ] = I_n — the identity making every sketch estimator unbiased."""
    n, d, m, reps = 64, 16, 4, 400
    acc = np.zeros((n, n))
    for i in range(reps):
        S = np.asarray(make_accum_sketch(jax.random.fold_in(KEY, i), n, d, m).dense())
        acc += S @ S.T
    acc /= reps
    off = acc - np.eye(n)
    assert np.abs(np.diag(off)).mean() < 0.15
    assert np.abs(off - np.diag(np.diag(off))).max() < 0.35   # MC noise bound


def test_nystrom_is_m1_special_case():
    """m=1 unsigned sketch selects/rescales single columns — Nyström."""
    sk = make_nystrom_sketch(KEY, n=50, d=5)
    S = np.asarray(sk.dense())
    assert ((S != 0).sum(axis=0) == 1).all()
    assert (S[S != 0] > 0).all()        # unsigned


def test_clt_limit_approaches_gaussian_moments():
    """m→∞: entries approach N(0, 1/d) for uniform P (CLT) — the same
    per-entry variance as make_gaussian_sketch. Check the variance and the
    empirical kurtosis trending to 3 (single-term excess kurtosis is n−3,
    divided by ~m by the CLT → ≈3.1 at m=256)."""
    n, d = 32, 8
    for m, kurt_tol in [(1, None), (256, 1.0)]:
        S = np.asarray(make_accum_sketch(KEY, n, d, m).dense()).ravel()
        var = S.var()
        assert abs(var - 1.0 / d) < 0.3 / d
        if kurt_tol is not None:
            kurt = ((S - S.mean()) ** 4).mean() / var**2
            assert abs(kurt - 3.0) < kurt_tol   # Gaussian kurtosis = 3


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(8, 80), d=st.integers(2, 16), m=st.integers(1, 6),
    r=st.integers(1, 20), seed=st.integers(0, 2**20),
)
def test_structural_apply_equals_dense(n, d, m, r, seed):
    """Property: the O(nmd) structural paths equal the dense matrix algebra."""
    key = jax.random.PRNGKey(seed)
    sk = make_accum_sketch(key, n, d, m)
    S = sk.dense()
    K = jax.random.normal(jax.random.fold_in(key, 1), (r, n))
    M = jax.random.normal(jax.random.fold_in(key, 2), (n, r))
    v = jax.random.normal(jax.random.fold_in(key, 3), (n,))
    w = jax.random.normal(jax.random.fold_in(key, 4), (d,))
    np.testing.assert_allclose(sketch_right(K, sk), K @ S, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(sketch_left(sk, M), S.T @ M, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(sketch_vec(sk, v), S.T @ v, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(unsketch_vec(sk, w), S @ w, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(
        unsketch_mat(sk, jnp.stack([w, w], 1)), S @ np.stack([w, w], 1),
        rtol=2e-4, atol=2e-4,
    )
    np.testing.assert_allclose(gram_sketch(sk), S.T @ S, rtol=2e-4, atol=2e-4)


def test_gram_sketch_scatter_add_matches_dense():
    """SᵀS via the segment-sum scatter-add (no (md)² coincidence matrix, no
    (n, d) dense form) equals the dense algebra — incl. index collisions."""
    for i, (n, d, m) in enumerate([(50, 5, 1), (100, 10, 3), (40, 8, 6)]):
        sk = make_accum_sketch(jax.random.fold_in(KEY, 300 + i), n, d, m)
        S = sk.dense()
        np.testing.assert_allclose(np.asarray(gram_sketch(sk)),
                                   np.asarray(S.T @ S), rtol=2e-5, atol=2e-5)
    # jit-compatibility (static-size unique under the hood)
    sk = make_accum_sketch(KEY, 64, 6, 2)
    np.testing.assert_allclose(np.asarray(jax.jit(gram_sketch)(sk)),
                               np.asarray(gram_sketch(sk)), rtol=1e-6, atol=1e-6)


def test_nnz_per_column_structural_matches_dense_count():
    """The O(m²·d) structural count from indices/coef pins the old dense
    count jnp.sum(S != 0, axis=0) — including index collisions and draws
    whose signs cancel exactly (a zero in S, not a non-zero)."""
    for i, (n, d, m) in enumerate([(50, 5, 1), (30, 8, 6), (10, 12, 8), (100, 10, 3)]):
        sk = make_accum_sketch(jax.random.fold_in(KEY, 500 + i), n, d, m)
        dense_count = jnp.sum(sk.dense() != 0, axis=0)      # the seed formula
        np.testing.assert_array_equal(np.asarray(sk.nnz_per_column()),
                                      np.asarray(dense_count))
    # forced exact cancellation: two draws on the same row, opposite signs
    sk = AccumSketch(indices=jnp.array([[0, 1], [0, 2]], jnp.int32),
                     signs=jnp.array([[1.0, 1.0], [-1.0, 1.0]]),
                     probs=jnp.full((5,), 0.2), n=5)
    np.testing.assert_array_equal(np.asarray(sk.nnz_per_column()), [0, 2])
    np.testing.assert_array_equal(np.asarray(jnp.sum(sk.dense() != 0, axis=0)),
                                  [0, 2])


def test_weighted_sampling_distribution_respected():
    probs = jnp.asarray([0.7] + [0.3 / 99] * 99)
    sk = make_accum_sketch(KEY, n=100, d=200, m=2, probs=probs)
    frac0 = float(jnp.mean((sk.indices == 0).astype(jnp.float32)))
    assert 0.6 < frac0 < 0.8


def test_baseline_sketches():
    Sg = make_gaussian_sketch(KEY, 100, 10)
    assert Sg.shape == (100, 10)
    assert abs(float(jnp.var(Sg)) - 0.1) < 0.02
    Sr = make_sparse_rp(KEY, 400, 10)
    density = float(jnp.mean((Sr != 0).astype(jnp.float32)))
    assert abs(density - 1 / np.sqrt(400)) < 0.03


def test_pytree_roundtrip():
    sk = make_accum_sketch(KEY, 30, 4, 2)
    leaves, treedef = jax.tree_util.tree_flatten(sk)
    sk2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(sk2, AccumSketch) and sk2.n == 30
    out = jax.jit(lambda s: s.dense())(sk)
    np.testing.assert_allclose(out, sk.dense())
