"""Serving-layer tests: batched prefill equivalence (bitwise at the scatter
level), the exact decode-step count, RNG stream independence, cache dtype and
memory-footprint invariants, and the identity-slot exactness degradation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.serve.engine as engine_mod
from repro.configs import ARCHS, reduced
from repro.configs.base import SketchAttnCfg
from repro.core.sketched_attention import (
    SketchCache,
    decode_slot_table,
    decode_slots,
    init_sketch_cache,
    prefill_sketch_cache,
    update_sketch_cache,
)
from repro.models.attention import KVCache
from repro.models.model import init_cache, init_params
from repro.serve.engine import Engine, ServeConfig

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def built():
    cfg = reduced(ARCHS["stablelm-3b"])
    return cfg, init_params(KEY, cfg)


def _prompts(B, L, vocab, seed=1):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(seed), (B, L), 0, vocab))


def _sketch_leaves(cache):
    flat = jax.tree_util.tree_flatten(
        cache.blocks, is_leaf=lambda n: isinstance(n, (SketchCache, KVCache))
    )[0]
    return [x for x in flat if isinstance(x, SketchCache)]


def _kv_leaves(cache):
    flat = jax.tree_util.tree_flatten(
        cache.blocks, is_leaf=lambda n: isinstance(n, (SketchCache, KVCache))
    )[0]
    return [x for x in flat if isinstance(x, KVCache)]


# --------------------------------------------------------------------------- #
# batched prefill ≡ sequential loop
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("scheme", ["uniform", "poisson"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_prefill_scatter_bitwise_matches_sequential_fold(scheme, dtype):
    """The one-dispatch vectorized scatter must produce a cache BIT-IDENTICAL
    to folding `update_sketch_cache` token by token (same contraction order:
    token-major, one rounding point per contribution)."""
    B, Hkv, d_slots, m_r, Dh, L = 2, 2, 16, 2, 8, 40
    table = decode_slot_table(KEY, L, d_slots, m_r, scheme=scheme, max_len=999)
    ks = jax.random.split(KEY, 2)
    k_seq = jax.random.normal(ks[0], (B, Hkv, L, Dh), dtype)
    v_seq = jax.random.normal(ks[1], (B, Hkv, L, Dh), dtype)

    seq = init_sketch_cache(B, Hkv, d_slots, Dh, dtype)
    for t in range(L):
        seq = update_sketch_cache(seq, k_seq[:, :, t], v_seq[:, :, t], table[t])
    bat = prefill_sketch_cache(
        init_sketch_cache(B, Hkv, d_slots, Dh, dtype), k_seq, v_seq, table
    )
    np.testing.assert_array_equal(np.asarray(bat.k_sum), np.asarray(seq.k_sum))
    np.testing.assert_array_equal(np.asarray(bat.v_sum), np.asarray(seq.v_sum))
    np.testing.assert_array_equal(np.asarray(bat.mass), np.asarray(seq.mass))


@pytest.mark.parametrize("use_sketch", [False, True])
def test_engine_batched_prefill_matches_sequential(built, use_sketch):
    """Engine-level: one-dispatch prefill ≈ the token-by-token oracle — same
    last-position logits and same cache, both cache flavors."""
    cfg, params = built
    sc = ServeConfig(max_len=48, use_sketch=use_sketch, cache_dtype=jnp.float32)
    eng = Engine(cfg, params, sc)
    prompts = _prompts(2, 33, cfg.vocab_size)
    cache_b, logits_b = eng.prefill_tokens(eng.new_cache(2), prompts)
    cache_s, logits_s = eng.prefill_tokens_sequential(eng.new_cache(2), prompts)
    np.testing.assert_allclose(
        np.asarray(logits_b), np.asarray(logits_s), rtol=1e-5, atol=1e-5
    )
    for b, s in zip(
        jax.tree_util.tree_leaves(cache_b), jax.tree_util.tree_leaves(cache_s)
    ):
        np.testing.assert_allclose(
            np.asarray(b, np.float32), np.asarray(s, np.float32),
            rtol=1e-5, atol=1e-5,
        )


@pytest.mark.parametrize("use_sketch", [False, True])
def test_generate_greedy_matches_stepwise_reference(built, use_sketch):
    """Greedy `generate` (batched prefill + scanned decode) emits the same
    token ids as the unbatched reference: sequential prefill + explicit
    decode_step/argmax loop."""
    cfg, params = built
    sc = ServeConfig(max_len=48, use_sketch=use_sketch, cache_dtype=jnp.float32)
    eng = Engine(cfg, params, sc)
    B, L, n_new = 2, 12, 6
    prompts = _prompts(B, L, cfg.vocab_size)
    out, _ = eng.generate(prompts, n_new)

    cache, logits = eng.prefill_tokens_sequential(eng.new_cache(B), prompts)
    ref = [np.asarray(jnp.argmax(logits, -1))]
    tok, pos = jnp.argmax(logits, -1).astype(jnp.int32), L
    for _ in range(n_new - 1):
        logits, cache = eng._step(
            params, cache, tok, jnp.int32(pos), eng._slots(pos)
        )
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        ref.append(np.asarray(tok))
        pos += 1
    np.testing.assert_array_equal(out, np.stack(ref, axis=1))


# --------------------------------------------------------------------------- #
# decode-step count (the seed ran one wasted forward per request)
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("n_new,expect", [(5, 4), (1, 0)])
def test_generate_runs_exactly_n_minus_1_decode_steps(built, monkeypatch, n_new, expect):
    """An n-token request runs exactly n−1 decode steps: token 0 comes from
    the prefill logits; no forward pass's outputs are discarded."""
    cfg, params = built
    counter = {"n": 0}
    real = engine_mod.decode_step

    def spy(*args, **kw):
        jax.debug.callback(lambda: counter.__setitem__("n", counter["n"] + 1))
        return real(*args, **kw)

    monkeypatch.setattr(engine_mod, "decode_step", spy)
    eng = Engine(cfg, params, ServeConfig(max_len=32, use_sketch=True))
    out, _ = eng.generate(_prompts(1, 8, cfg.vocab_size), n_new)
    jax.effects_barrier()
    assert out.shape == (1, n_new)
    assert counter["n"] == expect


# --------------------------------------------------------------------------- #
# RNG streams (regression: slot draws and sampling shared fold_in(key, pos))
# --------------------------------------------------------------------------- #

def test_rng_streams_independent(built):
    """Slot draws and temperature sampling must consume INDEPENDENT streams:
    fold_in(fold_in(key, tag), pos) with distinct tags — never the same
    fold_in(key, pos) key for both uses at a position."""
    cfg, params = built
    eng = Engine(cfg, params, ServeConfig(max_len=4096, use_sketch=True,
                                          temperature=1.0))
    base = np.asarray(jax.random.key_data(eng.key))
    slot = np.asarray(jax.random.key_data(eng._slot_key))
    samp = np.asarray(jax.random.key_data(eng._sample_key))
    assert not np.array_equal(slot, samp)
    assert not np.array_equal(slot, base) and not np.array_equal(samp, base)
    for pos in (0, 7, 1000):
        kd = lambda k: np.asarray(jax.random.key_data(jax.random.fold_in(k, pos)))
        assert not np.array_equal(kd(eng._slot_key), kd(eng._sample_key))
    # the draws stay deterministic per position (counter-based, resumable)
    np.testing.assert_array_equal(
        np.asarray(eng._slots(13)), np.asarray(eng._slots(13))
    )


# --------------------------------------------------------------------------- #
# slot schemes
# --------------------------------------------------------------------------- #

def test_decode_slots_poisson_properties():
    """Poisson draws: ≤ m_r real slots, no duplicates, padding marked with
    the out-of-bounds index d_slots (dropped by the scatter), deterministic."""
    d_slots, m_r = 16, 4
    saw_pad = saw_real = False
    for step in range(64):
        s = np.asarray(decode_slots(KEY, step, d_slots, m_r, scheme="poisson"))
        assert s.shape == (m_r,) and s.dtype == np.int32
        assert ((s >= 0) & (s <= d_slots)).all()
        real = s[s < d_slots]
        assert len(np.unique(real)) == len(real)    # coins → no replacement
        saw_pad |= bool((s == d_slots).any())
        saw_real |= len(real) > 0
        np.testing.assert_array_equal(
            s, np.asarray(decode_slots(KEY, step, d_slots, m_r, scheme="poisson"))
        )
    assert saw_pad and saw_real                     # mean m_r ⇒ both occur


def test_decode_slots_identity_and_bad_scheme():
    """max_len ≤ d_slots degrades every scheme to the identity draw (slot t
    for position t ⇒ singleton slots ⇒ exact attention); unknown schemes
    raise."""
    for scheme in ("uniform", "poisson"):
        s = decode_slots(KEY, 5, 16, 3, scheme=scheme, max_len=16)
        np.testing.assert_array_equal(np.asarray(s), np.full(3, 5, np.int32))
    with pytest.raises(ValueError, match="unknown decode slot scheme"):
        decode_slots(KEY, 0, 16, 3, scheme="bogus")


def test_sketched_decode_exact_when_slots_cover_context(built):
    """d_slots ≥ max_len ⇒ sketched generate == exact generate, token for
    token (the identity-slot degradation, end to end)."""
    cfg, params = built
    cfg = dataclasses.replace(
        cfg, sketch_attn=SketchAttnCfg(d_slots=64, m=cfg.sketch_attn.m, m_r=2)
    )
    params = init_params(KEY, cfg)
    outs = {}
    for use_sketch in (False, True):
        sc = ServeConfig(max_len=32, use_sketch=use_sketch, cache_dtype=jnp.float32)
        outs[use_sketch], _ = Engine(cfg, params, sc).generate(
            _prompts(2, 10, cfg.vocab_size), 6
        )
    np.testing.assert_array_equal(outs[False], outs[True])


# --------------------------------------------------------------------------- #
# cache dtype + memory footprint
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_cache_dtype_honored(built, dtype):
    """`ServeConfig.cache_dtype` reaches both cache flavors' k/v storage;
    sketched `mass` stays f32 regardless (count saturation in bf16)."""
    cfg, params = built
    for use_sketch in (False, True):
        eng = Engine(cfg, params, ServeConfig(
            max_len=32, use_sketch=use_sketch, cache_dtype=dtype
        ))
        cache = eng.new_cache(1)
        if use_sketch:
            leaves = _sketch_leaves(cache)
            assert leaves and not _kv_leaves(cache)
            for sc in leaves:
                assert sc.k_sum.dtype == dtype and sc.v_sum.dtype == dtype
                assert sc.mass.dtype == jnp.float32
        else:
            leaves = _kv_leaves(cache)
            assert leaves and not _sketch_leaves(cache)
            for kv in leaves:
                assert kv.k.dtype == dtype and kv.v.dtype == dtype


def test_cache_bytes_flat_vs_linear(built):
    """Sketched cache bytes are INDEPENDENT of max_len (the paper's fixed
    effective size); exact KV bytes grow linearly."""
    cfg, _ = built
    bytes_at = lambda ml, sk: sum(
        x.nbytes for x in jax.tree_util.tree_leaves(
            init_cache(cfg, 1, ml, jnp.bfloat16, use_sketch=sk)
        )
    )
    assert bytes_at(1024, True) == bytes_at(256, True)
    assert bytes_at(1024, False) == 4 * bytes_at(256, False)
