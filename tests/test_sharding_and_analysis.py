"""Sharding rules + HLO roofline analyzer unit tests (single device —
divisibility fallback must replicate everything gracefully)."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.launch.analysis import HloModule, _shape_bytes, model_flops
from repro.launch.mesh import make_debug_mesh
from repro.sharding import batch_spec, param_spec, params_shardings


def test_param_spec_rules():
    mesh = make_debug_mesh(1, 1)
    # embed: vocab→tp, d→fsdp; 1-device mesh → everything falls back to None
    assert param_spec(mesh, "embed", (512, 64)) == P(None, None)


def test_divisibility_fallback_never_errors():
    mesh = make_debug_mesh(1, 1)
    for shape in [(7, 13), (3, 5, 7), (1,), (127, 255, 3)]:
        spec = param_spec(mesh, "blocks/pos0/attn/wq", shape)
        assert len(spec) == len(shape)


def test_params_shardings_cover_tree():
    mesh = make_debug_mesh(1, 1)
    tree = {"embed": jnp.zeros((8, 4)), "blocks": {"pos0": {"attn": {"wq": jnp.zeros((4, 4))}}}}
    sh = params_shardings(mesh, tree)
    assert jax.tree_util.tree_structure(sh) == jax.tree_util.tree_structure(tree)


def test_batch_spec():
    mesh = make_debug_mesh(1, 1)
    assert batch_spec(mesh, 8) == P(None, None)


# --------------------------------------------------------------------------- #
# HLO analyzer
# --------------------------------------------------------------------------- #

_TOY_HLO = """
HloModule toy

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %w = f32[8,8]{1,0} constant({...})
  %d = f32[8,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups={}, to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ni, %ar)
}

%cond (p2: (s32[], f32[8,8])) -> pred[] {
  %p2 = (s32[], f32[8,8]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (arg: f32[8,8]) -> (s32[], f32[8,8]) {
  %arg = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%z, %arg)
  ROOT %w0 = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
}
"""


def test_hlo_while_trip_count_multiplies_flops_and_collectives():
    mod = HloModule(_TOY_HLO)
    assert mod.entry == "main"
    mult = mod.multipliers()
    assert mult["body"] == 10.0
    flops, hbm, coll, detail = mod.analyze()
    # dot: 2·8·8·8 = 1024 flops × 10 trips
    assert flops == 1024 * 10
    # all-reduce: 8·8·4B = 256B × factor 2 × 10
    assert coll == 256 * 2 * 10
    assert detail["count"]["all-reduce"] == 10


def test_shape_bytes_tuple():
    assert _shape_bytes("(s32[], f32[8,8])") == 4 + 256
    assert _shape_bytes("bf16[2,3]{1,0}") == 12


def test_model_flops():
    assert model_flops(1000, 10, "train") == 6e4
    assert model_flops(1000, 10, "prefill") == 2e4
