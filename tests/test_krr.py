"""Sketched KRR tests: the paper's estimator, error-vs-m monotonicity (Thm 8
empirics), leverage scores, incoherence, and K-satisfiability."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    get_kernel,
    incoherence,
    insample_error,
    krr_exact_fitted,
    krr_sketched_fit,
    krr_sketched_fit_dense,
    krr_sketched_fit_matfree,
    ksat_check,
    leverage_probs,
    leverage_scores,
    make_accum_sketch,
    make_gaussian_sketch,
    spectrum,
    statistical_dimension,
    d_delta,
    approx_leverage_probs,
)

KEY = jax.random.PRNGKey(7)


def _toy(n=400, noise=0.5):
    """The paper's bimodal distribution over R^3 (appendix D.2, scaled down)."""
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    gamma = 0.6
    n2 = max(int(n**gamma * n / (n + n**gamma)), 8)
    x1 = jax.random.uniform(k1, (n - n2, 3))
    x2 = 2.0 + 0.5 * jax.random.beta(k2, 1.0, 2.0, (n2, 3))
    X = jnp.concatenate([x1, x2])
    g = lambda x: 1.6 * jnp.abs((x - 0.4) * (x - 0.6)) - x * (x - 1) * (x - 2) - 0.5
    f = g(jnp.linalg.norm(X, axis=1) / 3.0)
    y = f + noise * jax.random.normal(k3, (n,))
    return X, y, f


def test_exact_krr_recovers_signal():
    X, y, f = _toy()
    kern = get_kernel("gaussian", bandwidth=0.75)
    K = kern(X, X)
    fitted = krr_exact_fitted(K, y, lam=1e-3)
    assert insample_error(fitted, f) < insample_error(y, f)


def test_error_decreases_with_m():
    """The paper's central empirical claim (Fig. 2): at fixed d, increasing m
    drives ‖f̂_S − f̂_n‖²_n down toward the Gaussian-sketch level."""
    n = 400
    X, y, f = _toy(n)
    # the paper's own hyper-parameters (appendix D.2): σ = 1.5 n^{-1/7},
    # λ = 0.5 n^{-4/7}, d = 1.5 n^{3/7} — the regime where uniform Nyström
    # fails on the bimodal data (high incoherence) and accumulation repairs it
    kern = get_kernel("gaussian", bandwidth=1.5 * n ** (-1 / 7))
    K = kern(X, X)
    lam = 0.5 * n ** (-4 / 7)
    fn = krr_exact_fitted(K, y, lam)
    d = int(1.5 * n ** (3 / 7))
    errs = {}
    for m in [1, 4, 16]:
        e = []
        for rep in range(5):
            sk = make_accum_sketch(jax.random.fold_in(KEY, 100 * m + rep), X.shape[0], d, m)
            mod = krr_sketched_fit(K, y, lam, sk)
            e.append(float(insample_error(mod.fitted, fn)))
        errs[m] = float(np.mean(e))
    assert errs[4] < errs[1] * 0.1, errs     # orders-of-magnitude repair
    assert errs[16] < errs[1] * 0.1, errs
    # Gaussian sketch benchmark: m=16 should be within ~4x of it
    eg = []
    for rep in range(5):
        S = make_gaussian_sketch(jax.random.fold_in(KEY, rep), X.shape[0], d)
        eg.append(float(insample_error(krr_sketched_fit_dense(K, y, lam, S).fitted, fn)))
    assert errs[16] < 4.0 * float(np.mean(eg)) + 1e-6


def test_matfree_equals_structural():
    X, y, _ = _toy(n=200)
    kern = get_kernel("matern", bandwidth=1.0, nu=1.5)
    K = kern(X, X)
    sk = make_accum_sketch(KEY, 200, 24, 4)
    a = krr_sketched_fit(K, y, 1e-3, sk, X, kern)
    b = krr_sketched_fit_matfree(X, y, 1e-3, sk, kern)
    np.testing.assert_allclose(a.fitted, b.fitted, rtol=2e-3, atol=2e-3)
    Xt = X[:16] + 0.01
    np.testing.assert_allclose(a.predict(Xt), b.predict(Xt), rtol=2e-3, atol=2e-3)


def test_matfree_chunked_equals_unchunked():
    X, y, _ = _toy(n=192)
    kern = get_kernel("gaussian", bandwidth=0.75)
    sk = make_accum_sketch(KEY, 192, 16, 2)
    a = krr_sketched_fit_matfree(X, y, 1e-3, sk, kern)
    b = krr_sketched_fit_matfree(X, y, 1e-3, sk, kern, chunk=64)
    # the chunked C itself is tight; the solve amplifies the f32 reorder noise
    # by cond(SᵀK²S + nλSᵀKS), so the fitted values get a looser bound
    from repro.core import sketch_kernel_cols
    np.testing.assert_allclose(
        sketch_kernel_cols(X, sk, kern),
        sketch_kernel_cols(X, sk, kern, chunk=64), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(a.fitted, b.fitted, rtol=2e-2, atol=2e-2)


def test_fused_kernel_routing_matches_seed_path():
    """The Pallas-routed fits (use_kernel=True: fused sketch_both / GEMM
    sketch_left) reproduce the XLA-gather path within 1e-4 on the paper's
    bimodal fixtures."""
    from repro.core import krr_sketched_fit_pcg

    X, y, _ = _toy(n=256)
    kern = get_kernel("gaussian", bandwidth=0.75)
    K = kern(X, X)
    sk = make_accum_sketch(KEY, 256, 16, 4)

    # structural fit: C and W both come out of the fused kernel with blocked
    # reduction order; the d×d solve amplifies the f32 noise by cond(M), so
    # this path gets a looser (still tight) bound than matfree/pcg below
    a = krr_sketched_fit(K, y, 1e-3, sk, use_kernel=False)
    b = krr_sketched_fit(K, y, 1e-3, sk, use_kernel=True)
    np.testing.assert_allclose(np.asarray(b.fitted), np.asarray(a.fitted),
                               rtol=1e-3, atol=1e-3)

    c = krr_sketched_fit_matfree(X, y, 1e-3, sk, kern, use_kernel=False)
    d = krr_sketched_fit_matfree(X, y, 1e-3, sk, kern, use_kernel=True)
    np.testing.assert_allclose(np.asarray(d.fitted), np.asarray(c.fitted),
                               rtol=1e-4, atol=1e-4)

    e = krr_sketched_fit_pcg(X, y, 1e-3, sk, kern, iters=40, use_kernel=False)
    f = krr_sketched_fit_pcg(X, y, 1e-3, sk, kern, iters=40, use_kernel=True)
    np.testing.assert_allclose(np.asarray(f.fitted), np.asarray(e.fitted),
                               rtol=1e-4, atol=1e-4)


def test_leverage_scores_sum_to_dstat():
    X, _, _ = _toy(n=150)
    K = get_kernel("gaussian", bandwidth=0.75)(X, X)
    lam = 1e-3
    spec = spectrum(K)
    l = leverage_scores(K, lam, spec)
    ds = statistical_dimension(K, lam, spec)
    np.testing.assert_allclose(float(jnp.sum(l)), float(ds), rtol=1e-4)
    assert (np.asarray(l) >= -1e-6).all() and (np.asarray(l) <= 1 + 1e-6).all()


def test_leverage_sampling_reduces_incoherence():
    """Thm 8 remark: leverage-proportional P gives M ≤ d_stat."""
    X, _, _ = _toy(n=200)
    K = get_kernel("gaussian", bandwidth=0.75)(X, X)
    lam = delta = 1e-3
    spec = spectrum(K)
    M_unif = float(incoherence(K, delta, None, spec))
    p_lev = leverage_probs(K, lam, spec)
    M_lev = float(incoherence(K, delta, p_lev, spec))
    ds = float(statistical_dimension(K, delta, spec))
    assert M_lev <= M_unif
    assert M_lev <= 1.5 * ds          # M ≤ d_stat (constant slack for fp)


def test_bimodal_data_has_high_incoherence():
    """The paper's hard case: unbalanced bimodal data → M = Ω(n) under uniform P."""
    X, _, _ = _toy(n=300)
    K = get_kernel("gaussian", bandwidth=0.3)(X, X)
    spec = spectrum(K)
    M = float(incoherence(K, 1e-4, None, spec))
    ds = float(statistical_dimension(K, 1e-4, spec))
    # M = Ω(n): the isolated mode forces near-maximal incoherence (M ≈ 0.84·n
    # here), far above the statistical dimension (M ≈ 2.9·ds on this fixture)
    assert M > 0.7 * K.shape[0]
    assert M > 2.5 * ds               # incoherence ≫ statistical dimension


def test_ksat_improves_with_m():
    """K-satisfiability (Def. 3): accumulation shrinks ‖U₁ᵀSSᵀU₁ − I‖."""
    X, _, _ = _toy(n=250)
    K = get_kernel("gaussian", bandwidth=0.75)(X, X)
    spec = spectrum(K)
    delta = 1e-3
    d = 4 * max(d_delta(spec, delta), 1)
    devs = {}
    for m in [1, 16]:
        vals = [
            float(ksat_check(K, make_accum_sketch(jax.random.fold_in(KEY, 31 * m + r),
                                                  250, d, m), delta, spec).top_deviation)
            for r in range(5)
        ]
        devs[m] = np.mean(vals)
    assert devs[16] < devs[1]


def test_approx_leverage_close_to_exact():
    X, _, _ = _toy(n=200)
    K = get_kernel("gaussian", bandwidth=0.75)(X, X)
    # λ large enough that ℓ_i(λ) varies across points (at λ→0 every score
    # saturates at 1 and rank correlation is undefined)
    lam = 0.05
    p_exact = np.asarray(leverage_probs(K, lam))
    p_hat = np.asarray(approx_leverage_probs(KEY, K, lam, sketch_dim=80))
    # rank correlation is what sampling quality needs
    from scipy.stats import spearmanr
    rho = spearmanr(p_exact, p_hat).statistic
    assert rho > 0.5, rho


def test_pcg_falkon_matches_direct_solve():
    """Falkon-flavoured PCG (paper §3.3) reaches the Woodbury solution up to
    f32 normal-equation conditioning (cond(CᵀC) squares cond(C), so fitted
    values agree to ~1e-2 absolute), and is statistically AS GOOD an
    estimator of the exact-KRR fit as the direct solve."""
    from repro.core import krr_sketched_fit_pcg

    X, y, _ = _toy(n=300)
    kern = get_kernel("gaussian", bandwidth=0.75)
    K = kern(X, X)
    fn = krr_exact_fitted(K, y, 1e-3)
    sk = make_accum_sketch(KEY, 300, 24, 4)
    direct = krr_sketched_fit_matfree(X, y, 1e-3, sk, kern)
    pcg = krr_sketched_fit_pcg(X, y, 1e-3, sk, kern, iters=60)
    np.testing.assert_allclose(np.asarray(pcg.fitted), np.asarray(direct.fitted),
                               rtol=3e-2, atol=3e-2)
    assert float(insample_error(pcg.fitted, fn)) < 2.0 * float(
        insample_error(direct.fitted, fn)) + 1e-6


def test_sketched_krr_is_a_pytree():
    """The fitted model must trace through jit/vmap boundaries: pass it AS AN
    ARGUMENT (the unregistered dataclass failed here), roundtrip its leaves,
    and pin jit(predict) ≡ eager on both the structural and operator paths."""
    from repro.core.kernel_op import KernelOperator
    from repro.core.krr import SketchedKRR

    X, y, _ = _toy(n=200)
    kern = get_kernel("gaussian", bandwidth=0.75)
    sk = make_accum_sketch(KEY, 200, 12, 3)
    Xt = X[:31] + 0.01

    for model in (
        krr_sketched_fit(kern(X, X), y, 1e-3, sk, X, kern),
        krr_sketched_fit(KernelOperator(X, "gaussian", bandwidth=0.75),
                         y, 1e-3, sk),
    ):
        leaves, treedef = jax.tree_util.tree_flatten(model)
        assert any(l.shape == model.theta.shape for l in leaves)
        model2 = jax.tree_util.tree_unflatten(treedef, leaves)
        np.testing.assert_array_equal(np.asarray(model2.theta),
                                      np.asarray(model.theta))
        # jit with the model as a traced argument, not a closure constant
        jitted = jax.jit(SketchedKRR.predict)(model, Xt)
        np.testing.assert_allclose(np.asarray(jitted),
                                   np.asarray(model.predict(Xt)),
                                   rtol=1e-6, atol=1e-6)


def test_sketched_krr_vmap_over_models():
    """vmap over a stacked batch of fitted models (shared treedef)."""
    X, y, _ = _toy(n=160)
    kern = get_kernel("gaussian", bandwidth=0.75)
    sk = make_accum_sketch(KEY, 160, 10, 2)
    m1 = krr_sketched_fit(kern(X, X), y, 1e-3, sk, X, kern)
    m2 = krr_sketched_fit(kern(X, X), 2.0 * y, 1e-3, sk, X, kern)
    stacked = jax.tree_util.tree_map(lambda a, b: jnp.stack([a, b]), m1, m2)
    Xt = X[:17] + 0.01
    out = jax.vmap(lambda m: m.predict(Xt))(stacked)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(m1.predict(Xt)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(m2.predict(Xt)),
                               rtol=1e-5, atol=1e-5)


def test_sketched_krr_operator_models_share_treedef():
    """Two models fitted through EQUAL (but distinct) operators must carry
    equal treedefs: ``kernel_fn`` rides in pytree aux and compares by
    identity, so ``get_kernel`` must hand back the cached callable — a fresh
    partial per fit made operator-path models un-stackable."""
    from repro.core.kernel_op import KernelOperator

    X, y, _ = _toy(n=160)
    sk = make_accum_sketch(KEY, 160, 10, 2)
    m1 = krr_sketched_fit(KernelOperator(X, "gaussian", bandwidth=0.75),
                          y, 1e-3, sk)
    m2 = krr_sketched_fit(KernelOperator(X, "gaussian", bandwidth=0.75),
                          2.0 * y, 1e-3, sk)
    assert jax.tree_util.tree_structure(m1) == jax.tree_util.tree_structure(m2)
    stacked = jax.tree_util.tree_map(lambda a, b: jnp.stack([a, b]), m1, m2)
    Xt = X[:17] + 0.01
    out = jax.vmap(lambda m: m.predict(Xt))(stacked)
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(m2.predict(Xt)),
                               rtol=1e-5, atol=1e-5)
