"""Tests for sketched eigendecomposition and spectral clustering
(``repro.core.spectral``) — the paper's second flagship application.
"""
from math import comb

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import apply as A
from repro.core.kernels_math import gaussian_kernel
from repro.core.sketch import make_accum_sketch
from repro.core.spectral import (
    kmeans,
    nystrom_eigh,
    sketched_degrees,
    sketched_spectral_embedding,
    spectral_cluster,
)

KEY = jax.random.PRNGKey(0)


def adjusted_rand_index(a: np.ndarray, b: np.ndarray) -> float:
    """Adjusted Rand index between two label vectors (exact, small n)."""
    a, b = np.asarray(a), np.asarray(b)
    n = a.shape[0]
    cats_a, cats_b = np.unique(a), np.unique(b)
    cont = np.array([[np.sum((a == ca) & (b == cb)) for cb in cats_b]
                     for ca in cats_a])
    sum_cells = sum(comb(int(x), 2) for x in cont.ravel())
    sum_rows = sum(comb(int(x), 2) for x in cont.sum(axis=1))
    sum_cols = sum(comb(int(x), 2) for x in cont.sum(axis=0))
    total = comb(n, 2)
    expected = sum_rows * sum_cols / total
    max_index = 0.5 * (sum_rows + sum_cols)
    if max_index == expected:
        return 1.0
    return float((sum_cells - expected) / (max_index - expected))


def _two_block_kernel(n_half: int = 120, sep: float = 2.5, scale: float = 0.4):
    """Planted 2-block affinity: two well-separated Gaussian clusters."""
    mu = jnp.array([sep, 0.0])
    X = jnp.concatenate([
        jax.random.normal(KEY, (n_half, 2)) * scale - mu,
        jax.random.normal(jax.random.fold_in(KEY, 1), (n_half, 2)) * scale + mu,
    ])
    truth = np.array([0] * n_half + [1] * n_half)
    return gaussian_kernel(X, X, bandwidth=1.0), truth


# --------------------------------------------------------------------------- #
# sketched eigendecomposition
# --------------------------------------------------------------------------- #

def test_nystrom_eigh_matches_exact_spectrum():
    """With a rich sketch the Nyström lift recovers the top eigenpairs."""
    K, _ = _two_block_kernel(150)
    n = K.shape[0]
    sk = make_accum_sketch(KEY, n, 128, m=8)
    C, W = A.sketch_both(K, sk, use_kernel=False)
    ev, U = nystrom_eigh(C.astype(jnp.float32), W, 4)
    ev_exact = jnp.linalg.eigvalsh(K)[::-1][:4]
    np.testing.assert_allclose(np.asarray(ev), np.asarray(ev_exact), rtol=0.02)
    # eigenvectors orthonormal and spanning the exact top subspace
    np.testing.assert_allclose(np.asarray(U.T @ U), np.eye(4), atol=1e-4)
    _, V = jnp.linalg.eigh(K)
    s = jnp.linalg.svd(V[:, -4:].T @ U, compute_uv=False)
    assert float(jnp.mean(s**2)) > 0.99


def test_nystrom_eigh_reconstructs_sketched_operator():
    """U diag(ev) Uᵀ (full k=d) equals the dense K̂ = C W⁺ Cᵀ — the lift
    algebra including the pseudo-inverse branch on tiny W eigenvalues."""
    n, d = 120, 16
    X = jax.random.uniform(jax.random.fold_in(KEY, 2), (n, 3))
    K = gaussian_kernel(X, X, bandwidth=0.6)
    sk = make_accum_sketch(KEY, n, d, m=3)
    C, W = A.sketch_both(K, sk, use_kernel=False)
    C, W = C.astype(jnp.float32), W.astype(jnp.float32)
    ev, U = nystrom_eigh(C, W, d)
    Khat_lift = (U * ev[None, :]) @ U.T
    Winv = np.linalg.pinv(np.asarray(W), rcond=1e-7)
    Khat_dense = np.asarray(C) @ Winv @ np.asarray(C).T
    np.testing.assert_allclose(np.asarray(Khat_lift), Khat_dense,
                               rtol=1e-3, atol=1e-3)


def test_sketched_degrees_match_dense():
    n, d = 100, 12
    X = jax.random.uniform(jax.random.fold_in(KEY, 3), (n, 2))
    K = gaussian_kernel(X, X, bandwidth=0.5)
    sk = make_accum_sketch(KEY, n, d, m=2)
    C, W = A.sketch_both(K, sk, use_kernel=False)
    C, W = C.astype(jnp.float32), W.astype(jnp.float32)
    deg = sketched_degrees(C, W)
    Winv = np.linalg.pinv(np.asarray(W), rcond=1e-7)
    deg_dense = np.asarray(C) @ (Winv @ (np.asarray(C).T @ np.ones(n)))
    np.testing.assert_allclose(np.asarray(deg), deg_dense,
                               rtol=1e-3, atol=1e-3)


def test_embedding_shapes_and_normalized_flag():
    K, _ = _two_block_kernel(60)
    sk = make_accum_sketch(KEY, K.shape[0], 16, m=2)
    C, W = A.sketch_both(K, sk, use_kernel=False)
    for normalized in (True, False):
        ev, U = sketched_spectral_embedding(
            C.astype(jnp.float32), W.astype(jnp.float32), 2,
            normalized=normalized)
        assert ev.shape == (2,) and U.shape == (K.shape[0], 2)
        assert bool(jnp.all(jnp.isfinite(U)))


# --------------------------------------------------------------------------- #
# k-means
# --------------------------------------------------------------------------- #

def test_kmeans_recovers_separated_blobs():
    k, per = 3, 60
    X = jnp.concatenate([
        jax.random.normal(jax.random.fold_in(KEY, j), (per, 2)) * 0.3
        + 5.0 * jnp.asarray([np.cos(2 * np.pi * j / k),
                             np.sin(2 * np.pi * j / k)])
        for j in range(k)
    ])
    truth = np.repeat(np.arange(k), per)
    labels, centers, inertia = kmeans(jax.random.fold_in(KEY, 99), X, k)
    assert adjusted_rand_index(np.asarray(labels), truth) == 1.0
    assert float(inertia) < per * k * 0.3**2 * 2 * 2.0


# --------------------------------------------------------------------------- #
# full pipeline — planted 2-block fixture (ISSUE 2 acceptance: ARI ≥ 0.95)
# --------------------------------------------------------------------------- #

def test_spectral_clustering_recovers_planted_blocks():
    K, truth = _two_block_kernel(120)
    res = spectral_cluster(jax.random.fold_in(KEY, 5), K, 2, d=16, m=4,
                           use_kernel=False)
    assert adjusted_rand_index(np.asarray(res.labels), truth) >= 0.95
    # the top-2 eigenvalues dominate (block structure)
    assert float(res.eigvals[1]) > 0.0


def test_spectral_clustering_adaptive_engine_path():
    """tol= routes through the progressive engine and still recovers labels."""
    K, truth = _two_block_kernel(100)
    res = spectral_cluster(jax.random.fold_in(KEY, 6), K, 2, d=16, tol=0.1,
                           m_max=16, use_kernel=False)
    assert adjusted_rand_index(np.asarray(res.labels), truth) >= 0.95
    assert 1 <= res.info["m"] <= 16
    assert res.sketch.m == res.info["m"]


def test_spectral_cluster_kernel_routing():
    """The fused Pallas sketch_both path (interpret on CPU) gives the same
    clustering as the XLA path."""
    K, truth = _two_block_kernel(80)
    r_xla = spectral_cluster(jax.random.fold_in(KEY, 7), K, 2, d=16, m=4,
                             use_kernel=False)
    r_krn = spectral_cluster(jax.random.fold_in(KEY, 7), K, 2, d=16, m=4,
                             use_kernel=True)
    assert adjusted_rand_index(np.asarray(r_xla.labels),
                               np.asarray(r_krn.labels)) == 1.0
