"""Matrix-free kernel-operator layer: dense ≡ matrix-free golden equivalence,
the fused kernel-eval→GEMM Pallas kernel vs its oracle, engine routing, and
the jaxpr regression proving the matrix-free path never allocates an n×n
intermediate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.trace import max_intermediate_elems
from repro.core import apply as A
from repro.core.kernel_op import DENSE_GUARD_N, KernelOperator
from repro.core.kernels_math import get_kernel
from repro.core.krr import (
    krr_sketched_fit,
    krr_sketched_fit_adaptive,
    krr_sketched_fit_matfree,
    krr_sketched_fit_pcg,
)
from repro.core.sketch import make_accum_sketch
from repro.core.spectral import sketched_spectral_embedding, spectral_cluster
from repro.kernels.accum_apply.ops import matfree_cols_kernel
from repro.kernels.accum_apply.ref import matfree_cols_ref

KEY = jax.random.PRNGKey(0)

KERNELS = [("gaussian", 0.6, 1.5), ("laplacian", 1.0, 1.5), ("matern", 0.8, 1.5)]


def _data(n=300, p=3, dtype=jnp.float32):
    X = jax.random.uniform(KEY, (n, p), dtype)
    y = (jnp.sin(3.0 * X[:, 0]) + X[:, 1] ** 2
         + 0.2 * jax.random.normal(jax.random.fold_in(KEY, 1), (n,), dtype))
    return X, y


# --------------------------------------------------------------------------- #
# fused Pallas kernel vs ref oracle (required sweep for every Pallas kernel)
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("kernel,bw,nu", KERNELS + [("matern", 0.8, 2.5)])
@pytest.mark.parametrize("n,p,d,m", [(200, 3, 10, 3), (256, 8, 16, 4), (100, 5, 7, 1)])
def test_matfree_kernel_sweep(n, p, d, m, kernel, bw, nu, dtype):
    X = jax.random.normal(jax.random.fold_in(KEY, n + d), (n, p), dtype)
    sk = make_accum_sketch(jax.random.fold_in(KEY, m), n, d, m)
    kf = get_kernel(kernel, bw, nu)
    ref = matfree_cols_ref(X.astype(jnp.float32), sk.indices, sk.coef, kf)
    L = jnp.take(X, sk.indices.reshape(-1), axis=0)
    out = matfree_cols_kernel(X, L, sk.coef, kernel=kernel, bandwidth=bw, nu=nu)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=tol, atol=tol)


def test_matfree_kernel_odd_shapes_and_blocks():
    """Row counts that do not tile by bm: the ops wrapper pads and slices."""
    X = jax.random.normal(KEY, (173, 4))
    sk = make_accum_sketch(jax.random.fold_in(KEY, 3), 173, 9, 3)
    kf = get_kernel("gaussian", 0.7)
    ref = matfree_cols_ref(X, sk.indices, sk.coef, kf)
    L = jnp.take(X, sk.indices.reshape(-1), axis=0)
    out = matfree_cols_kernel(X, L, sk.coef, kernel="gaussian", bandwidth=0.7, bm=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------- #
# golden dense ≡ matrix-free equivalence
# --------------------------------------------------------------------------- #

def _golden_case(kernel, bw, nu, dtype):
    """(C, W), KRR predictions, spectral embeddings: operator vs dense ≤ 1e-5."""
    n, p, d, m, lam = 300, 3, 16, 4, 1e-2
    X, y = _data(n, p, dtype)
    op = KernelOperator(X, kernel, bandwidth=bw, nu=nu)
    K = op.dense()
    assert K.dtype == dtype
    sk = make_accum_sketch(KEY, n, d, m, dtype=dtype)

    # (C, W)
    C_d, W_d = A.sketch_both(K, sk, use_kernel=False)
    C_o, W_o = A.sketch_both(op, sk, use_kernel=False)
    np.testing.assert_allclose(np.asarray(C_o), np.asarray(C_d), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(W_o), np.asarray(W_d), rtol=1e-5, atol=1e-6)

    # KRR: in-sample fit and out-of-sample predictions
    fit_d = krr_sketched_fit(K, y, lam, sk, X, op.kernel_fn, use_kernel=False)
    fit_o = krr_sketched_fit(op, y, lam, sk, use_kernel=False)
    np.testing.assert_allclose(np.asarray(fit_o.fitted), np.asarray(fit_d.fitted),
                               rtol=1e-5, atol=1e-5)
    Xt = X[:32] + jnp.asarray(0.01, dtype)
    np.testing.assert_allclose(np.asarray(fit_o.predict(Xt)),
                               np.asarray(fit_d.predict(Xt)), rtol=1e-5, atol=1e-5)

    # spectral embedding (sign-aligned: eigenvectors are sign-ambiguous)
    k = 3
    ev_d, U_d = sketched_spectral_embedding(C_d.astype(jnp.float32),
                                            W_d.astype(jnp.float32), k)
    ev_o, U_o = sketched_spectral_embedding(C_o.astype(jnp.float32),
                                            W_o.astype(jnp.float32), k)
    np.testing.assert_allclose(np.asarray(ev_o), np.asarray(ev_d), rtol=1e-5, atol=1e-6)
    sign = np.sign(np.sum(np.asarray(U_d) * np.asarray(U_o), axis=0))
    np.testing.assert_allclose(np.asarray(U_o) * sign, np.asarray(U_d),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kernel,bw,nu", KERNELS)
def test_golden_dense_equals_matfree_f32(kernel, bw, nu):
    _golden_case(kernel, bw, nu, jnp.float32)


@pytest.mark.parametrize("kernel,bw,nu", KERNELS)
def test_golden_dense_equals_matfree_f64_cpu(kernel, bw, nu):
    with jax.experimental.enable_x64():
        _golden_case(kernel, bw, nu, jnp.float64)


# --------------------------------------------------------------------------- #
# jaxpr regression: no n×n intermediate on the matrix-free path
# --------------------------------------------------------------------------- #

# the hand-rolled walker this file used to carry now lives in
# repro.analysis.trace — the dense-path n² assertion below stays as the
# positive control proving the shared detector still sees the big buffer
_max_intermediate_elems = max_intermediate_elems


def test_matfree_path_has_no_nxn_intermediate():
    """The acceptance claim: tracing the matrix-free KRR fit (chunked scan
    streaming path) binds NO buffer within an order of magnitude of n² —
    while the dense path provably does (positive control)."""
    n, p, d, m, chunk = 4096, 4, 16, 4, 512
    X = jax.random.uniform(KEY, (n, p))
    y = jnp.zeros((n,))
    sk = make_accum_sketch(KEY, n, d, m)

    def matfree_fit(X, y):
        op = KernelOperator(X, "gaussian", bandwidth=0.6)
        C = op.sketch_cols(sk, chunk=chunk, use_kernel=False)
        W = A.sketch_left(sk, C)
        mdl = krr_sketched_fit_matfree(
            KernelOperator(X, "gaussian", bandwidth=0.6), y, 1e-2, sk, chunk=chunk)
        return C, W, mdl.fitted

    mf = _max_intermediate_elems(jax.make_jaxpr(matfree_fit)(X, y).jaxpr)
    assert mf < n * n // 8, f"matrix-free path binds a {mf}-element buffer"
    # every buffer is O(n·(m·d + p)): C/X rows and the chunked kernel slab
    assert mf <= n * (m * d + p), mf

    def dense_fit(X, y):
        K = get_kernel("gaussian", 0.6)(X, X)
        return krr_sketched_fit(K, y, 1e-2, sk, use_kernel=False).fitted

    dn = _max_intermediate_elems(jax.make_jaxpr(dense_fit)(X, y).jaxpr)
    assert dn >= n * n       # positive control: the detector sees the n² buffer


def test_auto_chunk_respects_slab_budget_at_large_md():
    """Regression for the ``max(256, …)`` floor in ``_auto_chunk``: at large
    m·d a 256-row floor made the (chunk, m·d) streaming slab 64 MiB (the exact
    failure ``matvec``'s chunk comment warns about).  The budget is ~16 MiB =
    4M f32 elements; the traced program must never bind a bigger buffer."""
    n, p, d, m = 8192, 4, 128, 512                 # m·d = 65536
    budget_elems = 4 * 1024 * 1024
    X = jax.random.uniform(KEY, (n, p))
    sk = make_accum_sketch(KEY, n, d, m)
    op = KernelOperator(X, "gaussian", bandwidth=0.6)
    assert op._auto_chunk(m * d) * m * d <= budget_elems

    jaxpr = jax.make_jaxpr(
        lambda X: KernelOperator(X, "gaussian", bandwidth=0.6).sketch_cols(
            sk, use_kernel=False))(X)
    peak = _max_intermediate_elems(jaxpr.jaxpr)
    # the old floor binds a 256·65536 ≈ 16.8M-element slab here
    assert peak <= budget_elems + n * p, peak

    # and the gate must key on SLAB size, not row count: at n = 4096 the old
    # `rows > 4096` gate skipped chunking entirely and bound the full
    # (4096, 65536) ≈ 1 GiB slab in one block
    n_small = 4096
    Xs = jax.random.uniform(KEY, (n_small, p))
    sks = make_accum_sketch(KEY, n_small, d, m)
    jaxpr_s = jax.make_jaxpr(
        lambda X: KernelOperator(X, "gaussian", bandwidth=0.6).sketch_cols(
            sks, use_kernel=False))(Xs)
    peak_s = _max_intermediate_elems(jaxpr_s.jaxpr)
    assert peak_s <= budget_elems + n_small * p, peak_s


def test_engine_step_matfree_no_nxn_intermediate():
    """The progressive engine's slab increment on an operator is O(n·d) too."""
    n, d = 2048, 16
    X = jax.random.uniform(KEY, (n, 4))
    state = A.accum_init(KEY, n, d, 4)

    jaxpr = jax.make_jaxpr(
        lambda X, s: A.accum_step(KernelOperator(X, "gaussian", bandwidth=0.6),
                                  s, use_kernel=False))(X, state)
    mf = _max_intermediate_elems(jaxpr.jaxpr)
    assert mf < n * n // 8, mf


# --------------------------------------------------------------------------- #
# engine + pipelines routed through the operator
# --------------------------------------------------------------------------- #

def test_engine_grow_operator_equals_dense():
    n, p, d, m_max = 300, 3, 16, 6
    X, _ = _data(n, p)
    op = KernelOperator(X, "gaussian", bandwidth=0.6)
    K = op.dense()
    st_o = A.accum_grow(op, A.accum_init(KEY, n, d, m_max), m_max, use_kernel=False)
    st_d = A.accum_grow(K, A.accum_init(KEY, n, d, m_max), m_max, use_kernel=False)
    np.testing.assert_allclose(np.asarray(st_o.C), np.asarray(st_d.C),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(st_o.W), np.asarray(st_d.W),
                               rtol=1e-5, atol=1e-6)


def test_engine_grow_operator_f64_mode():
    """x64 regression: an f64 operator must not promote the engine's f32 loop
    carry (the fori/while carry dtype check rejects the step otherwise)."""
    with jax.experimental.enable_x64():
        n, d = 96, 8
        X = jax.random.uniform(KEY, (n, 3), jnp.float64)
        op = KernelOperator(X, "gaussian", bandwidth=0.6)
        st_o = A.accum_grow(op, A.accum_init(KEY, n, d, 3), 3, use_kernel=False)
        assert st_o.C.dtype == jnp.float32
        st_d = A.accum_grow(op.dense(), A.accum_init(KEY, n, d, 3), 3,
                            use_kernel=False)
        np.testing.assert_allclose(np.asarray(st_o.C), np.asarray(st_d.C),
                                   rtol=1e-5, atol=1e-6)


def test_adaptive_krr_operator_equals_dense():
    n, d = 300, 16
    X, y = _data(n)
    op = KernelOperator(X, "gaussian", bandwidth=0.5)
    K = op.dense()
    a = krr_sketched_fit_adaptive(op, y, 1e-2, KEY, d, tol=0.05, m_max=8,
                                  use_kernel=False)
    b = krr_sketched_fit_adaptive(K, y, 1e-2, KEY, d, tol=0.05, m_max=8,
                                  use_kernel=False)
    assert a.info["m"] == b.info["m"]
    np.testing.assert_allclose(np.asarray(a.fitted), np.asarray(b.fitted),
                               rtol=1e-4, atol=1e-4)
    # operator predict is wired automatically
    Xt = X[:16] + 0.01
    assert a.predict(Xt).shape == (16,)


def test_hutchinson_estimator_operator_matches_dense():
    n, d = 256, 12
    X, _ = _data(n)
    op = KernelOperator(X, "gaussian", bandwidth=0.6)
    K = op.dense()
    st = A.accum_grow(K, A.accum_init(KEY, n, d, 4), 4, use_kernel=False)
    e_d = A.make_hutchinson_estimator(KEY, K, 4)(st)
    e_o = A.make_hutchinson_estimator(KEY, op, 4)(st)
    np.testing.assert_allclose(float(e_o), float(e_d), rtol=1e-4, atol=1e-5)


def test_operator_matvec_streams_and_matches_dense():
    n = 300
    X, _ = _data(n)
    op = KernelOperator(X, "laplacian", bandwidth=0.9)
    K = op.dense()
    Z = jax.random.normal(jax.random.fold_in(KEY, 2), (n, 5))
    np.testing.assert_allclose(np.asarray(op.matvec(Z, chunk=64)),
                               np.asarray(K.astype(jnp.float32) @ Z),
                               rtol=1e-4, atol=1e-4)
    v = Z[:, 0]
    assert op.matvec(v, chunk=64).shape == (n,)


def test_spectral_cluster_operator_matches_dense_labels():
    """Planted two-cluster mixture: operator pipeline ≡ dense pipeline."""
    k1, k2 = jax.random.split(KEY)
    Xa = 0.25 * jax.random.normal(k1, (80, 2))
    Xb = 0.25 * jax.random.normal(k2, (80, 2)) + jnp.asarray([3.0, 0.0])
    X = jnp.concatenate([Xa, Xb])
    op = KernelOperator(X, "gaussian", bandwidth=0.8)
    res_o = spectral_cluster(KEY, op, 2, d=24, m=4, use_kernel=False)
    res_d = spectral_cluster(KEY, op.dense(), 2, d=24, m=4, use_kernel=False)
    lo, ld = np.asarray(res_o.labels), np.asarray(res_d.labels)
    agree = max(np.mean(lo == ld), np.mean(lo == 1 - ld))   # label-swap invariant
    assert agree == 1.0
    truth = np.asarray([0] * 80 + [1] * 80)
    acc = max(np.mean(lo == truth), np.mean(lo == 1 - truth))
    assert acc >= 0.95


def test_pcg_operator_close_to_direct():
    n, d = 300, 16
    X, y = _data(n)
    op = KernelOperator(X, "gaussian", bandwidth=0.6)
    sk = make_accum_sketch(KEY, n, d, 4)
    direct = krr_sketched_fit_matfree(op, y, 1e-2, sk)
    pcg = krr_sketched_fit_pcg(op, y, 1e-2, sk, iters=60)
    np.testing.assert_allclose(np.asarray(pcg.fitted), np.asarray(direct.fitted),
                               rtol=3e-2, atol=3e-2)


def test_dense_guard_refuses_large_n():
    op = KernelOperator(jnp.zeros((DENSE_GUARD_N + 1, 2)), "gaussian")
    with pytest.raises(ValueError, match="refusing to materialize"):
        op.dense()


def test_operator_is_a_pytree():
    X, _ = _data(64)
    op = KernelOperator(X, "matern", bandwidth=0.9, nu=2.5)
    leaves, treedef = jax.tree_util.tree_flatten(op)
    assert len(leaves) == 1
    op2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert op2.kernel == "matern" and op2.nu == 2.5
    sk = make_accum_sketch(KEY, 64, 8, 2)
    out = jax.jit(lambda o: o.sketch_cols(sk, use_kernel=False))(op)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(op.sketch_cols(sk, use_kernel=False)),
                               rtol=1e-6, atol=1e-6)
