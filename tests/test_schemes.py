"""Sampling-scheme zoo: unbiasedness, Poisson design, leverage estimation.

Covers the ``scheme=`` knob end to end: per-scheme E[S Sᵀ] = I (the identity
every estimator rests on), the Poisson/Horvitz–Thompson normalization and
overflow correction, convergence of the sketch-estimated ridge-leverage
probabilities to the exact O(n³) oracle, and draw parity across the dense,
matrix-free, and (on the 8-device leg) sharded engines.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import apply as A
from repro.core.kernel_op import KernelOperator
from repro.core.kernels_math import gaussian_kernel
from repro.core.leverage import leverage_probs
from repro.core.schemes import (
    SCHEMES,
    poisson_inclusion,
    state_leverage_probs,
    validate_scheme,
)
from repro.core.sketch import (
    make_accum_sketch,
    make_accum_sketch_jit,
    make_nystrom_sketch,
)

KEY = jax.random.PRNGKey(0)

needs_8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(the CI acceptance leg sets it)")


def _nonuniform_probs(n):
    """A fixed, deliberately lopsided weight vector (unnormalized)."""
    return jnp.arange(1, n + 1, dtype=jnp.float32) ** 1.5


# --------------------------------------------------------------------------- #
# E[S Sᵀ] = I for every scheme
# --------------------------------------------------------------------------- #

def _check_scheme_unbiasedness(scheme, n, d, m, reps=300):
    """Monte-Carlo E[S Sᵀ] ≈ I at fixed seeds, under non-uniform weights."""
    probs = _nonuniform_probs(n)
    acc = np.zeros((n, n))
    for i in range(reps):
        key = jax.random.fold_in(jax.random.fold_in(KEY, 97 * n + d), i)
        S = np.asarray(
            make_accum_sketch(key, n, d, m, probs, scheme=scheme).dense())
        acc += S @ S.T
    acc /= reps
    diag = np.diag(acc)
    off = acc - np.diag(diag)
    assert abs(diag.mean() - 1.0) < 0.25, (scheme, diag.mean())
    assert abs(off.mean()) < 0.05, (scheme, off.mean())


@pytest.mark.parametrize("scheme", SCHEMES)
def test_unbiasedness_pinned(scheme):
    _check_scheme_unbiasedness(scheme, 16, 4, 2)
    _check_scheme_unbiasedness(scheme, 24, 6, 3)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(8, 40), d=st.integers(2, 8), m=st.integers(1, 4),
       scheme=st.sampled_from(SCHEMES))
def test_unbiasedness_property(n, d, m, scheme):
    d = min(d, n)
    _check_scheme_unbiasedness(scheme, n, d, m, reps=150)


def test_validate_scheme():
    assert validate_scheme("poisson") == "poisson"
    with pytest.raises(ValueError, match="unknown scheme"):
        validate_scheme("importance")


# --------------------------------------------------------------------------- #
# Poisson design: inclusion probabilities, HT normalization, overflow
# --------------------------------------------------------------------------- #

def test_poisson_expected_column_count():
    """E[#included] = Σ π_i (= d when nothing clips); the realized kept count
    (non-zero signs per slab) matches in Monte-Carlo mean, minus the mass
    lost to the overflow truncation at d."""
    n, d, m = 64, 4, 2
    pi = np.asarray(poisson_inclusion(None, n, d, jnp.float32))
    np.testing.assert_allclose(pi.sum(), d, rtol=1e-6)
    counts = []
    for i in range(300):
        sk = make_accum_sketch(jax.random.fold_in(KEY, i), n, d, m,
                               scheme="poisson")
        counts.append(float((np.asarray(sk.signs) != 0).sum(axis=1).mean()))
    # kept = min(N, d) with N ~ PoissonBinomial(π), E[N] = d → mean kept is
    # slightly BELOW d (truncation) but well above d/2
    assert d / 2 < np.mean(counts) <= d, np.mean(counts)


def test_poisson_coef_normalization():
    """The stored probs make the universal coef formula Horvitz–Thompson:
    coef²·d·m·p̃ = N/kept on taken entries (exactly 1 when N ≤ d), constant
    within a slab, with N = lhs·kept an integer; padding entries have sign 0
    and contribute zero columns."""
    n, d, m = 32, 4, 6
    sk = make_accum_sketch(jax.random.PRNGKey(0), n, d, m, scheme="poisson")
    signs = np.asarray(sk.signs)
    p_taken = np.asarray(jnp.take(sk.probs, sk.indices))
    lhs = np.asarray(sk.coef) ** 2 * d * m * p_taken
    assert (np.abs(signs[signs != 0]) >= 1.0 - 1e-6).all()
    saw_overflow = False
    for t in range(m):
        taken = signs[t] != 0
        kept = int(taken.sum())
        assert kept >= 1
        row = lhs[t][taken]
        np.testing.assert_allclose(row, row[0], rtol=1e-5)
        N = row[0] * kept
        np.testing.assert_allclose(N, round(float(N)), atol=1e-3)
        assert row[0] >= 1.0 - 1e-5
        saw_overflow |= row[0] > 1.0 + 1e-3
    assert saw_overflow  # this seed includes an N > d slab (the HT √(N/kept))
    # padding entries contribute nothing: their combination coefficient is 0
    coef = np.asarray(sk.coef)
    assert (signs == 0).any()           # the seed produces real padding
    assert (coef[signs == 0] == 0).all()
    assert np.isfinite(coef).all()


def test_poisson_grow_matches_sketch_both():
    """The progressive engine's accumulated (C, W) under scheme="poisson"
    reproduces the direct sketch application of the final sketch."""
    n, d, m = 96, 8, 4
    X = jax.random.normal(jax.random.PRNGKey(2), (n, 2))
    K = gaussian_kernel(X, X, 0.7)
    sk, C, W, _ = A.grow_sketch_both(KEY, K, d, m_max=m, tol=None,
                                     scheme="poisson")
    C2, W2 = A.sketch_both(K, sk, use_kernel=False)
    np.testing.assert_allclose(np.asarray(C), np.asarray(C2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(W), np.asarray(W2), atol=1e-4)


# --------------------------------------------------------------------------- #
# sketch-estimated leverage → exact oracle
# --------------------------------------------------------------------------- #

def test_sketch_leverage_converges_to_exact():
    """TV(ℓ̂, ℓ) shrinks as the sketch grows — the matrix-free estimate
    approaches the O(n³) oracle ``leverage.leverage_probs``."""
    n, lam = 128, 1e-2
    X = jax.random.normal(jax.random.PRNGKey(3), (n, 2))
    K = gaussian_kernel(X, X, 0.8)
    exact = np.asarray(leverage_probs(K, lam))
    tvs = []
    for d, m in [(8, 2), (16, 8), (32, 32)]:
        state = A.accum_init(jax.random.PRNGKey(7), n, d, m)
        state = A.accum_grow_batched(K, state, m, use_kernel=False)
        est = np.asarray(state_leverage_probs(state, lam, mix=0.0))
        np.testing.assert_allclose(est.sum(), 1.0, atol=1e-5)
        assert (est >= 0).all()
        tvs.append(0.5 * np.abs(est - exact).sum())
    assert tvs[0] > tvs[1] > tvs[2], tvs
    assert tvs[2] < 0.05, tvs


def test_leverage_requires_probs_or_engine():
    """scheme="leverage" has no closed-form draw: the one-shot constructors
    demand explicit probs (the engine path estimates them instead)."""
    with pytest.raises(ValueError, match="leverage"):
        make_accum_sketch(KEY, 32, 4, 2, scheme="leverage")
    with pytest.raises(ValueError, match="leverage"):
        make_accum_sketch_jit(KEY, 32, 4, 2, scheme="leverage")
    with pytest.raises(ValueError, match="doubling"):
        A.grow_sketch_both(KEY, jnp.eye(32), 4, m_max=2, tol=None,
                           scheme="leverage", schedule="unit")


# --------------------------------------------------------------------------- #
# scheme parity: dense ≡ matrix-free ≡ sharded
# --------------------------------------------------------------------------- #

def _parity_setup(n=96):
    X = jax.random.normal(jax.random.PRNGKey(11), (n, 2))
    K = gaussian_kernel(X, X, 0.6)
    op = KernelOperator(X, "gaussian", bandwidth=0.6)
    return K, op


@pytest.mark.parametrize("scheme", SCHEMES)
def test_scheme_parity_dense_vs_matfree(scheme):
    """Same key, same scheme → bitwise-identical draws and matching (C, W)
    whether the engine sweeps a dense K or a matrix-free operator."""
    K, op = _parity_setup()
    kw = dict(m_max=4, tol=None, scheme=scheme)
    sk0, C0, W0, _ = A.grow_sketch_both(KEY, K, 8, **kw)
    sk1, C1, W1, _ = A.grow_sketch_both(KEY, op, 8, use_kernel=False, **kw)
    assert (np.asarray(sk0.indices) == np.asarray(sk1.indices)).all()
    assert (np.asarray(sk0.signs) == np.asarray(sk1.signs)).all()
    np.testing.assert_allclose(np.asarray(C0), np.asarray(C1), atol=2e-4)
    np.testing.assert_allclose(np.asarray(W0), np.asarray(W1), atol=2e-4)


@needs_8
@pytest.mark.parametrize("scheme", SCHEMES)
def test_scheme_parity_sharded(scheme):
    """The acceptance bit: sharded draws are BITWISE identical to the
    single-device engine for every scheme (leverage includes the refinement
    loop — probs re-estimated from driver-level gathers, same fold_in keys)."""
    from repro.core import distributed as D
    mesh = D.make_data_mesh(8)
    K, op = _parity_setup(n=96)
    kw = dict(m_max=4, tol=None, scheme=scheme)
    sk0, C0, W0, _ = A.grow_sketch_both(KEY, op, 8, use_kernel=False, **kw)
    sk1, C1, W1, _ = D.sharded_grow_sketch_both(KEY, op, 8, mesh=mesh, **kw)
    assert (np.asarray(sk0.indices) == np.asarray(sk1.indices)).all()
    assert (np.asarray(sk0.signs) == np.asarray(sk1.signs)).all()
    np.testing.assert_allclose(np.asarray(C0), np.asarray(C1), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(W0), np.asarray(W1), rtol=1e-5,
                               atol=1e-5)


# --------------------------------------------------------------------------- #
# constructor unification (the PR's ride-along bugfix pin)
# --------------------------------------------------------------------------- #

def test_nystrom_probs_normalization_matches_accum():
    """make_nystrom_sketch delegates to make_accum_sketch: unnormalized /
    float64 / list-typed weight vectors produce the IDENTICAL draw in both,
    and the stored probs are normalized to sum 1 in float32."""
    n, d = 40, 6
    raw = [float(3 * i + 1) for i in range(n)]          # unnormalized list
    sk_a = make_nystrom_sketch(KEY, n, d, jnp.asarray(raw, jnp.float64))
    sk_b = make_accum_sketch(KEY, n, d, m=1,
                             probs=jnp.asarray(raw, jnp.float32), signed=False)
    assert (np.asarray(sk_a.indices) == np.asarray(sk_b.indices)).all()
    np.testing.assert_allclose(np.asarray(sk_a.probs), np.asarray(sk_b.probs),
                               rtol=1e-6)
    assert sk_a.probs.dtype == jnp.float32
    np.testing.assert_allclose(float(jnp.sum(sk_a.probs)), 1.0, atol=1e-5)
    # scheme threads through the delegation unchanged
    sk_p = make_nystrom_sketch(KEY, n, d, scheme="poisson")
    assert sk_p.scheme == "poisson" and sk_p.m == 1
