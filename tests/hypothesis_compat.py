"""Import-or-stub shim for hypothesis.

The tier-1 environment may not ship hypothesis; property-based tests import
``given``/``settings``/``st`` from here so they skip cleanly (instead of
failing collection with ModuleNotFoundError) when the dependency is absent.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        """Replace the property test with a skip marker (zero-arg body so
        pytest never tries to resolve the strategy kwargs as fixtures)."""
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def _skipped():
                pass

            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _StrategiesStub:
        """Stands in for hypothesis.strategies at decoration time only."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategiesStub()
