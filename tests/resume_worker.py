"""Subprocess worker for the cross-process kill-and-resume bitwise pin.

Usage: python tests/resume_worker.py <mode> <ckpt_dir>

  ref    — no checkpointing, no faults: print the reference tokens as JSON;
  kill   — generate with checkpointing armed; the parent sets a
           REPRO_FAULT_PLAN that kills a decode dispatch, so this process is
           expected to die with DeviceLost → exit code 17, "KILLED" on stdout;
  resume — fresh process, no faults, same ckpt_dir: resume the half-finished
           request and print its tokens as JSON.

The parent (tests/test_resilience.py) asserts ref == resume bitwise — the
counter-based RNG makes (cache, emitted tokens) the complete resume state, so
a request killed mid-decode and resumed in a NEW PROCESS must reproduce the
uninterrupted token stream exactly.

Everything about the request (arch, prompts, sampling temperature, seed) is
fixed here so all three invocations describe the same request.
"""
import json
import sys

import numpy as np


def main() -> int:
    mode, ckdir = sys.argv[1], sys.argv[2]
    import jax

    from repro.configs import ARCHS, reduced
    from repro.models.model import init_params
    from repro.resilience import faults
    from repro.serve.engine import Engine, ServeConfig

    cfg = reduced(ARCHS["stablelm-3b"])
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, L, n_new = 2, 8, 6
    prompts = (
        np.random.default_rng(0).integers(0, cfg.vocab_size, size=(B, L))
        .astype(np.int32)
    )
    sc = ServeConfig(
        max_len=L + n_new + 2, use_sketch=True, temperature=0.7, seed=3,
        ckpt_dir=None if mode == "ref" else ckdir, ckpt_every=2,
    )
    eng = Engine(cfg, params, sc)
    try:
        toks, _ = eng.generate(
            prompts, n_new, request_id=None if mode == "ref" else "req"
        )
    except faults.DeviceLost:
        print("KILLED")
        return 17
    print(json.dumps(toks.tolist()))
    return 0


if __name__ == "__main__":
    sys.exit(main())
