"""Tests for the progressive accumulation engine: incremental m → m+1 updates
of (C, W), the adaptive stopping rule, and the grow/append sketch API.

The load-bearing guarantees (ISSUE 2 acceptance criteria):
  * growing step-by-step to m matches the one-shot ``make_accum_sketch`` +
    ``sketch_both`` at that m to ≤ 1e-5 relative error (f32, same keys);
  * one step is asymptotically O(n·d) — no O(n²·d) recompute and no n²-sized
    intermediate in the jaxpr.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import apply as A
from repro.core.kernels_math import gaussian_kernel, laplacian_kernel
from repro.core.sketch import (
    AccumSketch,
    append_subsample,
    make_accum_sketch,
    make_accum_sketch_jit,
)

KEY = jax.random.PRNGKey(0)


def _psd_kernel(n: int, p: int = 3, bandwidth: float = 0.6, seed: int = 0):
    X = jax.random.uniform(jax.random.fold_in(KEY, seed), (n, p))
    return gaussian_kernel(X, X, bandwidth=bandwidth)


def _rel(a, b):
    return float(jnp.linalg.norm(a - b) / jnp.maximum(jnp.linalg.norm(b), 1e-30))


# --------------------------------------------------------------------------- #
# incremental update ≡ one-shot construction
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("m", [1, 3, 6])
def test_incremental_matches_one_shot(m):
    """Growing to m slab-by-slab equals make_accum_sketch + sketch_both at the
    final m, given the same key (engine pre-draws with the same RNG scheme)."""
    n, d = 300, 16
    K = _psd_kernel(n)
    sk = make_accum_sketch(KEY, n, d, m)
    C_ref, W_ref = A.sketch_both(K, sk, use_kernel=False)

    state = A.accum_init(KEY, n, d, m)
    state = A.accum_grow(K, state, m, use_kernel=False)
    assert bool(jnp.all(state.indices == sk.indices))
    assert _rel(state.C, C_ref.astype(jnp.float32)) < 1e-5
    assert _rel(state.W, W_ref.astype(jnp.float32)) < 1e-5
    assert int(state.m) == m


def test_incremental_kernel_path_matches_xla_path():
    """The single-slab Pallas entry point (interpret on CPU) and the XLA
    gather path produce the same trajectory."""
    n, d, m = 256, 16, 4
    K = _psd_kernel(n, seed=1)
    s_xla = A.accum_grow(K, A.accum_init(KEY, n, d, m), m, use_kernel=False)
    s_krn = A.accum_grow(K, A.accum_init(KEY, n, d, m), m, use_kernel=True)
    np.testing.assert_allclose(np.asarray(s_krn.C), np.asarray(s_xla.C),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_krn.W), np.asarray(s_xla.W),
                               rtol=1e-5, atol=1e-5)


def test_truncated_state_sketch_consistent_with_from_scratch():
    """grow_sketch_both's (sk, C, W) is self-consistent: re-applying the
    returned (truncated, renormalized) sketch from scratch reproduces C, W."""
    n, d = 200, 12
    K = _psd_kernel(n, seed=2)
    sk, C, W, info = A.grow_sketch_both(KEY, K, d, m_max=8, tol=0.15)
    assert 1 <= info["m"] <= 8 and sk.m == info["m"]
    C_ref, W_ref = A.sketch_both(K, sk, use_kernel=False)
    assert _rel(C, C_ref.astype(jnp.float32)) < 1e-5
    assert _rel(W, W_ref.astype(jnp.float32)) < 1e-5


# --------------------------------------------------------------------------- #
# O(n·d) per step — jaxpr / FLOP regression
# --------------------------------------------------------------------------- #

def _iter_eqns(jaxpr):
    try:
        from jax.extend.core import ClosedJaxpr, Jaxpr
    except ImportError:  # older jax
        from jax.core import ClosedJaxpr, Jaxpr

    def subjaxprs(val):
        if isinstance(val, ClosedJaxpr):
            yield val.jaxpr
        elif isinstance(val, Jaxpr):
            yield val
        elif isinstance(val, (list, tuple)):
            for v in val:
                yield from subjaxprs(v)

    for eqn in jaxpr.eqns:
        yield eqn
        for val in eqn.params.values():
            for sub in subjaxprs(val):
                yield from _iter_eqns(sub)


def test_step_has_no_quadratic_intermediate():
    """jaxpr regression: every intermediate of one engine step is O(n·d) —
    the O(n²·d) (or even n²) from-scratch recompute never appears."""
    n, d, m = 256, 8, 4
    K = _psd_kernel(n, seed=3)
    state = A.accum_init(KEY, n, d, m)
    jaxpr = jax.make_jaxpr(
        lambda K, s: A.accum_step(K, s, use_kernel=False))(K, state)
    budget = 6 * n * d                      # generous O(n·d); n² = 65536 ≫ this
    for eqn in _iter_eqns(jaxpr.jaxpr):
        for v in eqn.outvars:
            size = int(np.prod(v.aval.shape)) if v.aval.shape else 1
            assert size <= budget, (eqn.primitive.name, v.aval.shape)


def test_step_flops_scale_linearly_in_n():
    """FLOP regression via XLA cost analysis: doubling n must ~double (not
    quadruple) the cost of one incremental step."""

    def flops_at(n):
        d, m = 16, 4
        K = _psd_kernel(n, seed=4)
        state = A.accum_init(KEY, n, d, m)
        step = jax.jit(lambda K, s: A.accum_step(K, s, use_kernel=False))
        cost = step.lower(K, state).compile().cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else None
        if not cost or "flops" not in cost:
            pytest.skip("XLA cost analysis unavailable on this backend")
        return float(cost["flops"])

    f1, f2 = flops_at(512), flops_at(1024)
    assert f2 / f1 < 3.0, f"step cost superlinear in n: {f1} -> {f2}"


# --------------------------------------------------------------------------- #
# adaptive stopping rule
# --------------------------------------------------------------------------- #

def test_adaptive_stops_early_on_easy_kernel():
    """A fast-decaying spectrum clears a loose tolerance at small m."""
    n, d = 300, 24
    K = _psd_kernel(n, bandwidth=0.8, seed=5)
    sk, C, W, info = A.grow_sketch_both(KEY, K, d, m_max=16, tol=0.2)
    assert info["m"] < 16 and info["err"] <= 0.2


def test_adaptive_exhausts_budget_on_unreachable_tol():
    n, d = 200, 8
    X = jax.random.uniform(jax.random.fold_in(KEY, 6), (n, 3))
    K = laplacian_kernel(X, X, bandwidth=0.5)      # heavy spectral tail
    sk, C, W, info = A.grow_sketch_both(KEY, K, d, m_max=6, tol=1e-6)
    assert info["m"] == 6                          # ran out of slabs
    assert np.isfinite(info["err"]) and info["err"] > 1e-6


def test_estimators_agree_on_scale():
    """Holdout and Hutchinson rules both report a small error for a sketch
    that reconstructs K well, and both are plain AccumState → scalar."""
    n, d = 300, 64
    K = _psd_kernel(n, bandwidth=0.8, seed=7)
    state = A.accum_grow(K, A.accum_init(KEY, n, d, 8), 8, use_kernel=False)
    e_hold = A.make_holdout_estimator(jax.random.fold_in(KEY, 1), K)(state)
    e_hutch = A.make_hutchinson_estimator(jax.random.fold_in(KEY, 2), K)(state)
    assert float(e_hold) < 0.05 and float(e_hutch) < 0.05


def test_adaptive_check_every_amortization():
    """check_every > 1 evaluates the estimator on a stride but still stops."""
    n, d = 250, 16
    K = _psd_kernel(n, bandwidth=0.7, seed=8)
    est = A.make_holdout_estimator(jax.random.fold_in(KEY, 3), K)
    state = A.accum_init(KEY, n, d, 12)
    out = A.accum_grow_adaptive(K, state, tol=0.25, estimator=est,
                                check_every=3, use_kernel=False)
    assert int(out.m) % 3 == 0 or int(out.m) == 12
    assert float(out.err) <= 0.25 or int(out.m) == 12


# --------------------------------------------------------------------------- #
# grow/append sketch API + constructor bugfixes
# --------------------------------------------------------------------------- #

def test_append_subsample_rescales_survivors():
    sk = make_accum_sketch(KEY, 100, 8, 4)
    sk2 = append_subsample(sk, jax.random.fold_in(KEY, 9))
    assert sk2.m == 5 and bool(jnp.all(sk2.indices[:4] == sk.indices))
    np.testing.assert_allclose(np.asarray(sk2.coef[:4]),
                               np.asarray(sk.coef) * np.sqrt(4 / 5), rtol=1e-6)
    # dense identity: S_5 = sqrt(4/5) S_4 + T̃_5
    T = AccumSketch(indices=sk2.indices[4:], signs=sk2.signs[4:],
                    probs=sk2.probs, n=sk2.n)
    T5 = np.asarray(T.dense()) * np.sqrt(1 / 5)    # renormalize m=1 → slab-of-5
    np.testing.assert_allclose(np.asarray(sk2.dense()),
                               np.sqrt(4 / 5) * np.asarray(sk.dense()) + T5,
                               rtol=1e-5, atol=1e-6)


def test_truncated_renormalizes():
    sk = make_accum_sketch(KEY, 80, 6, 5)
    tr = sk.truncated(3)
    ref = AccumSketch(indices=sk.indices[:3], signs=sk.signs[:3],
                      probs=sk.probs, n=sk.n)
    np.testing.assert_allclose(np.asarray(tr.coef), np.asarray(ref.coef),
                               rtol=1e-6)


def test_make_accum_sketch_jit_propagates_dtype():
    """Seed bug: make_accum_sketch_jit ignored dtype (always f32)."""
    sk16 = make_accum_sketch_jit(KEY, 64, 8, 2, dtype=jnp.bfloat16)
    assert sk16.signs.dtype == jnp.bfloat16
    assert sk16.probs.dtype == jnp.bfloat16
    assert sk16.coef.dtype == jnp.bfloat16
    sk32 = make_accum_sketch_jit(KEY, 64, 8, 2)
    assert sk32.signs.dtype == jnp.float32


def test_coef_is_cached_and_correct():
    """Constructors populate coef_ so hot loops skip the probs gather; the
    cache matches the recomputed value and survives pytree round-trips."""
    sk = make_accum_sketch(KEY, 64, 8, 3)
    assert sk.coef_ is not None
    uncached = dataclasses.replace(sk, coef_=None)
    np.testing.assert_allclose(np.asarray(sk.coef), np.asarray(uncached.coef),
                               rtol=1e-7)
    leaves, treedef = jax.tree_util.tree_flatten(sk)
    sk2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert sk2.coef_ is not None
    np.testing.assert_allclose(np.asarray(sk2.coef), np.asarray(sk.coef))


# --------------------------------------------------------------------------- #
# jittable driver (traced info scalars + masked sketch)
# --------------------------------------------------------------------------- #

def test_grow_sketch_both_is_jittable():
    """The one-call driver must trace: ``info``'s m/err come back as traced
    scalars (the seed's int()/float() forced a host sync per call) and the
    sketch degrades to the masked full-size form, which applies identically
    to the eager truncation."""
    n, d = 200, 12
    K = _psd_kernel(n, seed=3)

    sk_e, C_e, W_e, info_e = A.grow_sketch_both(KEY, K, d, m_max=8, tol=0.15,
                                                use_kernel=False)

    @jax.jit
    def driver(key, K):
        sk, C, W, info = A.grow_sketch_both(key, K, d, m_max=8, tol=0.15,
                                            use_kernel=False)
        # applying the masked sketch INSIDE the trace must work too
        C2 = A.sketch_right(K, sk)
        return sk, C, W, info, C2

    sk_j, C_j, W_j, info_j, C2 = driver(KEY, K)
    assert int(info_j["m"]) == int(info_e["m"])
    np.testing.assert_allclose(float(info_j["err"]), float(info_e["err"]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(C_j), np.asarray(C_e),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(W_j), np.asarray(W_e),
                               rtol=1e-5, atol=1e-6)
    # masked sketch ≡ truncated sketch under every bilinear application
    assert sk_j.m == 8 and sk_e.m == int(info_e["m"])   # static vs truncated
    np.testing.assert_allclose(np.asarray(C2), np.asarray(A.sketch_right(K, sk_e)),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(sk_j.dense()),
                               np.asarray(sk_e.dense()), rtol=1e-5, atol=1e-6)


def test_adaptive_krr_driver_jits_end_to_end():
    """The adaptive KRR caller can stay inside jit: fit + predict traced."""
    from repro.core.krr import krr_sketched_fit_adaptive

    n, d = 200, 12
    X = jax.random.uniform(jax.random.fold_in(KEY, 9), (n, 3))
    K = gaussian_kernel(X, X, bandwidth=0.6)
    y = jnp.sin(3.0 * X[:, 0])

    eager = krr_sketched_fit_adaptive(K, y, 1e-2, KEY, d, tol=0.1, m_max=8,
                                      use_kernel=False)

    @jax.jit
    def fit(K, y):
        mdl = krr_sketched_fit_adaptive(K, y, 1e-2, KEY, d, tol=0.1, m_max=8,
                                        use_kernel=False)
        return mdl.fitted, mdl.info["m"], mdl.info["err"]

    fitted, m, err = fit(K, y)
    assert int(m) == int(eager.info["m"])
    np.testing.assert_allclose(np.asarray(fitted), np.asarray(eager.fitted),
                               rtol=1e-4, atol=1e-5)
