"""Batched rank-B accumulation: one data sweep per m → m+B batch.

The load-bearing guarantees (ISSUE 5 acceptance criteria):

  * ``accum_grow_batched`` ≡ B sequential ``accum_step`` calls on every
    backend ({dense-XLA, dense-Pallas, matfree, sharded} × {f32, f64-on-CPU}):
    IDENTICAL index draws (both fold the same pre-drawn slabs) and (C, W)
    equal to ≤ 1e-5 relative (summation order only);
  * the doubling schedule stops in both directions (early on a loose tol,
    budget-exhausted on an unreachable one) in O(log m) passes;
  * one K-pass per batch — jaxpr regressions: a single pallas_call where the
    sequential loop launches B, and no B×(n·d) slab on the streaming path;
  * the measured autotune cache round-trips, and a corrupt/missing cache
    falls back to the static table;
  * the engine's donated growth wrappers really alias their loop carries.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.trace import count_pallas_calls, max_intermediate_elems
from repro.core import apply as A
from repro.core import distributed as D
from repro.core.kernel_op import KernelOperator
from repro.core.kernels_math import gaussian_kernel, laplacian_kernel
from repro.core.krr import krr_sketched_fit_adaptive
from repro.kernels.accum_apply import autotune
from repro.kernels.accum_apply.kernel import accum_grow_slabs
from repro.kernels.accum_apply.ops import (
    accum_grow_kernel,
    autotune_blocks,
    sketch_right_kernel,
)
from repro.kernels.accum_apply.ref import accum_grow_ref
from repro.core.sketch import make_accum_sketch

KEY = jax.random.PRNGKey(0)

needs_8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(the distributed CI leg sets it)")


def _rel(a, b):
    return float(jnp.linalg.norm(a - b) / jnp.maximum(jnp.linalg.norm(b), 1e-30))


def _problem(n=300, p=3, bandwidth=0.6, dtype=jnp.float32):
    X = jax.random.uniform(KEY, (n, p), dtype)
    op = KernelOperator(X, "gaussian", bandwidth=bandwidth)
    return X, op


# --------------------------------------------------------------------------- #
# fused kernel vs ref oracle (required sweep for every Pallas kernel)
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,d,B", [(256, 16, 4), (300, 8, 8), (128, 64, 1),
                                   (173, 9, 3)])
def test_grow_kernel_sweep(n, d, B, dtype):
    K = jax.random.normal(jax.random.fold_in(KEY, n + d), (n, n), dtype)
    idx = jax.random.randint(jax.random.fold_in(KEY, 1), (B, d), 0, n)
    coef = jax.random.normal(jax.random.fold_in(KEY, 2), (B, d))
    C = jax.random.normal(jax.random.fold_in(KEY, 3), (n, d), jnp.float32)
    a = jnp.float32(0.77)
    Cn, TtG, TtC = accum_grow_kernel(K, idx, coef, C, a)
    Cr, TtGr, TtCr = accum_grow_ref(K, idx, coef, C, a)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(Cn), np.asarray(Cr), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(TtG), np.asarray(TtGr), rtol=tol,
                               atol=max(tol, 1e-3 * float(jnp.abs(TtGr).max())))
    np.testing.assert_allclose(np.asarray(TtC), np.asarray(TtCr), rtol=tol, atol=tol)


def test_grow_kernel_multi_tile_accumulation():
    """Grid with several row tiles AND column chunks: the W pieces accumulate
    across every grid step, not just the last."""
    n, d, B = 512, 16, 4
    K = jax.random.normal(KEY, (n, n))
    idx = jax.random.randint(jax.random.fold_in(KEY, 1), (B, d), 0, n)
    coef = jax.random.normal(jax.random.fold_in(KEY, 2), (B, d))
    C = jax.random.normal(jax.random.fold_in(KEY, 3), (n, d))
    a = jnp.float32(0.5)
    out = accum_grow_slabs(K, idx, coef.astype(jnp.float32), C,
                           jnp.asarray([0.5], jnp.float32), bm=128, bn=128)
    ref = accum_grow_ref(K, idx, coef, C, a)
    for x, y in zip(out, ref):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-4,
                                   atol=1e-4)


# --------------------------------------------------------------------------- #
# batched ≡ sequential: {dense-XLA, dense-Pallas, matfree × both backends}
# --------------------------------------------------------------------------- #

def _seq_and_batched(K_in, B, *, n, d, m_max, use_kernel, mesh=None):
    seq = A.accum_grow(K_in, A.accum_init(KEY, n, d, m_max), B,
                       use_kernel=False, donate=False)
    bat = A.accum_grow_batched(K_in, A.accum_init(KEY, n, d, m_max), B,
                               use_kernel=use_kernel, mesh=mesh, donate=False)
    return seq, bat


@pytest.mark.parametrize("B", [1, 3, 6])
@pytest.mark.parametrize("path,use_kernel", [
    ("dense", False), ("dense", True), ("matfree", False), ("matfree", True),
])
def test_batched_equals_sequential_f32(path, use_kernel, B):
    n, d, m_max = 300, 16, 8
    _, op = _problem(n)
    K_in = op.dense() if path == "dense" else op
    seq, bat = _seq_and_batched(K_in, B, n=n, d=d, m_max=m_max,
                                use_kernel=use_kernel)
    assert bool(jnp.all(bat.indices == seq.indices))     # identical draws
    assert int(bat.m) == int(seq.m) == B
    assert _rel(bat.C, seq.C) < 1e-5
    assert _rel(bat.W, seq.W) < 1e-5


@pytest.mark.parametrize("path", ["dense", "matfree"])
def test_batched_equals_sequential_f64_cpu(path):
    with jax.experimental.enable_x64():
        n, d, B = 200, 12, 4
        X = jax.random.uniform(KEY, (n, 3), jnp.float64)
        op = KernelOperator(X, "gaussian", bandwidth=0.6)
        K_in = op.dense() if path == "dense" else op
        seq, bat = _seq_and_batched(K_in, B, n=n, d=d, m_max=8,
                                    use_kernel=False)
        assert bat.C.dtype == jnp.float32                # engine carry contract
        assert _rel(bat.C, seq.C) < 1e-5
        assert _rel(bat.W, seq.W) < 1e-5


def test_batched_from_nonzero_start_matches_sequential():
    """A batch folded mid-trajectory continues the SAME trajectory: grow 3
    sequentially, batch 4 more ≡ 7 sequential steps."""
    n, d = 300, 16
    _, op = _problem(n)
    K = op.dense()
    seq7 = A.accum_grow(K, A.accum_init(KEY, n, d, 8), 7, use_kernel=False,
                        donate=False)
    st3 = A.accum_grow(K, A.accum_init(KEY, n, d, 8), 3, use_kernel=False,
                       donate=False)
    st7 = st3.grow_batched(K, 4, use_kernel=False, donate=False)
    assert int(st7.m) == 7
    assert _rel(st7.C, seq7.C) < 1e-5
    assert _rel(st7.W, seq7.W) < 1e-5


def test_batched_overrun_raises():
    n, d = 100, 8
    _, op = _problem(n)
    state = A.accum_grow(op.dense(), A.accum_init(KEY, n, d, 4), 3,
                         use_kernel=False, donate=False)
    with pytest.raises(ValueError, match="overruns"):
        A.accum_grow_batched(op.dense(), state, 2, use_kernel=False)
    with pytest.raises(ValueError, match="batch size"):
        A.accum_grow_batched(op.dense(), state, 0, use_kernel=False)
    # the mesh path must validate too — an overrun there would silently
    # clamp the slice and re-fold earlier slabs into corrupted (C, W)
    st_op = A.accum_grow(op, A.accum_init(KEY, n, d, 4), 3,
                         use_kernel=False, donate=False)
    with pytest.raises(ValueError, match="overruns"):
        A.accum_grow_batched(op, st_op, 2, mesh=D.make_data_mesh(1))


def test_grow_sketch_both_fixed_size_is_one_pass():
    """tol=None (fixed m = m_max) rides the batched entry point: ONE data
    pass, and the result equals the one-shot sketch_both at m_max."""
    n, d, m_max = 300, 16, 8
    _, op = _problem(n)
    K = op.dense()
    sk, C, W, info = A.grow_sketch_both(KEY, K, d, m_max=m_max,
                                        use_kernel=False)
    assert int(info["m"]) == m_max and int(info["passes"]) == 1
    C_ref, W_ref = A.sketch_both(K, sk, use_kernel=False)
    assert _rel(C, C_ref.astype(jnp.float32)) < 1e-5
    assert _rel(W, W_ref.astype(jnp.float32)) < 1e-5
    jaxpr = jax.make_jaxpr(
        lambda K: A.grow_sketch_both(KEY, K, d, m_max=m_max,
                                     use_kernel=True)[1])(K)
    assert _count_pallas_calls(jaxpr.jaxpr) == 1


@pytest.mark.parametrize("num", [1])
def test_batched_sharded_single_device_mesh(num):
    """The shard_map plumbing of the batched step must be exact on a trivial
    mesh (n chosen to NOT divide the mesh padding away on larger ones)."""
    n, d, B = 300, 16, 5
    _, op = _problem(n)
    mesh = D.make_data_mesh(num)
    seq = A.accum_grow(op, A.accum_init(KEY, n, d, 8), B, use_kernel=False,
                       donate=False)
    bat = A.accum_grow_batched(op, A.accum_init(KEY, n, d, 8), B, mesh=mesh)
    assert bool(jnp.all(bat.indices == seq.indices))
    assert _rel(bat.C, seq.C) < 1e-5
    assert _rel(bat.W, seq.W) < 1e-5


@needs_8
def test_batched_sharded_8_devices_matches():
    n, d, B = 330, 16, 6                  # 330 % 8 != 0: pad path exercised
    X = jax.random.uniform(KEY, (n, 3))
    op = KernelOperator(X, "gaussian", bandwidth=0.6)
    mesh = D.make_data_mesh(8)
    seq = A.accum_grow(op, A.accum_init(KEY, n, d, 8), B, use_kernel=False,
                       donate=False)
    bat = A.accum_grow_batched(op, A.accum_init(KEY, n, d, 8), B, mesh=mesh)
    assert bool(jnp.all(bat.indices == seq.indices))
    assert _rel(bat.C, seq.C) < 1e-5
    assert _rel(bat.W, seq.W) < 1e-5


@needs_8
def test_doubling_sharded_matches_single_device():
    n, d = 320, 16
    X = jax.random.uniform(KEY, (n, 3))
    op = KernelOperator(X, "gaussian", bandwidth=0.6)
    mesh = D.make_data_mesh(8)
    s0 = A.grow_sketch_both(KEY, op, d, m_max=8, tol=0.1, use_kernel=False)
    s1 = A.grow_sketch_both(KEY, op, d, m_max=8, tol=0.1, use_kernel=False,
                            mesh=mesh)
    assert int(s0[3]["m"]) == int(s1[3]["m"])
    assert int(s0[3]["passes"]) == int(s1[3]["passes"])
    assert bool(jnp.all(s0[0].indices == s1[0].indices))
    assert _rel(s1[1], s0[1]) < 1e-5
    assert _rel(s1[2], s0[2]) < 1e-5


# --------------------------------------------------------------------------- #
# doubling schedule: stopping both directions, O(log m) passes
# --------------------------------------------------------------------------- #

def test_doubling_schedule_shape():
    assert A.doubling_schedule(0, 1) == [1]
    assert A.doubling_schedule(0, 6) == [1, 2, 3]
    assert A.doubling_schedule(0, 32) == [1, 2, 4, 8, 16, 1]
    assert A.doubling_schedule(3, 8) == [1, 2, 2]
    assert sum(A.doubling_schedule(0, 100)) == 100
    # O(log m): the ladder length is ≤ 2·log2(m_max) + 2 for any m_max
    for m_max in (1, 2, 5, 7, 31, 32, 100, 1000):
        assert len(A.doubling_schedule(0, m_max)) <= 2 * int(np.log2(m_max) + 1) + 2


def test_doubling_stops_early_on_easy_kernel():
    n, d = 300, 24
    X = jax.random.uniform(jax.random.fold_in(KEY, 5), (n, 3))
    K = gaussian_kernel(X, X, bandwidth=0.8)
    sk, C, W, info = A.grow_sketch_both(KEY, K, d, m_max=16, tol=0.2,
                                        use_kernel=False)
    assert int(info["m"]) < 16 and float(info["err"]) <= 0.2
    # O(log m) passes, and strictly fewer than the unit schedule's m passes
    # whenever more than one batch was applied
    assert int(info["passes"]) <= len(A.doubling_schedule(0, 16))


def test_doubling_exhausts_budget_on_unreachable_tol():
    n, d = 200, 8
    X = jax.random.uniform(jax.random.fold_in(KEY, 6), (n, 3))
    K = laplacian_kernel(X, X, bandwidth=0.5)      # heavy spectral tail
    sk, C, W, info = A.grow_sketch_both(KEY, K, d, m_max=6, tol=1e-6,
                                        use_kernel=False)
    assert int(info["m"]) == 6                     # ran out of slabs
    assert np.isfinite(float(info["err"])) and float(info["err"]) > 1e-6
    # every phase of the ladder ran: 6 slabs in 3 passes, not 6
    assert int(info["passes"]) == len(A.doubling_schedule(0, 6)) == 3


def test_doubling_result_self_consistent_and_unit_available():
    """The doubling driver's (sk, C, W) re-applies from scratch (same contract
    as the unit schedule), and schedule="unit" still routes the old loop."""
    n, d = 200, 12
    _, op = _problem(n, bandwidth=0.5)
    K = op.dense()
    sk, C, W, info = A.grow_sketch_both(KEY, K, d, m_max=8, tol=0.15,
                                        use_kernel=False)
    C_ref, W_ref = A.sketch_both(K, sk, use_kernel=False)
    assert _rel(C, C_ref.astype(jnp.float32)) < 1e-5
    assert _rel(W, W_ref.astype(jnp.float32)) < 1e-5
    sku, Cu, Wu, infou = A.grow_sketch_both(KEY, K, d, m_max=8, tol=0.15,
                                            use_kernel=False, schedule="unit")
    assert int(infou["passes"]) == int(infou["m"])   # unit: one pass per slab
    with pytest.raises(ValueError, match="schedule"):
        A.accum_grow_adaptive(K, A.accum_init(KEY, n, d, 8), tol=0.1,
                              estimator=lambda s: s.err, schedule="bogus")


def test_doubling_driver_jits_and_matches_eager():
    n, d = 200, 12
    _, op = _problem(n, bandwidth=0.5)
    K = op.dense()
    eager = A.grow_sketch_both(KEY, K, d, m_max=8, tol=0.15, use_kernel=False)

    @jax.jit
    def driver(key, K):
        return A.grow_sketch_both(key, K, d, m_max=8, tol=0.15,
                                  use_kernel=False)

    sk_j, C_j, W_j, info_j = driver(KEY, K)
    assert int(info_j["m"]) == int(eager[3]["m"])
    assert int(info_j["passes"]) == int(eager[3]["passes"])
    np.testing.assert_allclose(np.asarray(C_j), np.asarray(eager[1]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(W_j), np.asarray(eager[2]),
                               rtol=1e-5, atol=1e-6)


def test_adaptive_krr_doubling_vs_unit_quality():
    """Both schedules clear the same error target; doubling reports its pass
    count in the model info."""
    n, d = 250, 16
    X = jax.random.uniform(KEY, (n, 3))
    K = gaussian_kernel(X, X, bandwidth=0.5)
    y = jnp.sin(3.0 * X[:, 0])
    md = krr_sketched_fit_adaptive(K, y, 1e-2, KEY, d, tol=0.1, m_max=8,
                                   use_kernel=False)
    mu = krr_sketched_fit_adaptive(K, y, 1e-2, KEY, d, tol=0.1, m_max=8,
                                   use_kernel=False, schedule="unit")
    assert float(md.info["err"]) <= 0.1 or int(md.info["m"]) == 8
    assert float(mu.info["err"]) <= 0.1 or int(mu.info["m"]) == 8
    assert int(md.info["passes"]) <= int(mu.info["passes"])


# --------------------------------------------------------------------------- #
# jaxpr regressions: one K-pass per batch, no B×(n·d) slab, donated carries
# --------------------------------------------------------------------------- #

# the hand-rolled walkers this file used to carry now live in
# repro.analysis.trace — the sequential-launch and B×(n·d)-slab positive
# controls below keep proving the shared library still catches both classes
_count_pallas_calls = count_pallas_calls
_max_intermediate_elems = max_intermediate_elems


def test_one_pallas_launch_per_batch():
    """The Pallas path reads K through ONE pallas_call per batch; B sequential
    steps launch B (the positive control)."""
    n, d, B = 256, 16, 8
    _, op = _problem(n)
    K = op.dense()
    state = A.accum_init(KEY, n, d, B)

    batched = jax.make_jaxpr(
        lambda K, s: A.accum_grow_batched(K, s, B, use_kernel=True))(K, state)
    assert _count_pallas_calls(batched.jaxpr) == 1

    def seq(K, s):
        for _ in range(B):
            s = A.accum_step(K, s, use_kernel=True)
        return s

    sequential = jax.make_jaxpr(seq)(K, state)
    assert _count_pallas_calls(sequential.jaxpr) == B


def test_batched_matfree_no_Bnd_slab():
    """Streaming path: the batch's kernel-eval slab stays chunk-bounded — no
    (n, B·d) buffer even though all B slabs ride one pass.  (The B×(n·d)
    object WOULD appear if the batch were evaluated as one unchunked slab —
    the positive control.)"""
    n, p, d, B = 32768, 4, 64, 8                  # m·d = 512 → chunk < n
    X = jax.random.uniform(KEY, (n, p))
    state = A.accum_init(KEY, n, d, B)
    budget = 4 * 1024 * 1024                      # the ~16 MiB f32 slab budget

    jaxpr = jax.make_jaxpr(
        lambda X, s: A.accum_grow_batched(
            KernelOperator(X, "gaussian", bandwidth=0.6), s, B,
            use_kernel=False))(X, state)
    peak = _max_intermediate_elems(jaxpr.jaxpr)
    assert peak < n * B * d, f"B×(n·d) slab materialized: {peak}"
    assert peak <= budget + n * (p + d), peak


def test_grow_wrappers_donate_loop_carries():
    """Peak-buffer regression for the donation satellite: the jitted growth
    wrappers advertise input-output aliasing on the state (so XLA reuses the
    n·d C buffer instead of holding 2×), and an eager call really consumes
    the caller's buffers."""
    n, d = 256, 16
    _, op = _problem(n)
    K = op.dense()

    from repro.analysis.trace import verify_donation

    low = A._grow_loop_donated.lower(K, A.accum_init(KEY, n, d, 4), 4, False)
    assert verify_donation(low)
    lowb = A._grow_batched_donated.lower(K, A.accum_init(KEY, n, d, 4), 4, False)
    assert verify_donation(lowb)

    st0 = A.accum_init(KEY, n, d, 4)
    out = A.accum_grow(K, st0, 4, use_kernel=False)
    assert int(out.m) == 4
    assert st0.C.is_deleted()                     # buffers really moved
    st1 = A.accum_init(KEY, n, d, 4)
    keep = A.accum_grow(K, st1, 4, use_kernel=False, donate=False)
    assert not st1.C.is_deleted()                 # opt-out for benchmarks

    # donation must NOT fire under an outer trace (it would be dropped with
    # a warning); the traced path still works
    @jax.jit
    def traced(K, s):
        return A.accum_grow(K, s, 4, use_kernel=False).C

    np.testing.assert_allclose(np.asarray(traced(K, A.accum_init(KEY, n, d, 4))),
                               np.asarray(out.C), rtol=1e-6, atol=1e-7)


# --------------------------------------------------------------------------- #
# measured autotune cache
# --------------------------------------------------------------------------- #

def test_autotune_cache_round_trip(tmp_path, monkeypatch):
    """First eligible eager call measures once and persists; the persisted
    winner is served afterwards (including to trace-time lookups)."""
    cache = tmp_path / "autotune.json"
    monkeypatch.setenv(autotune.ENV_CACHE, str(cache))
    monkeypatch.setenv(autotune.ENV_GATE, "1")

    n, d, m = 128, 16, 3
    K = jax.random.normal(KEY, (n, n))
    sk = make_accum_sketch(KEY, n, d, m)
    out = sketch_right_kernel(K, sk)
    assert cache.exists()
    entries = json.loads(cache.read_text())
    assert entries, "measurement did not persist a winner"
    blocks = autotune.lookup("accum_apply", (n, n, d, m), K.dtype, True)
    assert blocks is not None
    # the table lookup now serves the measured winner (e.g. under jit)
    assert autotune_blocks(n, n, d, m, K.dtype, interpret=True) == blocks
    # and the result is still the oracle's
    from repro.kernels.accum_apply.ref import accum_apply_ref
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(accum_apply_ref(K, sk.indices, sk.coef)),
                               rtol=1e-5, atol=1e-5)


def test_autotune_corrupt_and_missing_cache_fall_back(tmp_path, monkeypatch):
    """A corrupt cache file (or garbage entries) must degrade to the static
    table / heuristic — never crash, never return garbage blocks."""
    cache = tmp_path / "autotune.json"
    monkeypatch.setenv(autotune.ENV_CACHE, str(cache))
    monkeypatch.setenv(autotune.ENV_GATE, "0")         # no measuring

    # missing file → static table hit at the anchor shape
    assert autotune_blocks(4096, 8192, 64, 4, jnp.float32, interpret=True) == (256, 64)

    # corrupt JSON → same fallback, no exception
    cache.write_text("{not json at all")
    autotune._MEM.clear()
    assert autotune.lookup("accum_apply", (4096, 8192, 64, 4), jnp.float32,
                           True) is None
    assert autotune_blocks(4096, 8192, 64, 4, jnp.float32, interpret=True) == (256, 64)

    # valid JSON with garbage values → entries rejected, fallback again
    cache.write_text(json.dumps({"accum_apply|4096|8192|64|4|float32|cpu/interpret":
                                 ["huge", -3]}))
    autotune._MEM.clear()
    assert autotune.lookup("accum_apply", (4096, 8192, 64, 4), jnp.float32,
                           True) is None

    # schema-valid entry with the WRONG arity (hand-edited / stale schema)
    # must be rejected by the arity check, not crash the caller's unpack
    autotune.record("accum_apply", (4096, 8192, 64, 4), jnp.float32, True,
                    (8, 8, 8))
    assert autotune.lookup("accum_apply", (4096, 8192, 64, 4), jnp.float32,
                           True, arity=2) is None
    assert autotune_blocks(4096, 8192, 64, 4, jnp.float32, interpret=True) == (256, 64)

    # heuristic fallback for unknown shapes stays sane
    bm, bd = autotune_blocks(1000, 5000, 48, 3, jnp.float32, interpret=True)
    assert bm >= 8 and 1 <= bd <= 48


def test_autotune_never_measures_under_trace(tmp_path, monkeypatch):
    """Tracers cannot be timed: a jitted caller must fall back to the table
    even with measuring enabled, leaving the cache untouched."""
    cache = tmp_path / "autotune.json"
    monkeypatch.setenv(autotune.ENV_CACHE, str(cache))
    monkeypatch.setenv(autotune.ENV_GATE, "1")

    n, d, m = 96, 8, 2
    K = jax.random.normal(KEY, (n, n))
    sk = make_accum_sketch(KEY, n, d, m)
    jitted = jax.jit(lambda K: sketch_right_kernel(K, sk))
    _ = jitted(K)
    assert not cache.exists()


def test_autotune_record_lookup_round_trip(tmp_path, monkeypatch):
    monkeypatch.setenv(autotune.ENV_CACHE, str(tmp_path / "a.json"))
    autotune.record("sketch_both", (512, 16, 4), jnp.float32, True, (128, 512))
    assert autotune.lookup("sketch_both", (512, 16, 4), jnp.float32, True) == (128, 512)
    # a fresh in-memory state re-reads the file
    autotune._MEM.clear()
    assert autotune.lookup("sketch_both", (512, 16, 4), jnp.float32, True) == (128, 512)
    # and the fused-kernel table consults it
    from repro.kernels.accum_apply.ops import autotune_both_blocks
    assert autotune_both_blocks(512, True, 16, 4) == (128, 512)
