"""Trace-contract analyzer tests: every detector is proven on a planted bug.

A static-analysis gate that never fires is indistinguishable from one that is
broken — each test here pairs the clean case with a positive control:

  * peak-bytes: a quadratic outer product trips the detector, the streamed
    form does not;
  * RNG lineage: the PR 8 bug shape (two independent draws off the same
    `fold_in(key, pos)`) is flagged; the tagged two-stream form is clean;
  * donation: a jit WITHOUT `donate_argnums` fails `verify_donation`, the
    donated twin passes;
  * host sync: a `pure_callback` in the trace is caught by the forbidden-
    primitive check;
  * contracts: the manifest round-trips through `--update` (check → update →
    check clean) and a planted budget violation fails.
"""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import contracts as C
from repro.analysis import rng as R
from repro.analysis import streams as S
from repro.analysis import trace as T
from repro.analysis.hardware import TPU_V5E, HardwareModel

KEY = jax.random.PRNGKey(0)


# --------------------------------------------------------------------------- #
# trace: peak bytes / census / dispatch counts / loops
# --------------------------------------------------------------------------- #

def test_peak_bytes_trips_on_quadratic_buffer():
    """Positive control: an n×n outer product is seen at full size; the
    streamed row-sum of the same quantity stays O(n)."""
    n = 512
    x = jnp.ones((n,), jnp.float32)

    quad = T.trace_report(lambda x: (x[:, None] * x[None, :]).sum(), x)
    assert quad.peak_bytes == n * n * 4
    assert quad.peak_shape == (n, n)

    def streamed(x):
        def body(acc, xi):
            return acc + (xi * x).sum(), None
        acc, _ = jax.lax.scan(body, 0.0, x)
        return acc

    lean = T.trace_report(streamed, x)
    assert lean.peak_bytes <= n * 4


def test_scan_trip_count_multiplies_flops_and_dispatch():
    """FLOPs and pallas dispatches inside a scan are charged ×length; the
    static call count is not."""
    L, d = 7, 16
    A_ = jnp.ones((d, d))

    def stepper(x):
        def body(c, _):
            return c @ A_, None
        out, _ = jax.lax.scan(body, x, None, length=L)
        return out

    rep = T.trace_report(stepper, jnp.ones((d, d)))
    assert rep.flops == pytest.approx(L * 2 * d * d * d)


def test_while_trip_count_from_condition_literal():
    """`fori_loop` bounds are read off the condition's compare constant —
    the launch/analysis.py trick transplanted to jaxprs."""
    d, trips = 8, 13
    M = jnp.ones((d, d))

    def run(x):
        return jax.lax.fori_loop(0, trips, lambda i, c: c @ M, x)

    rep = T.trace_report(run, jnp.ones((d, d)))
    assert rep.flops == pytest.approx(trips * 2 * d * d * d)


def test_host_callback_detected():
    """Positive control for the forbidden-primitive check: a pure_callback
    in the trace is a host sync and must be reported."""
    def synced(x):
        return jax.pure_callback(
            lambda v: np.asarray(v) * 2, jax.ShapeDtypeStruct((4,), jnp.float32), x)

    rep = T.trace_report(synced, jnp.ones((4,)))
    assert rep.host_callbacks == ["pure_callback"]
    assert rep.forbidden(T.HOST_CALLBACK_PRIMITIVES) == ["pure_callback"]

    clean = T.trace_report(lambda x: x * 2, jnp.ones((4,)))
    assert clean.host_callbacks == []


def test_donation_verification_catches_dropped_donation():
    """Positive control: the same function jitted WITHOUT donate_argnums
    lowers with no aliasing attr — `verify_donation` must say so."""
    x = jnp.ones((32, 32))

    donated = jax.jit(lambda x: x + 1, donate_argnums=(0,)).lower(x)
    dropped = jax.jit(lambda x: x + 1).lower(x)
    assert T.verify_donation(donated)
    assert not T.verify_donation(dropped)


def test_dtype_census_and_compat_helpers():
    """Census sees produced buffers by dtype; compat helpers mirror the
    hand-rolled test walkers they replaced."""
    def f(x):
        y = x.astype(jnp.bfloat16)
        return (y @ y).astype(jnp.float32)

    closed = jax.make_jaxpr(f)(jnp.ones((8, 8)))
    rep = T.report_from_jaxpr(closed)
    assert "bfloat16" in rep.dtype_census
    assert T.max_intermediate_elems(closed) == 64
    assert T.count_pallas_calls(closed) == 0
    assert (8, 8) in T.all_shapes(closed)


# --------------------------------------------------------------------------- #
# rng lineage
# --------------------------------------------------------------------------- #

def test_rng_checker_flags_pr8_shared_stream():
    """THE bug class: slot draws and sampling both keyed off
    fold_in(key, pos) — two independent primitives, one stream."""
    def pr8(key, pos):
        k = jax.random.fold_in(key, pos)
        slots = jax.random.randint(k, (4,), 0, 16)
        u = jax.random.uniform(k, (4,))
        return slots, u

    rep = R.rng_report(pr8, KEY, jnp.int32(3))
    assert not rep.ok
    assert any(i.kind == "reused-key" for i in rep.issues)


def test_rng_checker_accepts_tagged_streams():
    """The PR 8 fix shape: per-consumer tags make the streams disjoint."""
    def fixed(key, pos):
        ks = jax.random.fold_in(jax.random.fold_in(key, S.SLOT_STREAM), pos)
        ku = jax.random.fold_in(jax.random.fold_in(key, S.SAMPLE_STREAM), pos)
        return jax.random.randint(ks, (4,), 0, 16), jax.random.uniform(ku, (4,))

    assert R.rng_report(fixed, KEY, jnp.int32(3)).ok


def test_rng_checker_flags_loop_invariant_key():
    """A key consumed unchanged inside a scan draws the SAME bits every
    iteration; the per-step fold_in form is legitimate."""
    def bad(key):
        def body(c, _):
            return c + jax.random.uniform(key, (2,)).sum(), None
        return jax.lax.scan(body, 0.0, None, length=5)[0]

    rep = R.rng_report(bad, KEY)
    assert any(i.kind == "loop-reuse" for i in rep.issues)

    def good(key):
        def body(c, i):
            return c + jax.random.uniform(
                jax.random.fold_in(key, i), (2,)).sum(), None
        return jax.lax.scan(body, 0.0, jnp.arange(5))[0]

    assert R.rng_report(good, KEY).ok


def test_rng_checker_accepts_split():
    """jax.random.split children are distinct streams by construction."""
    def split_draws(key):
        k1, k2 = jax.random.split(key)
        return jax.random.uniform(k1, (2,)), jax.random.normal(k2, (2,))

    assert R.rng_report(split_draws, KEY).ok


def test_fold_in_sweep_is_clean_and_detects_unregistered(tmp_path):
    """The real tree must sweep clean; a synthetic file with an untagged
    fold_in is the positive control."""
    assert R.check_fold_in_sites() == []

    bad = tmp_path / "mod.py"
    bad.write_text(
        "import jax\n"
        "def f(key, step):\n"
        "    return jax.random.fold_in(key, step)\n"
    )
    sites = R.sweep_fold_in_sites(tmp_path)
    assert len(sites) == 1 and not sites[0].ok

    marked = tmp_path / "ok.py"
    marked.write_text(
        "import jax\n"
        "def f(key, step):\n"
        "    # rng-stream: kmeanspp-iter\n"
        "    return jax.random.fold_in(key, step)\n"
    )
    assert all(s.ok for s in R.sweep_fold_in_sites(tmp_path)
               if str(s.path).endswith("ok.py"))


def test_stream_registry_pins_tag_values():
    """Tag values are the seed contract — changing one is a seed break."""
    assert S.SLOT_STREAM == 0x510C
    assert S.SAMPLE_STREAM == 0x5A3E
    assert S.HOLDOUT_STREAM == 0x5E1D
    assert S.REFINE_STREAM == 0x11E7
    assert S.stream_for_tag(0x510C).name == "serve-slots"
    for name in ("slot-position", "sample-position", "kmeanspp-iter",
                 "data-step-host", "compress-step-leaf", "init-block"):
        assert name in S.REGISTRY


# --------------------------------------------------------------------------- #
# contracts: manifest io + round trip
# --------------------------------------------------------------------------- #

def test_budget_expr_eval_and_rejects_unknown_names():
    got = C.eval_budget("4*n*(p + m*d) + 1*MiB",
                        {"n": 10, "p": 2, "m": 3, "d": 4})
    assert got == 4 * 10 * (2 + 3 * 4) + 1024 * 1024
    with pytest.raises(ValueError):
        C.eval_budget("__import__('os')", {})
    with pytest.raises(ValueError):
        C.eval_budget("n + q", {"n": 1})


def test_manifest_round_trip(tmp_path):
    """dump → load is the identity for the manifest subset of TOML."""
    manifest = {
        "thing": {"budget": "4*n*n + 1*MiB", "pallas_calls": 1,
                  "donation": True, "probe_n": 256, "probe_d": 8,
                  "measured_peak_bytes": 262144},
    }
    path = tmp_path / "contracts.toml"
    C.dump_manifest(manifest, path)
    assert C.load_manifest(path) == manifest
    # the flat fallback parser agrees with tomllib
    assert C._parse_toml_flat(path.read_text()) == manifest


def test_contract_check_update_round_trip(tmp_path):
    """check → --update ratchet → check clean; a planted too-small budget
    fails; --update never ratchets UP."""
    path = tmp_path / "contracts.toml"
    C.dump_manifest({
        "sketch_both": {"budget": "4*n*n + 1*MiB", "pallas_calls": 1,
                        "probe_n": 64, "probe_d": 8, "probe_m": 2},
    }, path)

    results, _, manifest = C.run_check(path=path, update=True, only="sketch_both")
    assert results[0].status == "pass"
    measured = manifest["sketch_both"]["measured_peak_bytes"]
    assert measured == 64 * 64 * 4

    # clean re-check against the written ratchet
    results, _, _ = C.run_check(path=path, only="sketch_both")
    assert results[0].status == "pass"

    # planted violation: ratchet below reality must fail loudly
    tight = C.load_manifest(path)
    tight["sketch_both"]["measured_peak_bytes"] = measured // 2
    results, _, after = C.run_check(manifest=tight, path=path,
                                    only="sketch_both", update=True)
    assert results[0].status == "fail"
    assert any("ratchet" in v for v in results[0].violations)
    # --update kept the (tighter) manifest value: ratchets never move up
    assert after["sketch_both"]["measured_peak_bytes"] == measured // 2

    # planted budget violation
    broke = C.load_manifest(path)
    broke["sketch_both"]["budget"] = "n"
    broke["sketch_both"]["measured_peak_bytes"] = measured
    results, _, _ = C.run_check(manifest=broke, path=path, only="sketch_both")
    assert results[0].status == "fail"
    assert any("exceeds budget" in v for v in results[0].violations)


def test_contract_pallas_count_violation():
    """A wrong pinned dispatch count is a contract failure."""
    entry = {"budget": "4*n*n + 1*MiB", "pallas_calls": 3,
             "probe_n": 64, "probe_d": 8, "probe_m": 2}
    res = C.evaluate_contract("sketch_both", entry)
    assert res.status == "fail"
    assert any("pallas_call count" in v for v in res.violations)


_JAX_VERSION = tuple(int(x) for x in jax.__version__.split(".")[:3])


@pytest.mark.skipif(
    _JAX_VERSION < (0, 4, 35),
    reason="budget ratchets are pinned on jax>=0.4.35 traces; the blocking "
           "trace-contracts CI job runs them on latest jax",
)
def test_full_manifest_passes_here():
    """The shipped manifest holds on this machine (sharded contracts skip
    below 8 devices — the CI leg covers them)."""
    results, sweep, _ = C.run_check()
    assert sweep == []
    bad = [r for r in results if r.status == "fail"]
    assert not bad, [(r.name, r.violations) for r in bad]


def test_contract_result_json_ready(tmp_path):
    res = C.evaluate_contract(
        "sketch_both",
        {"budget": "4*n*n + 1*MiB", "probe_n": 64, "probe_d": 8, "probe_m": 2})
    blob = json.dumps(res.to_dict())
    assert "sketch_both" in blob


# --------------------------------------------------------------------------- #
# hardware model ride-along
# --------------------------------------------------------------------------- #

def test_roofline_uses_overridable_hardware():
    from repro.launch.analysis import HBM_BW, ICI_BW, PEAK_FLOPS, Roofline

    assert (PEAK_FLOPS, HBM_BW, ICI_BW) == (
        TPU_V5E.peak_flops, TPU_V5E.hbm_bw, TPU_V5E.ici_bw)

    r = Roofline(flops=1e12, hbm_bytes=1e9, coll_bytes=0.0, coll_detail={},
                 peak_mem_bytes=0.0)
    assert r.t_compute == pytest.approx(1e12 / TPU_V5E.peak_flops)

    slow = HardwareModel(name="half-speed", peak_flops=TPU_V5E.peak_flops / 2,
                         hbm_bw=TPU_V5E.hbm_bw, ici_bw=TPU_V5E.ici_bw)
    r2 = Roofline(flops=1e12, hbm_bytes=1e9, coll_bytes=0.0, coll_detail={},
                  peak_mem_bytes=0.0, hardware=slow)
    assert r2.t_compute == pytest.approx(2 * r.t_compute)
    assert r2.to_dict()["hardware"] == "half-speed"
