"""Dense ≡ sharded equivalence for the multi-device data-parallel layer.

Two tiers:

  * single-device-mesh tests (always run): the shard_map plumbing — padding,
    masked gathers, psum reductions — must be exact on a trivial mesh;
  * 8-device tests (CI leg with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; skipped when the
    devices are absent so the plain tier-1 run is unaffected): (C, W), KRR
    predictions, spectral embeddings, and engine growth at tol must match the
    single-device path to ≤ 1e-5 rel, with BITWISE-identical index draws.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import apply as A
from repro.core import distributed as D
from repro.core.kernel_op import KernelOperator
from repro.core.krr import (
    krr_sketched_fit,
    krr_sketched_fit_adaptive,
    krr_sketched_fit_matfree,
    krr_sketched_fit_pcg,
)
from repro.core.sketch import make_accum_sketch
from repro.core.spectral import sketched_spectral_embedding, spectral_cluster

KEY = jax.random.PRNGKey(0)

needs_8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(the distributed CI leg sets it)")


def _data(n=320, p=3):
    X = jax.random.uniform(KEY, (n, p))
    y = (jnp.sin(3.0 * X[:, 0]) + X[:, 1] ** 2
         + 0.2 * jax.random.normal(jax.random.fold_in(KEY, 1), (n,)))
    return X, y


def _rel(a, b):
    return float(jnp.linalg.norm(a - b) / jnp.maximum(jnp.linalg.norm(b), 1e-30))


def _mesh(num):
    return D.make_data_mesh(num)


# --------------------------------------------------------------------------- #
# reduction primitives (any device count — exercised on a 1-device mesh too)
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("num", [1])
def test_primitives_single_device_mesh(num):
    mesh = _mesh(num)
    M = jax.random.normal(KEY, (300, 7))          # 300 pads to any mesh
    idx = jax.random.randint(jax.random.fold_in(KEY, 2), (13,), 0, 300)
    np.testing.assert_allclose(np.asarray(D.sharded_take_rows(M, idx, mesh)),
                               np.asarray(jnp.take(M, idx, axis=0)),
                               rtol=1e-6, atol=1e-6)
    B = jax.random.normal(jax.random.fold_in(KEY, 3), (300, 5))
    np.testing.assert_allclose(np.asarray(D.sharded_gram(M, B, mesh)),
                               np.asarray(M.T @ B), rtol=1e-5, atol=1e-5)


def test_sharded_paths_on_single_device_mesh():
    """The whole pipeline on a 1-device mesh — plumbing-only equivalence that
    runs in every environment (no forced device count needed)."""
    mesh = _mesh(1)
    n, d, m = 300, 16, 4
    X, y = _data(n)
    op = KernelOperator(X, "gaussian", bandwidth=0.6)
    sk = make_accum_sketch(KEY, n, d, m)
    C0, W0 = A.sketch_both(op, sk, use_kernel=False)
    C1, W1 = A.sketch_both(op, sk, mesh=mesh)
    assert _rel(C1, C0) < 1e-6 and _rel(W1, W0) < 1e-6
    f0 = krr_sketched_fit(op, y, 5e-2, sk, use_kernel=False)
    f1 = krr_sketched_fit(op, y, 5e-2, sk, mesh=mesh)
    assert _rel(f1.fitted, f0.fitted) < 1e-5


def test_resolve_mesh_forms():
    assert D.resolve_mesh(True).shape[D.DATA_AXIS] == jax.device_count()
    assert D.resolve_mesh(1).shape[D.DATA_AXIS] == 1
    with pytest.raises(TypeError):
        D.resolve_mesh("data")
    with pytest.raises(ValueError):
        D.resolve_mesh(jax.device_count() + 1)
    # bool is an int subclass: False/0 must fail LOUDLY, not build an empty
    # mesh and die with a division error deep in the padding
    with pytest.raises(ValueError, match="mesh=None"):
        D.resolve_mesh(False)
    with pytest.raises(ValueError, match="≥ 1"):
        D.resolve_mesh(0)


def test_mesh_requires_operator():
    K = jnp.eye(32)
    sk = make_accum_sketch(KEY, 32, 4, 2)
    with pytest.raises(ValueError, match="KernelOperator"):
        A.sketch_both(K, sk, mesh=_mesh(1))


# --------------------------------------------------------------------------- #
# the acceptance tier: 8-device host-platform mesh
# --------------------------------------------------------------------------- #

@needs_8
@pytest.mark.parametrize("n", [320, 300])     # divisible and padded rows
def test_sharded_sketch_both_matches_single_device(n):
    mesh = _mesh(8)
    d, m = 16, 4
    X, _ = _data(n)
    op = KernelOperator(X, "gaussian", bandwidth=0.6)
    sk = make_accum_sketch(KEY, n, d, m)
    C0, W0 = A.sketch_both(op, sk, use_kernel=False)
    C1, W1 = A.sketch_both(op, sk, mesh=mesh)
    assert _rel(C1, C0) < 1e-5
    assert _rel(W1, W0) < 1e-5
    if n % 8 == 0:
        # per-device peak: each shard holds exactly n/8 rows of C
        shapes = {s.data.shape for s in C1.addressable_shards}
        assert shapes == {(n // 8, d)}


@needs_8
def test_sharded_pallas_backend_matches():
    """use_kernel=True routes the per-device tiles through the fused Pallas
    kernel-eval→GEMM kernel (interpret mode on CPU) inside shard_map."""
    mesh = _mesh(8)
    n, d, m = 320, 16, 4
    X, _ = _data(n)
    op = KernelOperator(X, "gaussian", bandwidth=0.6)
    sk = make_accum_sketch(KEY, n, d, m)
    C0, W0 = A.sketch_both(op, sk, use_kernel=False)
    C1, W1 = A.sketch_both(op, sk, mesh=mesh, use_kernel=True)
    assert _rel(C1, C0) < 1e-5 and _rel(W1, W0) < 1e-5


@needs_8
def test_sharded_krr_predictions_match(krr_lam=5e-2):
    mesh = _mesh(8)
    n, d, m = 320, 16, 4
    X, y = _data(n)
    op = KernelOperator(X, "gaussian", bandwidth=0.6)
    sk = make_accum_sketch(KEY, n, d, m)
    f0 = krr_sketched_fit(op, y, krr_lam, sk, use_kernel=False)
    f1 = krr_sketched_fit(op, y, krr_lam, sk, mesh=mesh)
    assert _rel(f1.fitted, f0.fitted) < 1e-5
    Xt = X[:48] + 0.01
    assert _rel(f1.predict(Xt), f0.predict(Xt)) < 1e-5
    # sharded predict (test rows sharded too)
    assert _rel(f1.predict(Xt, mesh=mesh), f0.predict(Xt)) < 1e-5
    # matfree + PCG variants
    fm = krr_sketched_fit_matfree(op, y, krr_lam, sk, mesh=mesh)
    assert _rel(fm.fitted, f0.fitted) < 1e-5
    p0 = krr_sketched_fit_pcg(op, y, krr_lam, sk, iters=40, use_kernel=False)
    p1 = krr_sketched_fit_pcg(op, y, krr_lam, sk, iters=40, mesh=mesh)
    assert _rel(p1.fitted, p0.fitted) < 1e-5


@needs_8
def test_sharded_spectral_embedding_matches():
    mesh = _mesh(8)
    k1, k2 = jax.random.split(KEY)
    Xa = 0.25 * jax.random.normal(k1, (80, 2))
    Xb = 0.25 * jax.random.normal(k2, (80, 2)) + jnp.asarray([3.0, 0.0])
    X = jnp.concatenate([Xa, Xb])
    op = KernelOperator(X, "gaussian", bandwidth=0.8)
    sk = make_accum_sketch(KEY, 160, 24, 4)
    C0, W0 = A.sketch_both(op, sk, use_kernel=False)
    C1, W1 = A.sketch_both(op, sk, mesh=mesh)
    k = 2
    ev0, U0 = sketched_spectral_embedding(C0.astype(jnp.float32),
                                          W0.astype(jnp.float32), k)
    ev1, U1 = sketched_spectral_embedding(C1.astype(jnp.float32),
                                          W1.astype(jnp.float32), k)
    np.testing.assert_allclose(np.asarray(ev1), np.asarray(ev0),
                               rtol=1e-5, atol=1e-6)
    sign = np.sign(np.sum(np.asarray(U0) * np.asarray(U1), axis=0))
    np.testing.assert_allclose(np.asarray(U1) * sign, np.asarray(U0),
                               rtol=1e-5, atol=1e-5)
    # end-to-end pipeline: identical labels (up to the label-swap symmetry)
    r0 = spectral_cluster(KEY, op, 2, d=24, m=4, use_kernel=False)
    r1 = spectral_cluster(KEY, op, 2, d=24, m=4, mesh=mesh)
    l0, l1 = np.asarray(r0.labels), np.asarray(r1.labels)
    assert max(np.mean(l0 == l1), np.mean(l0 == 1 - l1)) == 1.0


@needs_8
def test_sharded_engine_growth_matches_and_draws_identical():
    """Engine growth at tol: the sharded engine must stop at the same m with
    BITWISE identical pre-drawn indices/signs and the same holdout draw."""
    mesh = _mesh(8)
    n, d, m_max = 300, 16, 8
    X, _ = _data(n)
    op = KernelOperator(X, "gaussian", bandwidth=0.5)
    sk0, C0, W0, info0 = A.grow_sketch_both(KEY, op, d, m_max=m_max, tol=0.1,
                                            use_kernel=False)
    sk1, C1, W1, info1 = A.grow_sketch_both(KEY, op, d, m_max=m_max, tol=0.1,
                                            mesh=mesh)
    assert int(info0["m"]) == int(info1["m"])
    assert bool(jnp.all(sk0.indices == sk1.indices))       # bitwise draws
    assert bool(jnp.all(sk0.signs == sk1.signs))
    np.testing.assert_allclose(float(info1["err"]), float(info0["err"]),
                               rtol=1e-4, atol=1e-6)
    assert _rel(C1, C0) < 1e-5 and _rel(W1, W0) < 1e-5


@needs_8
def test_sharded_unconditional_grow_matches():
    mesh = _mesh(8)
    n, d, steps = 320, 16, 5
    X, _ = _data(n)
    op = KernelOperator(X, "gaussian", bandwidth=0.6)
    st0 = A.accum_grow(op, A.accum_init(KEY, n, d, steps), steps,
                       use_kernel=False)
    st1 = A.accum_grow(op, A.accum_init(KEY, n, d, steps), steps, mesh=mesh)
    assert bool(jnp.all(st0.indices == st1.indices))
    assert _rel(st1.C, st0.C) < 1e-5 and _rel(st1.W, st0.W) < 1e-5


@needs_8
def test_sharded_estimators_match_single_device():
    mesh = _mesh(8)
    n, d = 300, 12
    X, _ = _data(n)
    op = KernelOperator(X, "gaussian", bandwidth=0.6)
    st = A.accum_grow(op, A.accum_init(KEY, n, d, 4), 4, use_kernel=False)
    h0 = A.make_holdout_estimator(KEY, op)(st)
    h1 = A.make_holdout_estimator(KEY, op, mesh=mesh)(st)
    np.testing.assert_allclose(float(h1), float(h0), rtol=1e-4, atol=1e-6)
    e0 = A.make_hutchinson_estimator(KEY, op, 4)(st)
    e1 = A.make_hutchinson_estimator(KEY, op, 4, mesh=mesh)(st)
    np.testing.assert_allclose(float(e1), float(e0), rtol=1e-4, atol=1e-6)


@needs_8
def test_sharded_adaptive_krr_matches():
    mesh = _mesh(8)
    n, d = 320, 16
    X, y = _data(n)
    op = KernelOperator(X, "gaussian", bandwidth=0.5)
    a0 = krr_sketched_fit_adaptive(op, y, 5e-2, KEY, d, tol=0.05, m_max=8,
                                   use_kernel=False)
    a1 = krr_sketched_fit_adaptive(op, y, 5e-2, KEY, d, tol=0.05, m_max=8,
                                   mesh=mesh)
    assert int(a0.info["m"]) == int(a1.info["m"])
    assert _rel(a1.fitted, a0.fitted) < 1e-5


@needs_8
def test_sharded_fit_is_jittable():
    """The whole sharded fit traces — shard_map composes with jit."""
    mesh = _mesh(8)
    n, d, m = 320, 16, 4
    X, y = _data(n)
    op = KernelOperator(X, "gaussian", bandwidth=0.6)
    sk = make_accum_sketch(KEY, n, d, m)
    f0 = krr_sketched_fit(op, y, 5e-2, sk, use_kernel=False)
    fitted = jax.jit(
        lambda o, yy: krr_sketched_fit(o, yy, 5e-2, sk, mesh=mesh).fitted
    )(op, y)
    assert _rel(fitted, f0.fitted) < 1e-5
