"""The §Perf optimizations must be EXACT rewrites: chunkwise mLSTM ≡ the
sequential recurrence, the sLSTM custom VJP ≡ autodiff-through-scan, and the
a2a expert-parallel MoE ≡ the local dispatch path (when nothing is dropped)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import MoECfg
from repro.models import moe as moem
from repro.models import xlstm as xm

KEY = jax.random.PRNGKey(0)


# --------------------------------------------------------------------------- #
# chunkwise mLSTM
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("L,chunk", [(8, 16), (64, 16), (96, 32)])
def test_chunkwise_mlstm_equals_sequential(L, chunk):
    cfg = reduced(get_config("xlstm-125m"))
    p = xm.init_mlstm(KEY, cfg)
    h = 0.5 * jax.random.normal(jax.random.fold_in(KEY, L),
                                (2, L, cfg.d_model), jnp.float32)
    y_seq = xm._mlstm_forward_seq(p, h, cfg)
    y_chk = xm.mlstm_forward(p, h, cfg, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_seq),
                               rtol=2e-4, atol=2e-4)


def test_chunkwise_mlstm_grads_equal_sequential():
    cfg = reduced(get_config("xlstm-125m"))
    p = xm.init_mlstm(KEY, cfg)
    h = 0.5 * jax.random.normal(KEY, (2, 32, cfg.d_model), jnp.float32)

    def loss(fn, p):
        return jnp.sum(fn(p, h, cfg) ** 2)

    g1 = jax.grad(lambda p: loss(lambda *a: xm.mlstm_forward(*a, chunk=8), p))(p)
    g2 = jax.grad(lambda p: loss(xm._mlstm_forward_seq, p))(p)
    for k in g1:
        a, b = np.asarray(g1[k], np.float32), np.asarray(g2[k], np.float32)
        scale = max(np.max(np.abs(b)), 1e-6)
        assert np.max(np.abs(a - b)) / scale < 5e-3, k


# --------------------------------------------------------------------------- #
# sLSTM custom VJP
# --------------------------------------------------------------------------- #

def test_slstm_custom_vjp_matches_autodiff():
    L, B, H, Dh = 12, 3, 2, 5
    ks = jax.random.split(KEY, 9)
    R = tuple(0.3 * jax.random.normal(ks[i], (H, Dh, Dh)) for i in range(4))
    fb = jax.random.normal(ks[4], (H * Dh,))
    xs = tuple(jax.random.normal(ks[5 + i], (L, B, H * Dh)) for i in range(4))
    w = jax.random.normal(KEY, (L, B, H, Dh))

    def loss_custom(R, fb, *xs):
        return jnp.sum(xm._slstm_scan(R, fb, *xs) * w)

    def loss_auto(R, fb, *xs):
        return jnp.sum(xm._slstm_scan_fwd_core(R, fb, *xs)[0] * w)

    np.testing.assert_allclose(float(loss_custom(R, fb, *xs)),
                               float(loss_auto(R, fb, *xs)), rtol=1e-6)
    g1 = jax.grad(loss_custom, argnums=(0, 1, 2, 3, 4, 5))(R, fb, *xs)
    g2 = jax.grad(loss_auto, argnums=(0, 1, 2, 3, 4, 5))(R, fb, *xs)
    for a, b in zip(jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g2)):
        scale = max(float(jnp.max(jnp.abs(b))), 1e-6)
        assert float(jnp.max(jnp.abs(a - b))) / scale < 1e-4


def test_slstm_forward_matches_decode_steps():
    """The scanned training forward and the per-token decode recurrence agree."""
    cfg = reduced(get_config("xlstm-125m"))
    p = xm.init_slstm(KEY, cfg)
    B, L = 2, 6
    h = 0.5 * jax.random.normal(KEY, (B, L, cfg.d_model), jnp.float32)
    y_train = xm.slstm_forward(p, h, cfg)
    st = xm.init_slstm_state(cfg, B)
    outs = []
    for t in range(L):
        y_t, st = xm.slstm_decode(p, h[:, t:t + 1], st, cfg)
        outs.append(y_t)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_train),
                               rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------- #
# a2a expert parallelism — needs >1 device, so it runs in a subprocess with
# forced host devices (the main pytest process must keep seeing 1 device)
# --------------------------------------------------------------------------- #

_A2A_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import MoECfg
from repro.models import moe as moem
mcfg = MoECfg(n_experts=8, top_k=2, d_ff_expert=16, capacity_factor=64.0,
              dense_residual=True, d_ff_dense=16)
key = jax.random.PRNGKey(0)
p = moem.init_moe(key, 12, mcfg)
h = 0.1 * jax.random.normal(key, (4, 8, 12), jnp.float32)
out_ref, _ = jax.jit(lambda p, h: moem.moe_forward(p, h, mcfg))(p, h)
mesh = jax.make_mesh((2, 4), ("data", "model"))
with mesh:
    out_sh, _ = jax.jit(lambda p, h: moem.moe_forward(p, h, mcfg))(p, h)
np.testing.assert_allclose(np.asarray(out_sh), np.asarray(out_ref),
                           rtol=1e-5, atol=1e-5)

def loss(p, h):
    o, m = moem.moe_forward(p, h, mcfg)
    return jnp.sum(o ** 2) + m.aux_loss

g1 = jax.jit(jax.grad(loss))(p, h)
with mesh:
    g2 = jax.jit(jax.grad(loss))(p, h)
for k in ("wi_gate", "wi_up", "wo"):
    a, b = np.asarray(g1[k], np.float32), np.asarray(g2[k], np.float32)
    assert np.max(np.abs(a - b)) < 1e-3, (k, np.max(np.abs(a - b)))
print("OK")
"""


def test_moe_a2a_matches_local_when_nothing_dropped():
    import pathlib
    import subprocess
    import sys

    env = dict(os.environ)
    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", _A2A_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0 and "OK" in r.stdout, r.stdout + r.stderr
