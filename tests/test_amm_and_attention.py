"""AMM extension + sketched attention (the paper's technique in the LM)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core import amm, amm_error, make_accum_sketch
from repro.core.sketched_attention import (
    accum_attention,
    decode_slots,
    exact_attention,
    init_sketch_cache,
    make_seq_sketch,
    sketch_decode_attend,
    update_sketch_cache,
)

KEY = jax.random.PRNGKey(3)


def test_amm_unbiased_and_converges():
    n, p, q = 256, 8, 6
    A = jax.random.normal(KEY, (n, p))
    B = jax.random.normal(jax.random.fold_in(KEY, 1), (n, q))
    exact = np.asarray(A.T @ B)
    # unbiasedness: average of many sketched products ≈ exact
    acc = np.zeros_like(exact)
    reps = 200
    for r in range(reps):
        sk = make_accum_sketch(jax.random.fold_in(KEY, 10 + r), n, 64, 2)
        acc += np.asarray(amm(A, B, sk))
    rel = np.linalg.norm(acc / reps - exact) / np.linalg.norm(exact)
    assert rel < 0.2, rel   # MC noise ~ O(1/√reps)
    # error decreases with d
    e_small = np.mean([float(amm_error(A, B, make_accum_sketch(jax.random.fold_in(KEY, 500 + r), n, 16, 2))) for r in range(10)])
    e_big = np.mean([float(amm_error(A, B, make_accum_sketch(jax.random.fold_in(KEY, 900 + r), n, 128, 2))) for r in range(10)])
    assert e_big < e_small


def test_accum_attention_error_decreases_with_m():
    B, H, S, Dh = 2, 2, 128, 32
    ks = jax.random.split(KEY, 3)
    # correlated keys → landmark attention meaningful
    base = jax.random.normal(ks[0], (B, H, 8, Dh))
    k = jnp.repeat(base, S // 8, axis=2) + 0.1 * jax.random.normal(ks[1], (B, H, S, Dh))
    q = k + 0.1 * jax.random.normal(ks[2], (B, H, S, Dh))
    v = jax.random.normal(ks[1], (B, H, S, Dh))
    ex = exact_attention(q, k, v)
    errs = {}
    for m in [1, 8]:
        es = []
        for r in range(4):
            sk = make_seq_sketch(jax.random.fold_in(KEY, 100 * m + r), S, 32, m)
            es.append(float(jnp.mean((accum_attention(q, k, v, sk) - ex) ** 2)))
        errs[m] = np.mean(es)
    assert errs[8] < errs[1], errs


def test_sketch_cache_exact_when_slots_exceed_tokens():
    """Singleton slots ⇒ the compressed decode equals exact attention."""
    B, Hkv, Dh, T = 2, 2, 16, 6
    d_slots = 32
    cache = init_sketch_cache(B, Hkv, d_slots, Dh)
    ks = jax.random.split(KEY, T)
    keys, vals = [], []
    for t in range(T):
        k_t = jax.random.normal(ks[t], (B, Hkv, Dh))
        v_t = jax.random.normal(jax.random.fold_in(ks[t], 9), (B, Hkv, Dh))
        keys.append(k_t)
        vals.append(v_t)
        cache = update_sketch_cache(cache, k_t, v_t, jnp.asarray([t]))  # singleton slots
    q = jax.random.normal(jax.random.fold_in(KEY, 77), (B, Hkv, Dh))
    out = sketch_decode_attend(q, cache)
    K = jnp.stack(keys, 2)
    V = jnp.stack(vals, 2)
    ref = exact_attention(q[:, :, None, :], K, V)[:, :, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3)


def test_sketch_cache_streaming_matches_batch_masses():
    """Slot masses after streaming T tokens ≈ T·m_r/√m_r · 1/d per slot."""
    B, Hkv, Dh, T, d_slots, m_r = 1, 1, 8, 512, 64, 2
    cache = init_sketch_cache(B, Hkv, d_slots, Dh)
    key = jax.random.PRNGKey(0)
    for t in range(T):
        k_t = jnp.ones((B, Hkv, Dh))
        cache = update_sketch_cache(
            cache, k_t, k_t, decode_slots(key, t, d_slots, m_r)
        )
    mass = np.asarray(cache.mass)[0, 0]
    expected = T * m_r / np.sqrt(m_r) / d_slots
    assert abs(mass.mean() - expected) / expected < 0.05
    assert mass.min() > 0  # every slot touched at T·m_r ≫ d_slots


@settings(max_examples=10, deadline=None)
@given(s=st.integers(16, 64), d=st.integers(4, 16), m=st.integers(1, 4),
       seed=st.integers(0, 999))
def test_accum_attention_rowstochastic(s, d, m, seed):
    """Property: sketched attention output stays in conv-hull scale of V."""
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (1, 1, s, 8))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, s, 8))
    v = jnp.ones((1, 1, s, 8))
    sk = make_seq_sketch(jax.random.fold_in(key, 2), s, d, m)
    out = accum_attention(q, k, v, sk)
    # exact attention with v=1 gives exactly 1; sketched ≈ 1
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(jnp.mean(jnp.abs(out - 1.0))) < 0.5
