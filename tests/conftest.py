import numpy as np
import pytest

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single CPU device; only launch/dryrun.py forces 512 placeholder devices.


@pytest.fixture(autouse=True)
def _autotune_isolation(tmp_path, monkeypatch):
    """Point the measured autotune cache at a per-test throwaway file: tests
    asserting static-table block sizes must not read (or write) the user's
    persisted ~/.cache/repro/autotune.json.  Tests that exercise the cache
    explicitly monkeypatch their own path on top of this."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "autotune.json"))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
