"""Substrate tests: optimizer, data pipeline determinism, checkpoint
fault-tolerance, gradient compression, training-loop resume, serving engine."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import AsyncCheckpointer, latest_step, restore, retain, save
from repro.configs import ARCHS, reduced
from repro.data.pipeline import DataConfig, global_batch, host_batch
from repro.models import init_params
from repro.optim.adamw import AdamWConfig, adamw_update, global_norm, init_adamw, schedule
from repro.optim.compress import CompressConfig, compress_grads, init_error_feedback
from repro.serve.engine import Engine, ServeConfig
from repro.train.loop import LoopConfig, run
from repro.train.step import TrainConfig, init_train_state

KEY = jax.random.PRNGKey(0)


# --------------------------------------------------------------------------- #
# optimizer
# --------------------------------------------------------------------------- #

def test_adamw_reduces_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0], jnp.float32)}
    state = init_adamw(params)
    cfg = AdamWConfig(lr_peak=0.1, warmup_steps=0, total_steps=200, weight_decay=0.0)
    p = params
    for _ in range(150):
        g = {"w": 2 * p["w"]}
        p, state, _ = adamw_update(g, state, cfg, param_dtype=jnp.float32)
    assert float(jnp.abs(p["w"]).max()) < 0.15


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr_peak=1e-3, lr_min=1e-5, warmup_steps=10, total_steps=100)
    assert float(schedule(cfg, jnp.int32(5))) == pytest.approx(5e-4)
    assert float(schedule(cfg, jnp.int32(10))) == pytest.approx(1e-3, rel=0.1)
    assert float(schedule(cfg, jnp.int32(100))) == pytest.approx(1e-5, rel=0.1)


def test_grad_clip_bounds_update_norm():
    params = {"w": jnp.zeros((4,), jnp.float32)}
    state = init_adamw(params)
    cfg = AdamWConfig(lr_peak=1.0, warmup_steps=0, clip_norm=1.0, weight_decay=0.0)
    g = {"w": jnp.full((4,), 100.0)}
    _, state2, mets = adamw_update(g, state, cfg, param_dtype=jnp.float32)
    assert float(mets["grad_norm"]) == pytest.approx(200.0)
    # post-clip effective grad norm 1 → m update bounded
    assert float(global_norm(state2.m)) < 0.2


# --------------------------------------------------------------------------- #
# data pipeline
# --------------------------------------------------------------------------- #

def test_data_deterministic_and_restart_safe():
    dc = DataConfig(vocab_size=100, seq_len=16, global_batch=8)
    a1, b1 = global_batch(dc, step=7)
    a2, b2 = global_batch(dc, step=7)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(b1, b2)
    a3, _ = global_batch(dc, step=8)
    assert not np.array_equal(a1, a3)
    # labels are next-token shifted
    full = np.concatenate([a1[:, :1], b1], axis=1)
    np.testing.assert_array_equal(full[:, 1:], b1)


def test_data_host_sharding_partitions():
    parts = [host_batch(DataConfig(100, 8, 8, 0, 4, h), 3)[0] for h in range(4)]
    assert all(p.shape == (2, 8) for p in parts)
    # different hosts get different data
    assert not np.array_equal(parts[0], parts[1])


# --------------------------------------------------------------------------- #
# checkpointing / fault tolerance
# --------------------------------------------------------------------------- #

def test_checkpoint_roundtrip_bf16():
    tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
            "b": {"c": jnp.float32(1.5)}}
    with tempfile.TemporaryDirectory() as td:
        save(td, tree, step=3)
        out, step = restore(td, tree)
        assert step == 3
        np.testing.assert_array_equal(np.asarray(out["a"], np.float32),
                                      np.asarray(tree["a"], np.float32))
        assert out["a"].dtype == jnp.bfloat16


def test_checkpoint_ignores_torn_writes():
    tree = {"x": jnp.ones((2,))}
    with tempfile.TemporaryDirectory() as td:
        save(td, tree, step=1)
        # simulate a preempted write: tmp dir without COMMIT marker
        os.makedirs(os.path.join(td, "step_00000002.tmp"))
        # and a committed-looking dir without marker
        os.makedirs(os.path.join(td, "step_00000003"))
        assert latest_step(td) == 1


def test_checkpoint_retention():
    tree = {"x": jnp.ones((2,))}
    with tempfile.TemporaryDirectory() as td:
        for s in range(6):
            save(td, tree, step=s)
        retain(td, keep=2)
        assert latest_step(td) == 5
        kept = [n for n in os.listdir(td) if n.startswith("step_")]
        assert len(kept) == 2


def test_async_checkpointer():
    tree = {"x": jnp.arange(4.0)}
    with tempfile.TemporaryDirectory() as td:
        ck = AsyncCheckpointer(td, keep=2)
        ck.save(tree, step=1)
        ck.wait()
        out, step = restore(td, tree)
        np.testing.assert_array_equal(out["x"], np.arange(4.0))


# --------------------------------------------------------------------------- #
# gradient compression (paper technique in the optimizer)
# --------------------------------------------------------------------------- #

def test_compression_error_feedback_preserves_signal():
    """EF guarantees: sum of applied (compressed) grads + residual = sum of
    true grads — nothing is lost, only delayed."""
    cfg = CompressConfig(ratio=0.25, m=4, min_rows=8)
    g = {"w": jax.random.normal(KEY, (64, 16))}
    ef = init_error_feedback(g, cfg)
    applied_sum = jnp.zeros((64, 16))
    true_sum = jnp.zeros((64, 16))
    for step in range(5):
        gs = {"w": jax.random.normal(jax.random.fold_in(KEY, step), (64, 16))}
        out, ef, mets = compress_grads(gs, ef, jnp.int32(step), KEY, cfg)
        applied_sum = applied_sum + out["w"]
        true_sum = true_sum + gs["w"]
        assert float(mets["compress_ratio"]) < 1.0
    resid = jax.tree_util.tree_leaves(ef)[0]
    np.testing.assert_allclose(
        np.asarray(applied_sum + resid), np.asarray(true_sum), rtol=1e-3, atol=1e-3
    )


def test_compression_skips_small_blocks():
    cfg = CompressConfig(ratio=0.25, m=2, min_rows=1000)
    g = {"small": jnp.ones((4, 4))}
    ef = init_error_feedback(g, cfg)
    out, ef2, mets = compress_grads(g, ef, jnp.int32(0), KEY, cfg)
    np.testing.assert_array_equal(out["small"], g["small"])
    assert float(mets["compress_ratio"]) == 1.0


# --------------------------------------------------------------------------- #
# end-to-end: loop + resume + serve
# --------------------------------------------------------------------------- #

def test_loop_trains_and_resumes():
    cfg = reduced(ARCHS["qwen2-vl-2b"]).scaled(frontend=None, cond_len=0)
    tc = TrainConfig(optimizer=AdamWConfig(lr_peak=5e-3, warmup_steps=2, total_steps=40))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    mk = lambda: init_train_state(init_params(KEY, cfg), tc)
    with tempfile.TemporaryDirectory() as td:
        lc = LoopConfig(total_steps=8, ckpt_dir=td, ckpt_every=4, log_every=100)
        rep = run(cfg, tc, dc, lc, init_params_fn=mk, log=lambda *a: None)
        assert rep.final_loss < rep.losses[0]
        lc2 = LoopConfig(total_steps=10, ckpt_dir=td, ckpt_every=4, log_every=100)
        rep2 = run(cfg, tc, dc, lc2, init_params_fn=mk, log=lambda *a: None)
        assert rep2.resumed_from == 8 and rep2.steps_run == 2


def test_engine_greedy_deterministic():
    cfg = reduced(ARCHS["stablelm-3b"])
    params = init_params(KEY, cfg)
    eng = Engine(cfg, params, ServeConfig(max_len=32))
    prompts = np.array([[1, 2, 3]], np.int32)
    out1, _ = eng.generate(prompts, 5)
    out2, _ = eng.generate(prompts, 5)
    np.testing.assert_array_equal(out1, out2)
    assert out1.shape == (1, 5)
