"""Per-architecture smoke tests (spec deliverable f): a REDUCED config of the
same family runs one forward/train step on CPU, asserting output shapes and
no NaNs; plus decode-vs-forward consistency per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduced
from repro.models import decode_step, forward, init_cache, init_params, output_embedding
from repro.models.model import loss_fn

KEY = jax.random.PRNGKey(0)
ARCH_IDS = list(ARCHS)


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = reduced(ARCHS[name])
            cache[name] = (cfg, init_params(KEY, cfg))
        return cache[name]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_shapes_and_finite(arch, built):
    cfg, params = built(arch)
    B, S = 2, 32
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.fold_in(KEY, 1), (B, S), 0, cfg.vocab_size)
    cond = (
        jax.random.normal(KEY, (B, cfg.cond_len, cfg.d_model), jnp.bfloat16)
        if cfg.frontend else None
    )
    (loss, mets), grads = jax.value_and_grad(
        lambda p: loss_fn(p, toks, labels, cfg, cond=cond), has_aux=True
    )(params)
    assert bool(jnp.isfinite(loss)), arch
    assert 3.0 < float(loss) < 12.0    # ~log(vocab) at init
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(x.astype(jnp.float32)))) for x in leaves)
    h, _ = forward(params, toks, cfg, cond=cond, remat="none")
    S_tot = S + (cfg.cond_len if cfg.frontend else 0)
    assert h.shape == (B, S_tot, cfg.d_model)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch, built):
    cfg, params = built(arch)
    B, S = 2, 16
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    h, _ = forward(params, toks, cfg, remat="none")
    emb = output_embedding(params)
    ref = h.astype(jnp.float32) @ emb.T.astype(jnp.float32)
    cache = init_cache(cfg, B, S)
    worst = 0.0
    for t in range(S):
        lg, cache = decode_step(params, cache, toks[:, t], jnp.int32(t), cfg)
        worst = max(worst, float(jnp.max(jnp.abs(lg - ref[:, t]))))
    # attention archs are exact; SSM/recurrent differ by chunked-vs-recurrent
    # bf16 accumulation order
    tol = 0.05 if any(k in ("mamba2", "mlstm", "slstm") for k in cfg.pattern) else 1e-3
    assert worst < tol, (arch, worst)


@pytest.mark.parametrize("arch", ["qwen1.5-110b", "zamba2-7b", "gemma3-12b"])
def test_sketched_decode_runs(arch, built):
    """The paper-technique cache path (long-context serving) stays finite."""
    cfg, params = built(arch)
    B = 2
    cache = init_cache(cfg, B, 64, use_sketch=True)
    tok = jnp.zeros((B,), jnp.int32)
    slots = jnp.asarray([0, 1], jnp.int32)
    for t in range(4):
        lg, cache = decode_step(
            params, cache, tok, jnp.int32(t), cfg, slots=slots, use_sketch=True
        )
        assert bool(jnp.all(jnp.isfinite(lg)))


def test_full_configs_match_spec():
    """The production configs carry the exact assigned hyperparameters."""
    spec = {
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
    }
    for name, (L, D, H, KV, FF, V) in spec.items():
        c = get_config(name)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab_size) == (
            L, D, H, KV, FF, V
        ), name
    assert get_config("moonshot-v1-16b-a3b").moe.n_experts == 64
    assert get_config("moonshot-v1-16b-a3b").moe.top_k == 6
    assert get_config("arctic-480b").moe.n_experts == 128
    assert get_config("arctic-480b").moe.top_k == 2
    assert get_config("arctic-480b").moe.dense_residual
    assert get_config("zamba2-7b").ssm.d_state == 64
    assert get_config("qwen1.5-110b").qkv_bias and get_config("qwen2-vl-2b").qkv_bias


def test_moe_capacity_drops_reported():
    from repro.models.moe import init_moe, moe_forward
    from repro.configs.base import MoECfg

    moe = MoECfg(n_experts=4, top_k=2, d_ff_expert=16, capacity_factor=1.0)
    p = init_moe(KEY, 32, moe)
    x = jax.random.normal(KEY, (2, 16, 32), jnp.bfloat16)
    out, mets = moe_forward(p, x, moe)
    assert out.shape == x.shape
    assert 0.0 <= float(mets.dropped_fraction) < 1.0
    assert float(mets.aux_loss) > 0.5       # ≈1 for balanced routing
