"""Resilience-layer tests: fault plans, checkpoint crash recovery, every
degradation-ladder rung, and the kill-and-resume bitwise pin.

Each ladder rung is exercised by ARMING A FAULT PLAN through the real entry
points (kernels/*/ops.py, ckpt.py, Engine.generate) — not by unit-mocking the
rung — so the recovery paths tested here are the ones production hits.

The module is chaos-tolerant: CI's chaos job re-runs this whole file under
three canned ambient ``REPRO_FAULT_PLAN``s (tests/fault_plans/*.json). The
deterministic tests clear the ambient plan via the autouse fixture below and
arm their own; ``TestAmbientChaos`` restores the ambient plan and asserts the
invariants that must hold under ANY plan (finite results or a clean
DeviceLost — never wrong numerics, never a corrupt latest checkpoint).
"""
import json
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.checkpoint import ckpt
from repro.configs import ARCHS, reduced
from repro.core.kernel_op import KernelOperator
from repro.core.krr import krr_sketched_fit
from repro.core.sketch import make_accum_sketch
from repro.core import apply as A
from repro.kernels.accum_apply import autotune
from repro.models.model import init_params
from repro.resilience import faults
from repro.resilience.degrade import (
    HealthReport,
    global_health,
    ladder_call,
    solve_psd_ladder,
)
from repro.serve.engine import Engine, ServeConfig

KEY = jax.random.PRNGKey(0)
REPO = pathlib.Path(__file__).resolve().parents[1]
PLANS = pathlib.Path(__file__).parent / "fault_plans"

# the chaos job's ambient plan, captured before the autouse fixture clears it
AMBIENT_PLAN = os.environ.get(faults.ENV_PLAN)


@pytest.fixture(autouse=True)
def _isolate_faults(monkeypatch):
    """Each test starts with no ambient plan, fresh arrival counters, and an
    empty global health report (tests arm their own plans explicitly)."""
    monkeypatch.delenv(faults.ENV_PLAN, raising=False)
    faults.reset()
    global_health().clear()
    yield
    faults.reset()
    global_health().clear()


def _arm(monkeypatch, plan: dict) -> None:
    monkeypatch.setenv(faults.ENV_PLAN, json.dumps(plan))
    faults.reset()


# --------------------------------------------------------------------------- #
# fault plans: parsing + deterministic triggering
# --------------------------------------------------------------------------- #

class TestFaultPlans:
    def test_inline_and_file_plans_parse(self, monkeypatch, tmp_path):
        _arm(monkeypatch, {"kernel.dispatch": {"action": "error", "at": 3}})
        assert faults.active_plan() == {
            "kernel.dispatch": {"action": "error", "at": 3}
        }
        p = tmp_path / "plan.json"
        p.write_text('{"ckpt.write": {"action": "kill", "at": 1}}')
        monkeypatch.setenv(faults.ENV_PLAN, str(p))
        assert faults.active_plan() == {
            "ckpt.write": {"action": "kill", "at": 1}
        }

    @pytest.mark.parametrize("bad", [
        '{"no.such.site": {"action": "error", "at": 1}}',
        '{"ckpt.write": {"action": "explode", "at": 1}}',
        '{"ckpt.write": "error"}',
        '["ckpt.write"]',
    ])
    def test_malformed_plans_raise(self, monkeypatch, bad):
        monkeypatch.setenv(faults.ENV_PLAN, bad)
        with pytest.raises(ValueError):
            faults.active_plan()

    def test_canned_ci_plans_are_valid(self, monkeypatch):
        for name in ("kernel_dispatch", "ckpt_kill", "nan_decode"):
            monkeypatch.setenv(faults.ENV_PLAN, str(PLANS / f"{name}.json"))
            assert faults.active_plan(), name

    def test_at_and_every_triggering(self, monkeypatch):
        _arm(monkeypatch, {"kernel.dispatch": {"action": "error", "at": 2}})
        assert faults.fault_point("kernel.dispatch") is None
        with pytest.raises(faults.FaultInjected):
            faults.fault_point("kernel.dispatch")
        assert faults.fault_point("kernel.dispatch") is None  # fires once

        _arm(monkeypatch, {
            "kernel.dispatch": {"action": "error", "every": 2, "times": 1}
        })
        hits = 0
        for _ in range(6):
            try:
                faults.fault_point("kernel.dispatch")
            except faults.FaultInjected:
                hits += 1
        assert hits == 1  # every=2 capped by times=1

    def test_unregistered_site_rejected(self):
        with pytest.raises(KeyError):
            faults.fault_point("not.a.site")

    def test_poison_refuses_tracers(self, monkeypatch):
        """A jitted function must never bake an injection into its artifact:
        on tracers the arrival is not consumed and the value is unchanged."""
        _arm(monkeypatch, {"decode.step": {"action": "nan", "at": 1}})

        @jax.jit
        def f(x):
            return faults.poison("decode.step", x)

        out = f(jnp.ones((8,)))
        assert bool(jnp.all(jnp.isfinite(out)))
        # the arrival was NOT consumed under trace: the first eager arrival
        # still fires
        poisoned = faults.poison("decode.step", jnp.ones((8,)))
        assert bool(jnp.any(jnp.isnan(poisoned)))

    def test_device_lost_is_not_fault_injected(self):
        """Retry loops catch FaultInjected but must let DeviceLost fly."""
        assert not issubclass(faults.DeviceLost, faults.FaultInjected)


# --------------------------------------------------------------------------- #
# checkpoint: crash recovery (satellites 1–3)
# --------------------------------------------------------------------------- #

def _tree(step=0):
    return {
        "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4) + step,
        "b": {"m": jnp.ones((2,), jnp.bfloat16) * step, "n": jnp.int32(step)},
    }


class TestCheckpoint:
    def test_kill_leaves_prior_step_loadable(self, monkeypatch, tmp_path):
        """A write killed mid-attempt (after meta, before state) must leave
        the PRIOR committed step as latest, plus a stale tmp dir that
        sweep_stale/latest_step removes."""
        td = str(tmp_path)
        ckpt.save(td, _tree(1), step=1)
        ckpt.save(td, _tree(2), step=2)
        _arm(monkeypatch, {"ckpt.write": {"action": "kill", "at": 1}})
        with pytest.raises(faults.DeviceLost):
            ckpt.save(td, _tree(3), step=3)
        assert any(n.endswith(".tmp") for n in os.listdir(td))
        assert ckpt.latest_step(td) == 2  # sweeps the stale tmp by default
        assert not any(n.endswith(".tmp") for n in os.listdir(td))
        state, step = ckpt.restore(td, _tree())
        assert step == 2
        assert float(state["w"][0, 0]) == 2.0

    def test_corrupt_latest_falls_back_to_prior(self, monkeypatch, tmp_path):
        td = str(tmp_path)
        ckpt.save(td, _tree(1), step=1)
        _arm(monkeypatch, {"ckpt.write": {"action": "corrupt", "at": 1}})
        ckpt.save(td, _tree(2), step=2)  # commits a mangled payload
        state, step = ckpt.restore(td, _tree())
        assert step == 1
        assert float(state["w"][0, 0]) == 1.0
        assert global_health().count("ckpt.restore") == 1

    def test_truncated_latest_falls_back(self, monkeypatch, tmp_path):
        td = str(tmp_path)
        ckpt.save(td, _tree(1), step=1)
        _arm(monkeypatch, {"ckpt.write": {"action": "truncate", "at": 1}})
        ckpt.save(td, _tree(2), step=2)
        _, step = ckpt.restore(td, _tree())
        assert step == 1

    def test_transient_error_retried_with_backoff(self, monkeypatch, tmp_path):
        """An 'error' plan on the first attempt is absorbed by save()'s
        retry loop; the second attempt commits."""
        td = str(tmp_path)
        _arm(monkeypatch, {"ckpt.write": {"action": "error", "at": 1}})
        out = ckpt.save(td, _tree(5), step=5, backoff=0.001)
        assert out.endswith("step_00000005")
        assert ckpt.latest_step(td) == 5

    def test_retries_exhausted_raises(self, monkeypatch, tmp_path):
        _arm(monkeypatch, {
            "ckpt.write": {"action": "error", "at": [1, 2, 3]}
        })
        with pytest.raises(faults.FaultInjected):
            ckpt.save(str(tmp_path), _tree(), step=1, retries=3, backoff=0.001)

    def test_keep_last_retention(self, tmp_path):
        td = str(tmp_path)
        for s in range(1, 6):
            ckpt.save(td, _tree(s), step=s, keep_last=2)
        assert ckpt.committed_steps(td) == [5, 4]

    def test_sweep_stale_reports_removals(self, tmp_path):
        td = str(tmp_path)
        ckpt.save(td, _tree(1), step=1)
        (tmp_path / "step_00000009").mkdir()           # uncommitted dir
        (tmp_path / "step_00000010.tmp").mkdir()       # torn tmp
        removed = ckpt.sweep_stale(td)
        assert sorted(removed) == ["step_00000009", "step_00000010.tmp"]
        assert ckpt.committed_steps(td) == [1]

    def test_async_writer_failure_reraised(self, tmp_path):
        """Satellite 1: a writer-thread death must surface on the next
        save()/close(), never silently."""
        parent = tmp_path / "plainfile"
        parent.write_text("not a directory")
        ac = ckpt.AsyncCheckpointer(str(parent / "sub"), keep=2)
        ac.save(_tree(1), step=1)
        with pytest.raises(OSError):
            ac.close()
        # a second failure surfaces on the next save() call
        ac.save(_tree(2), step=2)
        with pytest.raises(OSError):
            ac.save(_tree(3), step=3)

    def test_async_writer_clean_path_still_works(self, tmp_path):
        ac = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
        ac.save(_tree(1), step=1)
        ac.save(_tree(2), step=2)
        ac.close()
        assert ckpt.committed_steps(str(tmp_path)) == [2, 1]


# --------------------------------------------------------------------------- #
# leaf wire-format round-trip (satellite 3)
# --------------------------------------------------------------------------- #

_DTYPES = [jnp.float32, jnp.float64, jnp.bfloat16, jnp.int8, jnp.bool_]
_SHAPES = [(), (0,), (3, 2), (1, 0, 4)]


def _roundtrip(a):
    out = ckpt._decode_leaf(ckpt._encode_leaf(a))
    assert out.shape == a.shape
    assert out.dtype == a.dtype
    np.testing.assert_array_equal(np.asarray(out), np.asarray(a))


class TestLeafRoundTrip:
    @pytest.mark.parametrize("dtype", _DTYPES, ids=str)
    @pytest.mark.parametrize("shape", _SHAPES, ids=str)
    def test_encode_decode_roundtrip(self, dtype, shape):
        if dtype == jnp.bool_:
            a = np.arange(int(np.prod(shape))).reshape(shape) % 2 == 0
        else:
            a = np.arange(int(np.prod(shape))).reshape(shape)
        _roundtrip(np.asarray(a, jnp.dtype(dtype)))

    @given(
        seed=st.integers(min_value=0, max_value=2**32 - 1),
        dtype_i=st.integers(min_value=0, max_value=len(_DTYPES) - 1),
        shape=st.lists(st.integers(min_value=0, max_value=4),
                       min_size=0, max_size=3),
    )
    @settings(max_examples=50, deadline=None)
    def test_roundtrip_property(self, seed, dtype_i, shape):
        """Property form: arbitrary bit patterns reinterpreted as each wire
        dtype must survive encode→decode bitwise across 0-d/empty shapes
        (including NaN payloads and non-canonical bools)."""
        dt = jnp.dtype(_DTYPES[dtype_i])
        n = int(np.prod(shape)) if shape else 1
        raw = np.random.default_rng(seed).integers(
            0, 256, size=n * dt.itemsize, dtype=np.uint8
        )
        a = raw.view(dt).reshape(tuple(shape))
        out = ckpt._decode_leaf(ckpt._encode_leaf(a))
        assert out.shape == a.shape
        assert out.dtype == a.dtype
        assert out.tobytes() == a.tobytes()


# --------------------------------------------------------------------------- #
# degradation ladders (tentpole c) — driven by fault plans, not mocks
# --------------------------------------------------------------------------- #

def _kernel_fixture(n=96, d=8, m=2):
    X = jax.random.uniform(jax.random.PRNGKey(1), (n, 5))
    op = KernelOperator(X, "gaussian", bandwidth=0.7)
    sk = make_accum_sketch(KEY, n, d, m)
    return op, sk


class TestLadders:
    def test_sketch_both_pallas_to_xla(self, monkeypatch):
        """kernel.dispatch error → the XLA gather rung, bitwise-equal to the
        use_kernel=False path, with the drop health-recorded."""
        op, sk = _kernel_fixture()
        K = op.dense()
        want = A.sketch_both(K, sk, use_kernel=False)
        _arm(monkeypatch, {"kernel.dispatch": {"action": "error", "at": 1}})
        got = A.sketch_both(K, sk, use_kernel=True)
        for a, b in zip(got, want):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert global_health().count("kernel.dispatch") == 1

    def test_weighted_cols_three_rungs_to_dense(self, monkeypatch):
        """Arming BOTH kernel sites drives the matfree ladder past Pallas AND
        the streaming rung, landing on the dense one-slab oracle."""
        op, sk = _kernel_fixture()
        want = op.sketch_cols(sk, use_kernel=False)
        _arm(monkeypatch, {
            "kernel.dispatch": {"action": "error", "at": 1},
            "kernel.stream": {"action": "error", "at": 1},
        })
        got = op.sketch_cols(sk, use_kernel=True)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6
        )
        assert global_health().count("kernel.dispatch") == 2  # two rung drops

    def test_terminal_rung_failure_propagates(self, monkeypatch):
        """When every rung's arrival faults, the last exception escapes the
        ladder (the caller must see a real failure, not a silent None)."""
        _arm(monkeypatch, {"kernel.dispatch": {"action": "error", "at": [1, 2]}})
        rungs = [("a", lambda: faults.fault_point("kernel.dispatch")),
                 ("b", lambda: faults.fault_point("kernel.dispatch"))]
        with pytest.raises(faults.FaultInjected):
            ladder_call("kernel.dispatch", rungs, health=HealthReport())

    def test_ladder_lets_device_lost_fly(self, monkeypatch):
        """A simulated preemption is NOT a degradation — the ladder must not
        absorb it into a slower rung."""
        _arm(monkeypatch, {"kernel.dispatch": {"action": "kill", "at": 1}})
        rungs = [("a", lambda: faults.fault_point("kernel.dispatch")),
                 ("b", lambda: 42)]
        with pytest.raises(faults.DeviceLost):
            ladder_call("kernel.dispatch", rungs, health=HealthReport())

    def test_solve_healthy_no_escalation(self):
        Am = jax.random.uniform(jax.random.PRNGKey(2), (16, 16))
        M = Am @ Am.T / 16 + jnp.eye(16)
        b = jnp.ones((16,))
        x, health = solve_psd_ladder(M, b)
        np.testing.assert_allclose(np.asarray(M @ x), np.asarray(b), atol=1e-4)
        assert int(health["solve_escalations"]) == 0
        assert not bool(health["solve_used_lstsq"])

    def test_solve_escalates_on_marginal_matrix(self, monkeypatch):
        """A barely-indefinite input (tiny negative shift past a singular
        direction) is recovered by the ×10 jitter escalation WITHOUT falling
        to lstsq: the shift 3e-7·(tr M/d) ≈ 2.8e-7 beats the base jitter
        j0 ≈ 9.4e-9 but not j0·10²."""
        _arm(monkeypatch, {
            "solve.cholesky": {"action": "indefinite", "at": 1, "scale": 3e-7}
        })
        M = jnp.diag(jnp.ones((16,)).at[0].set(0.0))
        x, health = solve_psd_ladder(M, jnp.ones((16,)))
        assert bool(jnp.all(jnp.isfinite(x)))
        assert int(health["solve_escalations"]) >= 1
        assert not bool(health["solve_used_lstsq"])

    def test_solve_lstsq_terminal_rung(self, monkeypatch):
        """A hard spectrum flip exhausts the bounded escalation and lands on
        lstsq — still finite, flagged in the health scalars."""
        _arm(monkeypatch, {
            "solve.cholesky": {"action": "indefinite", "at": 1, "scale": 2.0}
        })
        Am = jax.random.uniform(jax.random.PRNGKey(2), (16, 16))
        M = Am @ Am.T / 16 + jnp.eye(16)
        x, health = solve_psd_ladder(M, jnp.ones((16,)))
        assert bool(jnp.all(jnp.isfinite(x)))
        assert bool(health["solve_used_lstsq"])

    def test_krr_fit_survives_indefinite_fault(self, monkeypatch):
        """The fault threaded through the REAL fit entry point: the fit stays
        finite and the ladder's health scalars ride out in .info."""
        op, sk = _kernel_fixture()
        K = op.dense()
        y = jnp.sin(jnp.arange(K.shape[0], dtype=jnp.float32))
        _arm(monkeypatch, {
            "solve.cholesky": {"action": "indefinite", "at": 1, "scale": 2.0}
        })
        fit = krr_sketched_fit(K, y, 1e-2, sk, use_kernel=False)
        assert bool(jnp.all(jnp.isfinite(fit.fitted)))
        assert bool(fit.info["solve_used_lstsq"])

    def test_autotune_corrupt_cache_degrades(self, monkeypatch, tmp_path):
        """A garbage cache file must fall back to the static table (lookup
        returns None) and record the degradation — never crash the caller."""
        p = tmp_path / "autotune.json"
        p.write_text("{ this is not json")
        monkeypatch.setenv(autotune.ENV_CACHE, str(p))
        autotune._MEM.clear()
        assert autotune.lookup("sketch_both", (96, 8, 2), jnp.float32, True) is None
        assert global_health().count("autotune.load") == 1

    def test_autotune_fault_site_degrades(self, monkeypatch, tmp_path):
        p = tmp_path / "autotune.json"
        p.write_text('{"k": [1, 2]}')
        monkeypatch.setenv(autotune.ENV_CACHE, str(p))
        _arm(monkeypatch, {"autotune.load": {"action": "error", "at": 1}})
        autotune._MEM.clear()
        assert autotune.lookup("k", (), jnp.float32, True) is None
        assert global_health().count("autotune.load") == 1
        # missing file is a normal cold start — no health event
        global_health().clear()
        autotune._MEM.clear()
        monkeypatch.setenv(autotune.ENV_CACHE, str(tmp_path / "absent.json"))
        assert autotune.lookup("k", (), jnp.float32, True) is None
        assert global_health().count("autotune.load") == 0


# --------------------------------------------------------------------------- #
# engine: checkpoint/resume + health screen (tentpole b)
# --------------------------------------------------------------------------- #

@pytest.fixture(scope="module")
def built():
    cfg = reduced(ARCHS["stablelm-3b"])
    return cfg, init_params(KEY, cfg)


B, L, N_NEW = 2, 8, 6


def _engine(built, ckdir=None, ckpt_every=2):
    cfg, params = built
    sc = ServeConfig(
        max_len=L + N_NEW + 2, use_sketch=True, temperature=0.7, seed=3,
        ckpt_dir=ckdir, ckpt_every=ckpt_every,
    )
    return Engine(cfg, params, sc)


def _prompts(built):
    cfg, _ = built
    return np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (B, L), 0, cfg.vocab_size)
    )


class TestEngineResilience:
    def test_checkpointed_run_matches_plain(self, built, tmp_path):
        """Chunked decode + checkpointing must not change the tokens."""
        prompts = _prompts(built)
        ref, _ = _engine(built).generate(prompts, N_NEW)
        toks, _ = _engine(built, str(tmp_path)).generate(
            prompts, N_NEW, request_id="r"
        )
        np.testing.assert_array_equal(ref, toks)

    def test_kill_and_resume_bitwise(self, built, tmp_path, monkeypatch):
        """In-process pin: kill the 2nd decode dispatch, resume with a FRESH
        engine from the surviving checkpoint → bitwise-identical tokens."""
        prompts = _prompts(built)
        ref, _ = _engine(built).generate(prompts, N_NEW)
        _arm(monkeypatch, {"decode.step": {"action": "kill", "at": 2}})
        with pytest.raises(faults.DeviceLost):
            _engine(built, str(tmp_path)).generate(
                prompts, N_NEW, request_id="r"
            )
        monkeypatch.delenv(faults.ENV_PLAN)
        faults.reset()
        eng = _engine(built, str(tmp_path))
        toks, _ = eng.generate(prompts, N_NEW, request_id="r")
        np.testing.assert_array_equal(ref, toks)
        assert eng.health.count("ckpt.resume") == 1

    def test_kill_and_resume_bitwise_cross_process(self, built, tmp_path):
        """THE pinned guarantee: a generate() killed mid-decode and resumed in
        a NEW PROCESS produces bitwise-identical tokens (tests/resume_worker
        fixes the request; three subprocess runs: ref / kill / resume)."""
        env = {k: v for k, v in os.environ.items() if k != faults.ENV_PLAN}
        env["PYTHONPATH"] = str(REPO / "src")

        def run(mode, extra_env=None):
            return subprocess.run(
                [sys.executable, str(REPO / "tests" / "resume_worker.py"),
                 mode, str(tmp_path)],
                env={**env, **(extra_env or {})},
                capture_output=True, text=True, timeout=600,
            )

        ref = run("ref")
        assert ref.returncode == 0, ref.stderr
        kill = run("kill", {
            faults.ENV_PLAN: '{"decode.step": {"action": "kill", "at": 2}}'
        })
        assert kill.returncode == 17, (kill.stdout, kill.stderr)
        assert "KILLED" in kill.stdout
        assert ckpt.committed_steps(str(tmp_path / "req"))  # progress survived
        res = run("resume")
        assert res.returncode == 0, res.stderr
        assert json.loads(res.stdout) == json.loads(ref.stdout)

    def test_nan_poison_degrades_to_exact(self, built, monkeypatch):
        """decode.step nan → the health screen catches the poisoned sketched
        cache between chunks and rebuilds exact attention; tokens stay valid
        and the degradation is recorded — never silent."""
        prompts = _prompts(built)
        _arm(monkeypatch, {"decode.step": {"action": "nan", "at": 1}})
        eng = _engine(built)
        toks, _ = eng.generate(prompts, N_NEW)
        assert toks.shape == (B, N_NEW)
        assert np.all((toks >= 0) & (toks < built[0].vocab_size))
        assert eng.health.count("decode.cache") == 1
        ev = eng.health.events[0]
        assert (ev.rung_from, ev.rung_to) == ("sketched", "exact-rebuild")

    def test_resume_refuses_mismatched_request(self, built, tmp_path):
        """Resuming different prompts against an existing request checkpoint
        must raise — silently generating different tokens would void the
        bitwise guarantee."""
        prompts = _prompts(built)
        _engine(built, str(tmp_path)).generate(prompts, N_NEW, request_id="r")
        other = (prompts + 1) % built[0].vocab_size
        with pytest.raises(ValueError, match="refusing to resume"):
            _engine(built, str(tmp_path)).generate(
                other, N_NEW, request_id="r"
            )

    def test_stats_surface_health(self, built, monkeypatch):
        prompts = _prompts(built)
        _arm(monkeypatch, {"decode.step": {"action": "nan", "at": 1}})
        eng = _engine(built)
        eng.generate(prompts, N_NEW)
        stats = eng.stats()
        assert stats["health_events"] >= 1
        assert any("decode.cache" in k for k in stats["health"])


# --------------------------------------------------------------------------- #
# chaos job: the whole module re-runs under an ambient plan; this class
# restores it and asserts only plan-agnostic invariants
# --------------------------------------------------------------------------- #

class TestAmbientChaos:
    @pytest.mark.skipif(AMBIENT_PLAN is None, reason="no ambient fault plan")
    def test_pipeline_survives_ambient_plan(self, built, monkeypatch, tmp_path):
        """Under ANY canned plan the stack must produce finite results, a
        loadable checkpoint trail, or die with a clean DeviceLost — never
        wrong numerics, never a corrupt latest checkpoint."""
        monkeypatch.setenv(faults.ENV_PLAN, AMBIENT_PLAN)
        faults.reset()
        op, sk = _kernel_fixture()
        prompts = _prompts(built)
        try:
            C = op.sketch_cols(sk, use_kernel=True)
            assert bool(jnp.all(jnp.isfinite(C)))
            eng = _engine(built, str(tmp_path))
            toks, _ = eng.generate(prompts, N_NEW, request_id="r")
            assert np.all((toks >= 0) & (toks < built[0].vocab_size))
        except faults.DeviceLost:
            pass  # a preemption plan may kill the attempt — that IS the contract
        # whatever happened, the checkpoint directory must never hold a
        # corrupt LATEST step: either nothing was committed or it restores
        faults.reset()
        monkeypatch.delenv(faults.ENV_PLAN)
        req = tmp_path / "r"
        steps = ckpt.committed_steps(str(req))
        if steps:
            eng2 = _engine(built, str(tmp_path))
            toks, _ = eng2.generate(prompts, N_NEW, request_id="r")
            assert toks.shape == (B, N_NEW)
