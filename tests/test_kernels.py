"""Per-kernel allclose sweeps vs the ref.py oracles (shapes × dtypes),
as required for every Pallas kernel. interpret=True executes on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.trace import all_shapes
from repro.core.sketch import make_accum_sketch
from repro.core.sketched_attention import accum_attention, make_seq_sketch
from repro.kernels.accum_apply.ops import (
    MAX_COLS,
    autotune_blocks,
    default_interpret,
    sketch_both_kernel,
    sketch_left_kernel,
    sketch_right_kernel,
)
from repro.kernels.accum_apply.ref import accum_apply_ref, sketch_both_ref
from repro.kernels.landmark_attention.kernel import landmark_attention
from repro.kernels.landmark_attention.ops import (
    accum_attention_kernel,
    landmark_attend,
    landmark_stats_fused,
)
from repro.kernels.landmark_attention.ref import (
    landmark_attention_ref,
    landmark_stats_ref,
)

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "R,N,d,m", [(128, 256, 8, 1), (256, 512, 32, 4), (128, 1024, 16, 8), (256, 256, 64, 2)]
)
def test_accum_apply_sweep(R, N, d, m, dtype):
    K = jax.random.normal(KEY, (R, N), dtype)
    sk = make_accum_sketch(jax.random.fold_in(KEY, d * m), N, d, m)
    ref = accum_apply_ref(K, sk.indices, sk.coef.astype(jnp.float32))
    out = sketch_right_kernel(K, sk, bm=128, bd=min(8, d))
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


def test_accum_apply_wide_K_chunked():
    """N > MAX_COLS path: chunked partial products sum exactly."""
    K = jax.random.normal(KEY, (128, 3 * 8192 // 2), jnp.float32)
    sk = make_accum_sketch(KEY, K.shape[1], 16, 4)
    ref = accum_apply_ref(K, sk.indices, sk.coef)
    out = sketch_right_kernel(K, sk, bm=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_accum_apply_wide_K_non_multiple_chunk():
    """N neither a multiple of MAX_COLS nor of the block: scan + padding."""
    N = 2 * MAX_COLS + 777
    K = jax.random.normal(KEY, (96, N), jnp.float32)
    sk = make_accum_sketch(jax.random.fold_in(KEY, 5), N, 12, 3)
    ref = accum_apply_ref(K, sk.indices, sk.coef)
    out = sketch_right_kernel(K, sk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_accum_apply_odd_shapes_padded():
    """Shapes that do not tile (R=100, d=10): the ops wrapper pads and slices."""
    K = jax.random.normal(KEY, (100, 300), jnp.float32)
    sk = make_accum_sketch(jax.random.fold_in(KEY, 9), 300, 10, 3)
    ref = accum_apply_ref(K, sk.indices, sk.coef)
    out = sketch_right_kernel(K, sk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_wide_K_chunking_does_not_unroll():
    """Jaxpr-size regression: the lax.scan chunk loop keeps the traced program
    O(1) in the number of chunks (the seed's Python loop emitted one
    pallas_call per chunk, exploding compile time for wide K)."""

    def n_eqns(N):
        sk = make_accum_sketch(KEY, N, 16, 2)
        jaxpr = jax.make_jaxpr(lambda K: sketch_right_kernel(K, sk))(
            jnp.zeros((64, N), jnp.float32)
        )
        return len(jaxpr.jaxpr.eqns)

    assert n_eqns(2 * MAX_COLS) == n_eqns(4 * MAX_COLS)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "n,d,m", [(128, 8, 1), (256, 32, 4), (128, 16, 8), (256, 64, 2)]
)
def test_sketch_both_fused_sweep(n, d, m, dtype):
    """Fused (C, W) kernel vs the two-pass oracle across shapes × dtypes."""
    K = jax.random.normal(KEY, (n, n), dtype)
    K = (0.5 * (K.astype(jnp.float32) + K.astype(jnp.float32).T)).astype(dtype)
    sk = make_accum_sketch(jax.random.fold_in(KEY, n + d * m), n, d, m)
    C_ref, W_ref = sketch_both_ref(K, sk.indices, sk.coef.astype(jnp.float32))
    C, W = sketch_both_kernel(K, sk, bm=64, bn=128)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(C, np.float32), np.asarray(C_ref, np.float32), rtol=tol, atol=tol
    )
    np.testing.assert_allclose(
        np.asarray(W, np.float32), np.asarray(W_ref, np.float32), rtol=tol, atol=tol
    )


def test_sketch_both_fused_odd_shapes():
    """n=400, d=19 (nothing tiles): padded fused kernel stays exact."""
    n, d, m = 400, 19, 4
    K = jax.random.normal(KEY, (n, n), jnp.float32)
    sk = make_accum_sketch(jax.random.fold_in(KEY, 41), n, d, m)
    C_ref, W_ref = sketch_both_ref(K, sk.indices, sk.coef)
    C, W = sketch_both_kernel(K, sk)
    np.testing.assert_allclose(np.asarray(C), np.asarray(C_ref), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(W), np.asarray(W_ref), rtol=1e-4, atol=1e-4)


def test_sketch_left_kernel_matches_dense():
    sk = make_accum_sketch(jax.random.fold_in(KEY, 77), 300, 12, 3)
    M = jax.random.normal(KEY, (300, 7), jnp.float32)
    S = sk.dense()
    out = sketch_left_kernel(sk, M)
    np.testing.assert_allclose(np.asarray(out), np.asarray(S.T @ M),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("N,d,m,c", [(256, 8, 1, 16), (512, 32, 4, 32),
                                     (300, 12, 3, 7), (128, 19, 2, 5)])
def test_sketch_left_kernel_sweep(N, d, m, c, dtype):
    """True left-apply vs the ref oracle across shapes × dtypes (incl. shapes
    where nothing tiles — the ops wrapper pads rows and sketch columns)."""
    from repro.kernels.accum_apply.ref import sketch_left_ref

    sk = make_accum_sketch(jax.random.fold_in(KEY, N + d + m), N, d, m)
    M = jax.random.normal(jax.random.fold_in(KEY, c), (N, c), dtype)
    ref = sketch_left_ref(sk.indices, sk.coef, M)
    out = sketch_left_kernel(sk, M)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)


def test_sketch_left_kernel_multi_tile_accumulation():
    """N larger than the row tile: partial products accumulate across grid
    steps (the out block is revisited, as in the fused kernel's W)."""
    from repro.kernels.accum_apply.ref import sketch_left_ref

    N, d, m, c = 5000, 16, 4, 24
    sk = make_accum_sketch(jax.random.fold_in(KEY, 91), N, d, m)
    M = jax.random.normal(KEY, (N, c), jnp.float32)
    ref = sketch_left_ref(sk.indices, sk.coef, M)
    out = sketch_left_kernel(sk, M, bn=512)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_sketch_left_kernel_never_transposes_M():
    """The regression that motivated the rewrite: the old path computed
    (Mᵀ S)ᵀ, binding an O(n·c) transposed copy of M.  The traced program must
    contain no (c, N)-shaped buffer."""
    N, c = 300, 7
    sk = make_accum_sketch(jax.random.fold_in(KEY, 77), N, 12, 3)
    M = jax.random.normal(KEY, (N, c), jnp.float32)

    # shape walker now shared via repro.analysis.trace; the (c, N) assertion
    # is this file's planted positive-control target — M itself is (N, c), so
    # the detector must prove the transposed layout is ABSENT, not just small
    shapes = all_shapes(jax.make_jaxpr(
        lambda M: sketch_left_kernel(sk, M))(M).jaxpr)
    assert (N, c) in {s[:2] for s in shapes if len(s) >= 2}  # detector sees M
    assert not any(s[:2] == (c, N) for s in shapes if len(s) >= 2), shapes


def test_interpret_autodetect_and_autotune():
    """Backend autodetection (no TPU in CI → interpreter) and the block table
    covering the benchmark anchor shape."""
    if jax.default_backend() != "tpu":
        assert default_interpret() is True
    bm, bd = autotune_blocks(4096, 8192, 64, 4, jnp.float32)
    assert (bm, bd) == (256, 64)
    # heuristic fallback stays within the VMEM budget and divides nothing
    bm, bd = autotune_blocks(1000, 5000, 48, 3, jnp.float32)
    assert bm >= 8 and 1 <= bd <= 48


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S,Dh,L,Dv", [(128, 32, 16, 32), (256, 64, 64, 64), (128, 128, 256, 128)])
def test_landmark_attention_sweep(S, Dh, L, Dv, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (S, Dh), dtype)
    kt = jax.random.normal(ks[1], (L, Dh), dtype)
    M = jax.random.normal(ks[2], (L, Dv), dtype)
    ref = landmark_attention_ref(q, kt, M)
    out = landmark_attention(q, kt, M, bq=64)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


def test_full_sketched_attention_kernel_vs_core():
    B, H, S, Dh = 2, 3, 128, 32
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, S, Dh))
    k = jax.random.normal(ks[1], (B, H, S, Dh))
    v = jax.random.normal(ks[2], (B, H, S, Dh))
    sk = make_seq_sketch(ks[3], S, 32, 4)
    core = accum_attention(q, k, v, sk)
    kern = accum_attention_kernel(q, k, v, sk, bq=64)
    np.testing.assert_allclose(np.asarray(core), np.asarray(kern), rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------- #
# landmark kernels: padding, bias lane, fused stats, autotune registration
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("S,L,Dv", [(100, 13, 24), (7, 3, 5), (256, 64, 64)])
def test_landmark_attend_padded_bias_vs_oracle(S, L, Dv):
    """The ops-level entry pads arbitrary (S, L) to the block grid; padded
    landmarks get −inf bias so they carry exactly zero softmax weight, and the
    caller-supplied bias lane (the decode log-mass correction) is honored."""
    Dh = 16
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (S, Dh))
    kt = jax.random.normal(ks[1], (L, Dh))
    M = jax.random.normal(ks[2], (L, Dv))
    bias = jax.random.normal(ks[3], (L,))
    ref = landmark_attention_ref(q, kt, M, bias)
    out = landmark_attend(q, kt, M, bias, bq=64, interpret=True)
    assert out.shape == (S, Dv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("S,L,Dv", [(130, 10, 24), (512, 32, 16), (9, 5, 8)])
def test_landmark_stats_fused_vs_ref(S, L, Dv):
    """ONE fused sweep over S must reproduce both the landmark-row softmax W
    and the online-softmax Bm·V of the two-pass oracle, on odd (padded)
    shapes."""
    Dh = 16
    ks = jax.random.split(KEY, 4)
    qt = jax.random.normal(ks[0], (L, Dh))
    kt = jax.random.normal(ks[1], (L, Dh))
    k = jax.random.normal(ks[2], (S, Dh))
    v = jax.random.normal(ks[3], (S, Dv))
    W_ref, BmV_ref = landmark_stats_ref(qt, kt, k, v)
    W, BmV = landmark_stats_fused(qt, kt, k, v, bs=64, interpret=True)
    assert W.shape == (L, L) and BmV.shape == (L, Dv)
    np.testing.assert_allclose(np.asarray(W), np.asarray(W_ref), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(BmV), np.asarray(BmV_ref), rtol=1e-5, atol=1e-5)


def test_landmark_autotune_round_trip(tmp_path, monkeypatch):
    """Both landmark kernels register in the SAME measured cache as the KRR
    kernels: a gated eager call measures + persists under its own kind, and
    the persisted winner is served to later (e.g. traced) lookups."""
    import json as _json

    from repro.kernels.accum_apply import autotune

    cache = tmp_path / "autotune.json"
    monkeypatch.setenv(autotune.ENV_CACHE, str(cache))
    monkeypatch.setenv(autotune.ENV_GATE, "1")

    S, Dh, L, Dv = 128, 16, 8, 8
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (S, Dh))
    kt = jax.random.normal(ks[1], (L, Dh))
    M = jax.random.normal(ks[2], (L, Dv))
    out = landmark_attend(q, kt, M, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(landmark_attention_ref(q, kt, M)),
        rtol=1e-5, atol=1e-5,
    )
    k_seq = jax.random.normal(ks[3], (S, Dh))
    landmark_stats_fused(kt, kt, k_seq, q[:, :Dv], interpret=True)

    entries = _json.loads(cache.read_text())
    kinds = {e.split("|")[0] for e in entries}
    assert {"landmark_attention", "landmark_stats"} <= kinds
    blocks = autotune.lookup("landmark_attention", (S, Dh, L, Dv), q.dtype, True)
    assert blocks is not None and len(blocks) == 1


def test_accum_attention_use_kernel_routing():
    """core.accum_attention(use_kernel=True) routes through the Pallas
    pipeline and matches the plain-XLA path."""
    B, H, S, Dh = 1, 2, 96, 16
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, S, Dh))
    k = jax.random.normal(ks[1], (B, H, S, Dh))
    v = jax.random.normal(ks[2], (B, H, S, Dh))
    sk = make_seq_sketch(ks[3], S, 16, 4)
    plain = accum_attention(q, k, v, sk, use_kernel=False)
    kern = accum_attention(q, k, v, sk, use_kernel=True)
    np.testing.assert_allclose(np.asarray(plain), np.asarray(kern), rtol=1e-4, atol=1e-4)


def test_sketch_decode_attend_kernel_routing():
    """The decode-path kernel (log-mass correction in the bias lane) matches
    the plain jnp decode attend, including empty-slot masking."""
    from repro.core.sketched_attention import (
        decode_slots,
        init_sketch_cache,
        sketch_decode_attend,
        update_sketch_cache,
    )

    B, Hkv, G, d_slots, m_r, Dh = 2, 2, 2, 16, 2, 8
    cache = init_sketch_cache(B, Hkv, d_slots, Dh)
    for t in range(10):    # 10 tokens → some slots stay empty (mass 0)
        kk = jax.random.fold_in(KEY, t)
        k_t = jax.random.normal(kk, (B, Hkv, Dh))
        v_t = jax.random.normal(jax.random.fold_in(kk, 1), (B, Hkv, Dh))
        cache = update_sketch_cache(
            cache, k_t, v_t, decode_slots(KEY, t, d_slots, m_r)
        )
    q_t = jax.random.normal(jax.random.fold_in(KEY, 99), (B, G * Hkv, Dh))
    plain = sketch_decode_attend(q_t, cache, use_kernel=False)
    kern = sketch_decode_attend(q_t, cache, use_kernel=True)
    np.testing.assert_allclose(np.asarray(plain), np.asarray(kern), rtol=1e-5, atol=1e-6)
