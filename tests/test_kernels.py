"""Per-kernel allclose sweeps vs the ref.py oracles (shapes × dtypes),
as required for every Pallas kernel. interpret=True executes on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sketch import make_accum_sketch
from repro.core.sketched_attention import accum_attention, make_seq_sketch
from repro.kernels.accum_apply.ops import sketch_right_kernel
from repro.kernels.accum_apply.ref import accum_apply_ref
from repro.kernels.landmark_attention.kernel import landmark_attention
from repro.kernels.landmark_attention.ops import accum_attention_kernel
from repro.kernels.landmark_attention.ref import landmark_attention_ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "R,N,d,m", [(128, 256, 8, 1), (256, 512, 32, 4), (128, 1024, 16, 8), (256, 256, 64, 2)]
)
def test_accum_apply_sweep(R, N, d, m, dtype):
    K = jax.random.normal(KEY, (R, N), dtype)
    sk = make_accum_sketch(jax.random.fold_in(KEY, d * m), N, d, m)
    ref = accum_apply_ref(K, sk.indices, sk.coef.astype(jnp.float32))
    out = sketch_right_kernel(K, sk, bm=128, bd=min(8, d))
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


def test_accum_apply_wide_K_chunked():
    """N > MAX_COLS path: chunked partial products sum exactly."""
    K = jax.random.normal(KEY, (128, 3 * 8192 // 2), jnp.float32)
    sk = make_accum_sketch(KEY, K.shape[1], 16, 4)
    ref = accum_apply_ref(K, sk.indices, sk.coef)
    out = sketch_right_kernel(K, sk, bm=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S,Dh,L,Dv", [(128, 32, 16, 32), (256, 64, 64, 64), (128, 128, 256, 128)])
def test_landmark_attention_sweep(S, Dh, L, Dv, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (S, Dh), dtype)
    kt = jax.random.normal(ks[1], (L, Dh), dtype)
    M = jax.random.normal(ks[2], (L, Dv), dtype)
    ref = landmark_attention_ref(q, kt, M)
    out = landmark_attention(q, kt, M, bq=64)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


def test_full_sketched_attention_kernel_vs_core():
    B, H, S, Dh = 2, 3, 128, 32
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, S, Dh))
    k = jax.random.normal(ks[1], (B, H, S, Dh))
    v = jax.random.normal(ks[2], (B, H, S, Dh))
    sk = make_seq_sketch(ks[3], S, 32, 4)
    core = accum_attention(q, k, v, sk)
    kern = accum_attention_kernel(q, k, v, sk, bq=64)
    np.testing.assert_allclose(np.asarray(core), np.asarray(kern), rtol=1e-4, atol=1e-4)
