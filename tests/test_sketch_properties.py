"""Property-based invariants of the accumulation sketch (paper Algorithm 1).

Each invariant lives in a plain ``_check_*`` helper; the hypothesis property
drives it over random shapes/seeds (via the ``hypothesis_compat`` shim — the
suite skips cleanly where hypothesis is absent and runs for real on the CI
hypothesis leg), and a deterministic smoke test drives the same helpers over
pinned cases so the invariants stay exercised on every environment.
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core.sketch import (
    _compute_coef,
    append_subsample,
    make_accum_sketch,
    make_accum_sketch_jit,
)

KEY = jax.random.PRNGKey(0)

# (n, d, m) cases for the Monte-Carlo unbiasedness check — a curated finite
# set so the fixed-seed averages below are deterministic and pre-verified
MC_CASES = [
    (8, 2, 1), (16, 4, 2), (24, 8, 4), (32, 4, 1),
    (12, 3, 6), (64, 16, 4), (16, 2, 3), (48, 12, 2),
]


# --------------------------------------------------------------------------- #
# invariant helpers (plain functions — callable with or without hypothesis)
# --------------------------------------------------------------------------- #

def _check_unbiasedness(n, d, m, reps=200):
    """E[S Sᵀ] = I_n at fixed seeds: the identity behind every sketch
    estimator.  Averaged over ``reps`` deterministic draws."""
    acc = np.zeros((n, n))
    for i in range(reps):
        key = jax.random.fold_in(jax.random.fold_in(KEY, 1000 * n + 10 * d + m), i)
        S = np.asarray(make_accum_sketch(key, n, d, m).dense())
        acc += S @ S.T
    acc /= reps
    diag = np.diag(acc)
    off = acc - np.diag(diag)
    assert abs(diag.mean() - 1.0) < 0.25, diag.mean()
    assert abs(off.mean()) < 0.05, off.mean()


def _check_normalization_identity(n, d, m, seed):
    """The exact per-draw identity coef²·d·m·p[idx] = 1 (signs are ±1) —
    what makes E[S Sᵀ] = I hold draw-by-draw, no Monte Carlo needed."""
    sk = make_accum_sketch(jax.random.PRNGKey(seed), n, d, m)
    p = np.asarray(jnp.take(sk.probs, sk.indices))
    lhs = np.asarray(sk.coef) ** 2 * d * m * p
    np.testing.assert_allclose(lhs, np.ones((m, d)), rtol=1e-5, atol=1e-5)


def _check_append_truncate_roundtrip(n, d, m, seed):
    """truncated(m) ∘ append_subsample is the identity on the original draw:
    indices/signs restored exactly, cached coef up to the sqrt rescale."""
    key = jax.random.PRNGKey(seed)
    sk = make_accum_sketch(key, n, d, m)
    grown = append_subsample(sk, jax.random.fold_in(key, 1))
    assert grown.m == m + 1
    back = grown.truncated(m)
    np.testing.assert_array_equal(np.asarray(back.indices), np.asarray(sk.indices))
    np.testing.assert_array_equal(np.asarray(back.signs), np.asarray(sk.signs))
    np.testing.assert_array_equal(np.asarray(back.probs), np.asarray(sk.probs))
    np.testing.assert_allclose(np.asarray(back.coef), np.asarray(sk.coef),
                               rtol=1e-5, atol=1e-6)
    assert back.n == sk.n


def _check_coef_cache_consistency(n, d, m, seed):
    """Every constructor's cached coef_ equals the _compute_coef recompute —
    including through truncated()'s sqrt(M/m) rescale and with_coef()."""
    key = jax.random.PRNGKey(seed)
    for sk in [
        make_accum_sketch(key, n, d, m),
        make_accum_sketch_jit(key, n, d, m),
        append_subsample(make_accum_sketch(key, n, d, m), jax.random.fold_in(key, 7)),
    ]:
        assert sk.coef_ is not None
        np.testing.assert_allclose(
            np.asarray(sk.coef_),
            np.asarray(_compute_coef(sk.indices, sk.signs, sk.probs)),
            rtol=1e-5, atol=1e-6)
    grown = append_subsample(make_accum_sketch(key, n, d, m),
                             jax.random.fold_in(key, 8))
    for mm in range(1, grown.m + 1):
        tr = grown.truncated(mm).with_coef()
        assert tr.coef_ is not None
        np.testing.assert_allclose(
            np.asarray(tr.coef_),
            np.asarray(_compute_coef(tr.indices, tr.signs, tr.probs)),
            rtol=1e-5, atol=1e-6)


def _check_dtype_preserved(n, d, m, dtype_name):
    """signs/probs/coef dtype survives every constructor; indices stay int32."""
    dtype = jnp.dtype(dtype_name)
    for sk in [
        make_accum_sketch(KEY, n, d, m, dtype=dtype),
        make_accum_sketch_jit(KEY, n, d, m, dtype=dtype),
    ]:
        for arr in (sk.signs, sk.probs, sk.coef, sk.coef_):
            assert arr.dtype == dtype, (arr.dtype, dtype)
        assert sk.indices.dtype == jnp.int32
        grown = append_subsample(sk, jax.random.fold_in(KEY, 3))
        tr = grown.truncated(sk.m)
        for derived in (grown, tr, tr.with_coef()):
            for arr in (derived.signs, derived.probs, derived.coef):
                assert arr.dtype == dtype, (arr.dtype, dtype)
            assert derived.indices.dtype == jnp.int32


# --------------------------------------------------------------------------- #
# hypothesis properties
# --------------------------------------------------------------------------- #

@settings(max_examples=8, deadline=None)
@given(case=st.sampled_from(MC_CASES))
def test_prop_unbiasedness_fixed_seeds(case):
    _check_unbiasedness(*case)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(4, 100), d=st.integers(1, 20), m=st.integers(1, 8),
       seed=st.integers(0, 2**20))
def test_prop_normalization_identity(n, d, m, seed):
    _check_normalization_identity(n, d, m, seed)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(4, 100), d=st.integers(1, 16), m=st.integers(1, 6),
       seed=st.integers(0, 2**20))
def test_prop_append_truncate_roundtrip(n, d, m, seed):
    _check_append_truncate_roundtrip(n, d, m, seed)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(4, 64), d=st.integers(1, 12), m=st.integers(1, 5),
       seed=st.integers(0, 2**20))
def test_prop_coef_cache_consistency(n, d, m, seed):
    _check_coef_cache_consistency(n, d, m, seed)


@settings(max_examples=12, deadline=None)
@given(n=st.integers(4, 64), d=st.integers(1, 12), m=st.integers(1, 5),
       dtype_name=st.sampled_from(["float32", "bfloat16", "float16"]))
def test_prop_dtype_preserved(n, d, m, dtype_name):
    _check_dtype_preserved(n, d, m, dtype_name)


# --------------------------------------------------------------------------- #
# deterministic smoke coverage of the same invariants (runs everywhere)
# --------------------------------------------------------------------------- #

def test_invariants_pinned_cases():
    _check_unbiasedness(16, 4, 2, reps=120)
    for (n, d, m, seed) in [(20, 5, 1, 0), (33, 7, 4, 11), (64, 16, 2, 99)]:
        _check_normalization_identity(n, d, m, seed)
        _check_append_truncate_roundtrip(n, d, m, seed)
        _check_coef_cache_consistency(n, d, m, seed)
    for dt in ["float32", "bfloat16", "float16"]:
        _check_dtype_preserved(12, 6, 3, dt)
