"""Graceful-degradation ladder + structured health reporting.

A *ladder* is an ordered list of implementations of the same computation,
fastest first: Pallas kernel → XLA ``lax.scan`` path → dense oracle.  When a
rung raises, :func:`ladder_call` records the degradation in a
:class:`HealthReport` and falls to the next rung — the result stays correct,
only slower, and the event is surfaced through ``info`` / engine stats
instead of silently changing numerics.

For numerics that fail *inside* jitted code (a Cholesky on a non-PSD
matrix), :func:`solve_psd_ladder` runs the whole ladder — escalating ×10
jitter retries, then lstsq — in pure JAX under ``lax.while_loop`` /
``lax.cond``, returning its health record as traced scalars so the jitted
decode/fit path gains **no host syncs** (pinned by the ``solve_psd_ladder``
entry in ``analysis/contracts.toml``).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Sequence

from repro.resilience import faults


@dataclasses.dataclass(frozen=True)
class HealthEvent:
    """One recorded degradation: ``site`` fell from ``rung_from`` to ``rung_to``."""

    site: str
    rung_from: str
    rung_to: str
    detail: str = ""


class HealthReport:
    """Thread-safe append-only log of degradation events.

    Engines and module-level consumers record every rung drop here; tests and
    ops dashboards read ``events`` / ``summary()`` to see *that* and *why*
    numerics took a slower path."""

    def __init__(self):
        self._lock = threading.Lock()
        self._events: list[HealthEvent] = []

    def record(self, site: str, *, rung_from: str, rung_to: str, detail: str = "") -> HealthEvent:
        """Append one degradation event and return it."""
        ev = HealthEvent(site, rung_from, rung_to, str(detail))
        with self._lock:
            self._events.append(ev)
        return ev

    @property
    def events(self) -> list[HealthEvent]:
        """Snapshot of all recorded events, oldest first."""
        with self._lock:
            return list(self._events)

    def count(self, site: str | None = None) -> int:
        """Number of events, optionally restricted to one site."""
        return len([e for e in self.events if site is None or e.site == site])

    def summary(self) -> dict[str, int]:
        """Histogram ``{"site: from->to": n}`` — the engine-stats surface."""
        out: dict[str, int] = {}
        for e in self.events:
            key = f"{e.site}: {e.rung_from}->{e.rung_to}"
            out[key] = out.get(key, 0) + 1
        return out

    def clear(self) -> None:
        """Drop all events (tests)."""
        with self._lock:
            self._events.clear()


_GLOBAL = HealthReport()


def global_health() -> HealthReport:
    """The process-wide report used by module-level ladders (apply, autotune,
    checkpoint restore).  ``Engine`` instances keep their own report too."""
    return _GLOBAL


def ladder_call(
    site: str,
    rungs: Sequence[tuple[str, Callable[[], Any]]],
    *,
    health: HealthReport | None = None,
):
    """Run ``rungs`` (``(name, thunk)`` pairs, fastest first) until one succeeds.

    ``site`` names the ladder for health records; fault *arrivals* happen
    inside the rungs themselves (the kernel entry points in
    ``kernels/*/ops.py`` visit ``kernel.dispatch``, the streaming rung visits
    ``kernel.stream``), so arming both sites drives a three-rung ladder all
    the way to its dense oracle.  Each drop is recorded in ``health``
    (default: the global report).  The terminal rung's exception — and any
    :class:`faults.DeviceLost`, which models preemption, not a backend bug —
    propagates."""
    hr = health if health is not None else _GLOBAL
    for i, (name, fn) in enumerate(rungs):
        try:
            return fn()
        except faults.DeviceLost:
            raise
        except Exception as e:  # noqa: BLE001 — the ladder exists to catch rung failures
            if i == len(rungs) - 1:
                raise
            hr.record(site, rung_from=name, rung_to=rungs[i + 1][0], detail=repr(e))


def solve_psd_ladder(M, b, *, escalations: int = 3):
    """Solve ``M x = b`` for PSD ``M`` with an in-graph degradation ladder.

    Rungs: Cholesky with base jitter ``j0 = 1e-8·(tr M / d)``; on non-finite
    result escalate the jitter ×10 up to ``escalations`` times under
    ``lax.while_loop``; if still non-finite fall to ``lstsq`` under
    ``lax.cond``.  Everything is traced JAX — no host syncs — and the health
    record comes back as traced scalars:

    returns ``(x, {"solve_escalations": int32, "solve_used_lstsq": bool})``.

    The ``solve.cholesky`` fault site mangles ``M`` on entry (eager calls
    only; tracers pass through), letting fault-plan tests drive both the
    escalation rung (tiny ``scale``) and the lstsq rung (large ``scale``).
    """
    import jax.numpy as jnp
    from jax import lax
    from jax.scipy.linalg import cho_factor, cho_solve

    M = faults.mangle_matrix("solve.cholesky", M)
    d = M.shape[0]
    eye = jnp.eye(d, dtype=M.dtype)
    j0 = 1e-8 * (jnp.trace(M) / d + 1e-30)

    def attempt(level):
        c, lo = cho_factor(M + (j0 * 10.0**level) * eye, lower=True)
        x = cho_solve((c, lo), b)
        return x, jnp.all(jnp.isfinite(x))

    x0, ok0 = attempt(jnp.zeros((), M.dtype))

    def cond(carry):
        lvl, _, ok = carry
        return (~ok) & (lvl < escalations)

    def body(carry):
        lvl, _, _ = carry
        lvl = lvl + 1
        x, ok = attempt(lvl.astype(M.dtype))
        return lvl, x, ok

    lvl, x, ok = lax.while_loop(cond, body, (jnp.int32(0), x0, ok0))

    def _lstsq(_):
        rhs = b if b.ndim == 2 else b[:, None]
        sol = jnp.linalg.lstsq(M + j0 * eye, rhs)[0]
        return sol if b.ndim == 2 else sol[:, 0]

    x = lax.cond(ok, lambda _: x, _lstsq, None)
    return x, {"solve_escalations": lvl, "solve_used_lstsq": ~ok}
