"""Fault-injection harness: named fault sites + a deterministic trigger plan.

Production code declares *fault sites* — named points where the outside world
can fail (a kernel dispatch, a checkpoint write, a cache load, a decode
dispatch).  A JSON *fault plan* in ``REPRO_FAULT_PLAN`` (inline JSON or a file
path) arms any subset of them with an action and a deterministic trigger
count, so every recovery path in the repo is exercisable in CI instead of
only in prose:

    REPRO_FAULT_PLAN='{"kernel.dispatch": {"action": "error", "at": 1}}'
    REPRO_FAULT_PLAN=tests/fault_plans/ckpt_kill.json

Per-site spec keys:

  * ``action`` — what fires (see the table below);
  * ``at``     — trigger on the Nth arrival (1-based int or list of ints);
  * ``every``  — trigger every Nth arrival;
  * ``times``  — cap on how many ``every`` firings happen;
  * ``scale``  — magnitude knob for ``indefinite`` (see ``mangle_matrix``).

Actions:

  * ``error``                — raise :class:`FaultInjected` (a transient
                               backend error: retried / degraded around);
  * ``kill`` / ``device_loss`` — raise :class:`DeviceLost` (a simulated
                               preemption: never retried, never degraded —
                               checkpoint/resume is the recovery path);
  * ``nan`` / ``inf`` / ``zero`` — :func:`poison` overwrites a slab of every
                               floating leaf (corrupted accelerator memory);
  * ``indefinite``           — :func:`mangle_matrix` shifts a PSD matrix's
                               spectrum negative (Cholesky-breaking input);
  * ``corrupt`` / ``truncate`` — :func:`corrupt` mangles a byte payload
                               (torn / bit-flipped file writes).

Arrival counters are process-global and deterministic (no randomness); they
reset with :func:`reset` (tests) and are never consumed at JAX trace time —
the data-mangling helpers refuse to fire on tracers, so a jitted function can
never bake an injected fault into its compiled artifact.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any

SITES: dict[str, str] = {
    "kernel.dispatch": "Pallas kernel entry points (kernels/*/ops.py)",
    "kernel.stream": "the XLA lax.scan streaming rung of the kernel ladder",
    "ckpt.write": "one checkpoint tmp-write + rename attempt (checkpoint/ckpt.py)",
    "autotune.load": "autotune measured-cache load (kernels/accum_apply/autotune.py)",
    "solve.cholesky": "the PSD solve's input (resilience.degrade.solve_psd_ladder)",
    "decode.step": "one decode dispatch of Engine.generate (serve/engine.py)",
}

ENV_PLAN = "REPRO_FAULT_PLAN"

_RAISE_ACTIONS = ("error", "kill", "device_loss")
_DATA_ACTIONS = ("nan", "inf", "zero", "indefinite", "corrupt", "truncate")


class FaultInjected(RuntimeError):
    """A transient injected backend error — retry / degrade around it."""

    def __init__(self, site: str, action: str = "error"):
        super().__init__(f"injected fault at {site!r} (action={action!r})")
        self.site, self.action = site, action


class DeviceLost(RuntimeError):
    """A simulated preemption / device loss — fatal to the attempt.

    Deliberately NOT a :class:`FaultInjected` subclass: retry loops and
    degradation ladders catch transient errors but must let this fly (a killed
    process neither retries nor cleans up — checkpoint/resume recovers)."""

    def __init__(self, site: str, action: str = "kill"):
        super().__init__(f"injected device loss at {site!r} (action={action!r})")
        self.site, self.action = site, action


_lock = threading.Lock()
_counts: dict[str, int] = {}
_plan_cache: tuple[str | None, dict[str, dict]] | None = None


def _parse_plan(raw: str) -> dict[str, dict]:
    text = raw
    if not raw.lstrip().startswith(("{", "[")):
        path = raw[1:] if raw.startswith("@") else raw
        with open(path) as f:
            text = f.read()
    obj = json.loads(text)
    if not isinstance(obj, dict):
        raise ValueError(f"{ENV_PLAN} must be a JSON object, got {type(obj).__name__}")
    plan: dict[str, dict] = {}
    for site, spec in obj.items():
        if site not in SITES:
            raise ValueError(f"unknown fault site {site!r}; known: {sorted(SITES)}")
        if not isinstance(spec, dict) or spec.get("action") not in (
            _RAISE_ACTIONS + _DATA_ACTIONS
        ):
            raise ValueError(f"bad spec for fault site {site!r}: {spec!r}")
        plan[site] = spec
    return plan


def active_plan() -> dict[str, dict]:
    """The parsed ``REPRO_FAULT_PLAN`` (env read at call time; cached per
    value).  A malformed plan raises — fault injection is an explicit opt-in
    and a silent typo would fake a green chaos run."""
    global _plan_cache
    raw = os.environ.get(ENV_PLAN)
    if _plan_cache is not None and _plan_cache[0] == raw:
        return _plan_cache[1]
    plan = _parse_plan(raw) if raw else {}
    _plan_cache = (raw, plan)
    return plan


def reset() -> None:
    """Clear all arrival counters (tests — deterministic per-test counts)."""
    with _lock:
        _counts.clear()


def _fires(spec: dict, count: int) -> bool:
    at = spec.get("at")
    if at is not None:
        if count in (at if isinstance(at, list) else [at]):
            return True
    every = spec.get("every")
    if every:
        times = spec.get("times")
        if count % int(every) == 0:
            return times is None or count // int(every) <= int(times)
    return False


def fault_point(site: str) -> dict | None:
    """One arrival at ``site``: count it and fire the armed action, if any.

    Raise-style actions (``error`` / ``kill``) raise here; data-mangling
    actions return the triggered spec so the call site can apply them via
    :func:`poison` / :func:`mangle_matrix` / :func:`corrupt` (which all call
    this themselves — one arrival per call either way).  Returns None when
    nothing fires."""
    if site not in SITES:
        raise KeyError(f"unregistered fault site {site!r}")
    spec = active_plan().get(site)
    if spec is None:
        return None
    with _lock:
        _counts[site] = count = _counts.get(site, 0) + 1
    if not _fires(spec, count):
        return None
    action = spec["action"]
    if action == "error":
        raise FaultInjected(site, action)
    if action in ("kill", "device_loss"):
        raise DeviceLost(site, action)
    return dict(spec)


def _is_tracer(x: Any) -> bool:
    import jax

    return isinstance(x, jax.core.Tracer)


def poison(site: str, tree: Any) -> Any:
    """Arrive at ``site``; on a ``nan``/``inf``/``zero`` trigger overwrite the
    leading eighth of every floating leaf of ``tree`` (a corrupted slab).

    Host-level only: if any leaf is a JAX tracer the arrival is NOT consumed
    and the tree is returned unchanged (a compiled function must never bake an
    injection into its artifact)."""
    import jax
    import jax.numpy as jnp

    leaves = jax.tree_util.tree_leaves(tree)
    if any(_is_tracer(x) for x in leaves):
        return tree
    spec = fault_point(site)
    if spec is None or spec["action"] not in ("nan", "inf", "zero"):
        return tree
    val = {"nan": jnp.nan, "inf": jnp.inf, "zero": 0.0}[spec["action"]]

    def _poison_leaf(x):
        if not hasattr(x, "dtype") or not jnp.issubdtype(x.dtype, jnp.floating):
            return x
        if x.ndim == 0:
            return jnp.asarray(val, x.dtype)
        flat = jnp.ravel(x)
        k = max(1, flat.shape[0] // 8)
        return flat.at[:k].set(val).reshape(x.shape)

    return jax.tree_util.tree_map(_poison_leaf, tree)


def mangle_matrix(site: str, M: Any) -> Any:
    """Arrive at ``site``; on a trigger make the square matrix ``M`` hostile.

    ``indefinite`` shifts the spectrum by ``-scale · (tr M / d)`` (default
    scale 2.0 — far past any bounded jitter escalation, forcing the lstsq
    rung; a tiny scale like 3e-8 is recoverable by one ×10 escalation).
    ``nan``/``inf``/``zero`` poison a slab like :func:`poison`.  No-op on
    tracers (arrival not consumed)."""
    import jax.numpy as jnp

    if _is_tracer(M):
        return M
    spec = fault_point(site)
    if spec is None:
        return M
    action = spec["action"]
    if action == "indefinite":
        scale = float(spec.get("scale", 2.0))
        d = M.shape[0]
        return M - scale * (jnp.trace(M) / d) * jnp.eye(d, dtype=M.dtype)
    if action in ("nan", "inf", "zero"):
        val = {"nan": jnp.nan, "inf": jnp.inf, "zero": 0.0}[action]
        return M.at[0].set(jnp.asarray(val, M.dtype)) if M.ndim else M
    return M


def corrupt(site: str, data: bytes) -> bytes:
    """Arrive at ``site``; on a trigger mangle the byte payload.

    ``truncate`` keeps the first half (a torn write); ``corrupt`` XOR-flips a
    byte every ~1% (bit rot).  Raise-style actions raise from the shared
    :func:`fault_point` — a ``kill`` here models dying mid-write."""
    spec = fault_point(site)
    if spec is None:
        return data
    action = spec["action"]
    if action == "truncate":
        return data[: len(data) // 2]
    if action == "corrupt":
        b = bytearray(data)
        step = max(1, len(b) // 97)
        for i in range(0, len(b), step):
            b[i] ^= 0xFF
        return bytes(b)
    return data
