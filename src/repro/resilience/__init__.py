"""Resilience layer: fault injection, degradation ladders, health reporting.

See ``docs/resilience.md`` for the fault-site catalog, the ladder table and
the checkpoint/resume bitwise guarantee.
"""
from repro.resilience.degrade import (
    HealthEvent,
    HealthReport,
    global_health,
    ladder_call,
    solve_psd_ladder,
)
from repro.resilience.faults import (
    SITES,
    DeviceLost,
    FaultInjected,
    active_plan,
    corrupt,
    fault_point,
    mangle_matrix,
    poison,
    reset,
)
