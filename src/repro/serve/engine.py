"""Serving engine: batched prefill + decode with exact or AccumSketch caches.

The sketched cache (paper technique) makes per-request memory independent of
context length — the long_500k production shape decodes against d_slots
landmark slots instead of a 500k-entry KV cache.

Request lifecycle (each phase is ONE jitted dispatch):

  prefill  — `prefill_with_cache`: all L prompt tokens in a single chunked
             forward with a bulk cache write (exact: dynamic_update_slice;
             sketched: one vectorized segment-sum scatter, bitwise-identical
             to the token-by-token loop's cache);
  decode   — a `lax.scan` of exactly n_new - 1 `decode_step`s (the first
             output token is sampled from the prefill logits, so an n-token
             request runs n - 1 steps — the seed ran n and threw the last
             away).

Slot draws and temperature sampling use independent counter-based RNG streams
(`fold_in(fold_in(key, tag), pos)`); the seed derived both from
`fold_in(key, pos)`, correlating cache placement with sampled tokens.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.streams import SAMPLE_STREAM as _SAMPLE_STREAM
from repro.analysis.streams import SLOT_STREAM as _SLOT_STREAM
from repro.configs.base import ModelConfig
from repro.core.sketched_attention import decode_slot_table, decode_slots
from repro.models.model import (
    DecodeCache,
    decode_step,
    init_cache,
    prefill_with_cache,
)

PyTree = Any

# distinct fold_in tags (registered in repro.analysis.streams) so slot draws
# and sampling draws are independent streams off the same seed (both are then
# folded with the position counter)


@dataclasses.dataclass
class ServeConfig:
    """Engine knobs (cache flavor, sampling, slot-draw scheme).

    `slot_scheme` selects the streaming sampling scheme for sketched-cache
    slot draws ("uniform" | "poisson" — see `decode_slots`). `cache_dtype`
    applies to both exact KV caches and the sketched k/v slot accumulators
    (mass stays f32). When `max_len <= cfg.sketch_attn.d_slots` the slot draw
    degrades to the identity and sketched decode is exact attention."""

    max_len: int = 2048
    use_sketch: bool = False
    temperature: float = 0.0        # 0 → greedy
    seed: int = 0
    slot_scheme: str = "uniform"
    cache_dtype: Any = jnp.bfloat16


class Engine:
    """Single-host engine; the sharded variant jits with in_shardings from
    repro.sharding (see launch/serve.py)."""

    def __init__(self, cfg: ModelConfig, params: PyTree, sc: ServeConfig):
        self.cfg, self.params, self.sc = cfg, params, sc
        self.key = jax.random.PRNGKey(sc.seed)
        self._slot_key = jax.random.fold_in(self.key, _SLOT_STREAM)
        self._sample_key = jax.random.fold_in(self.key, _SAMPLE_STREAM)
        self._step = jax.jit(
            lambda p, c, t, i, s: decode_step(
                p, c, t, i, cfg, slots=s, use_sketch=sc.use_sketch
            )
        )
        self._prefill = jax.jit(
            lambda p, c, t, st: prefill_with_cache(p, t, cfg, c, slot_table=st)
        )
        self._decode = jax.jit(self._decode_scan, static_argnames=("n_steps",))

    def new_cache(self, batch: int) -> DecodeCache:
        """Fresh decode cache (exact KV or sketched per `sc.use_sketch`)."""
        return init_cache(
            self.cfg, batch, self.sc.max_len, self.sc.cache_dtype,
            use_sketch=self.sc.use_sketch,
        )

    def _slots(self, pos) -> jax.Array:
        sa = self.cfg.sketch_attn
        return decode_slots(
            self._slot_key, pos, sa.d_slots, sa.m_r,
            scheme=self.sc.slot_scheme, max_len=self.sc.max_len,
        )

    def _slot_table(self, length: int) -> jax.Array:
        sa = self.cfg.sketch_attn
        return decode_slot_table(
            self._slot_key, length, sa.d_slots, sa.m_r,
            scheme=self.sc.slot_scheme, max_len=self.sc.max_len,
        )

    def prefill_tokens(
        self, cache: DecodeCache, prompts: np.ndarray
    ) -> tuple[DecodeCache, jax.Array]:
        """Batched one-dispatch prefill of all L prompt tokens (positions
        0..L-1). prompts: (B, L). Returns (cache, last-position logits)."""
        tokens = jnp.asarray(prompts)
        table = self._slot_table(tokens.shape[1]) if self.sc.use_sketch else None
        logits, cache = self._prefill(self.params, cache, tokens, table)
        return cache, logits

    def prefill_tokens_sequential(
        self, cache: DecodeCache, prompts: np.ndarray
    ) -> tuple[DecodeCache, jax.Array]:
        """Token-by-token decode-mode prefill (L jitted dispatches) — the
        pre-batched path, kept as the equivalence oracle for tests and the
        baseline for `benchmarks/attention_bench.py`. prompts: (B, L)."""
        logits = None
        for t in range(prompts.shape[1]):
            logits, cache = self._step(
                self.params, cache, jnp.asarray(prompts[:, t]), jnp.int32(t),
                self._slots(t),
            )
        return cache, logits

    def _decode_scan(self, params, cache, tok0, pos0, *, n_steps: int):
        """n_steps decode steps + samples as one jitted `lax.scan` dispatch."""
        def _body(carry, _):
            cache, tok, pos = carry
            logits, cache = decode_step(
                params, cache, tok, pos, self.cfg,
                slots=self._slots(pos), use_sketch=self.sc.use_sketch,
            )
            nxt = self._sample(logits, pos + 1)
            return (cache, nxt, pos + 1), nxt

        (cache, _, _), toks = jax.lax.scan(
            _body, (cache, tok0, pos0), None, length=n_steps
        )
        return jnp.swapaxes(toks, 0, 1), cache

    def generate(
        self, prompts: np.ndarray, n_new: int
    ) -> tuple[np.ndarray, DecodeCache]:
        """Prefill `prompts` (B, L) and generate n_new tokens per sequence.

        Token 0 is sampled from the prefill logits; the scan then runs exactly
        n_new - 1 decode steps (each producing the next token), so no model
        forward's outputs are ever discarded. Returns ((B, n_new), cache)."""
        B, L = prompts.shape
        cache = self.new_cache(B)
        cache, logits = self.prefill_tokens(cache, prompts)
        tok = self._sample(logits, jnp.int32(L))
        if n_new <= 1:
            return np.asarray(tok)[:, None], cache
        toks, cache = self._decode(
            self.params, cache, tok, jnp.int32(L), n_steps=n_new - 1
        )
        out = np.concatenate([np.asarray(tok)[:, None], np.asarray(toks)], axis=1)
        return out, cache

    def _sample(self, logits: jax.Array, pos) -> jax.Array:
        if self.sc.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        k = jax.random.fold_in(self._sample_key, pos)  # rng-stream: sample-position
        return jax.random.categorical(k, logits / self.sc.temperature).astype(jnp.int32)
