"""Serving engine: batched prefill + decode with exact or AccumSketch caches.

The sketched cache (paper technique) makes per-request memory independent of
context length — the long_500k production shape decodes against d_slots
landmark slots instead of a 500k-entry KV cache.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.sketched_attention import decode_slots
from repro.models.model import DecodeCache, decode_step, init_cache

PyTree = Any


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 2048
    use_sketch: bool = False
    temperature: float = 0.0        # 0 → greedy
    seed: int = 0


class Engine:
    """Single-host engine; the sharded variant jits with in_shardings from
    repro.sharding (see launch/serve.py)."""

    def __init__(self, cfg: ModelConfig, params: PyTree, sc: ServeConfig):
        self.cfg, self.params, self.sc = cfg, params, sc
        self.key = jax.random.PRNGKey(sc.seed)
        self._step = jax.jit(
            lambda p, c, t, i, s: decode_step(
                p, c, t, i, cfg, slots=s, use_sketch=sc.use_sketch
            )
        )

    def new_cache(self, batch: int) -> DecodeCache:
        return init_cache(
            self.cfg, batch, self.sc.max_len, use_sketch=self.sc.use_sketch
        )

    def _slots(self, pos: int) -> jax.Array:
        sa = self.cfg.sketch_attn
        return decode_slots(self.key, pos, sa.d_slots, sa.m_r)

    def prefill_tokens(self, cache: DecodeCache, prompts: np.ndarray) -> tuple[DecodeCache, jax.Array]:
        """Sequential decode-mode prefill (token by token) — exercises the same
        cache path the decoder uses. prompts: (B, L)."""
        logits = None
        for t in range(prompts.shape[1]):
            logits, cache = self._step(
                self.params, cache, jnp.asarray(prompts[:, t]), jnp.int32(t),
                self._slots(t),
            )
        return cache, logits

    def generate(
        self, prompts: np.ndarray, n_new: int
    ) -> tuple[np.ndarray, DecodeCache]:
        B, L = prompts.shape
        cache = self.new_cache(B)
        cache, logits = self.prefill_tokens(cache, prompts)
        out = []
        tok = self._sample(logits, L)
        for i in range(n_new):
            out.append(np.asarray(tok))
            pos = L + i
            logits, cache = self._step(
                self.params, cache, tok, jnp.int32(pos), self._slots(pos)
            )
            tok = self._sample(logits, pos + 1)
        return np.stack(out, axis=1), cache

    def _sample(self, logits: jax.Array, pos: int) -> jax.Array:
        if self.sc.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        k = jax.random.fold_in(self.key, pos)
        return jax.random.categorical(k, logits / self.sc.temperature).astype(jnp.int32)
