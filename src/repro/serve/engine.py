"""Serving engine: batched prefill + decode with exact or AccumSketch caches.

The sketched cache (paper technique) makes per-request memory independent of
context length — the long_500k production shape decodes against d_slots
landmark slots instead of a 500k-entry KV cache.

Request lifecycle (each phase is ONE jitted dispatch):

  prefill  — `prefill_with_cache`: all L prompt tokens in a single chunked
             forward with a bulk cache write (exact: dynamic_update_slice;
             sketched: one vectorized segment-sum scatter, bitwise-identical
             to the token-by-token loop's cache);
  decode   — a `lax.scan` of exactly n_new - 1 `decode_step`s (the first
             output token is sampled from the prefill logits, so an n-token
             request runs n - 1 steps — the seed ran n and threw the last
             away).

Slot draws and temperature sampling use independent counter-based RNG streams
(`fold_in(fold_in(key, tag), pos)`); the seed derived both from
`fold_in(key, pos)`, correlating cache placement with sampled tokens.

Resilience (see docs/resilience.md):

* With `ckpt_dir` set and a `request_id` passed to `generate()`, the decode
  loop runs in chunks of `ckpt_every` steps and checkpoints
  (cache, emitted tokens) after each chunk. Because every random draw is a
  pure function of (seed, position-counter), the snapshot plus the emitted
  count IS the full RNG-stream + slot-schedule state — a generate() killed
  mid-decode and resumed in a fresh process emits bitwise-identical tokens.
* With `health_check` on, the cache is screened for non-finite values / mass
  underflow between chunks (eager, OUTSIDE the jitted scan — the scan itself
  gains no host syncs, pinned by the `engine_decode*` trace contracts). A
  poisoned sketched cache degrades to exact attention by re-prefilling the
  emitted history; the event lands in `Engine.health`, never silently.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.streams import SAMPLE_STREAM as _SAMPLE_STREAM
from repro.analysis.streams import SLOT_STREAM as _SLOT_STREAM
from repro.checkpoint import ckpt
from repro.configs.base import ModelConfig
from repro.core.sketched_attention import SketchCache, decode_slot_table, decode_slots
from repro.models.model import (
    DecodeCache,
    decode_step,
    init_cache,
    prefill_with_cache,
)
from repro.resilience import faults
from repro.resilience.degrade import HealthReport

PyTree = Any

# distinct fold_in tags (registered in repro.analysis.streams) so slot draws
# and sampling draws are independent streams off the same seed (both are then
# folded with the position counter)


def _prompt_digest(prompts: np.ndarray) -> str:
    a = np.ascontiguousarray(np.asarray(prompts))
    return hashlib.sha256(a.tobytes() + str(a.shape).encode()).hexdigest()[:16]


@dataclasses.dataclass
class ServeConfig:
    """Engine knobs (cache flavor, sampling, slot-draw scheme, resilience).

    `slot_scheme` selects the streaming sampling scheme for sketched-cache
    slot draws ("uniform" | "poisson" — see `decode_slots`). `cache_dtype`
    applies to both exact KV caches and the sketched k/v slot accumulators
    (mass stays f32). When `max_len <= cfg.sketch_attn.d_slots` the slot draw
    degrades to the identity and sketched decode is exact attention.

    Resilience knobs: `ckpt_dir` + a `request_id` arm per-request
    checkpoint/resume, `ckpt_every` sets the decode chunk between snapshots
    (0 → one chunk, checkpoint only at the end), `keep_last` bounds retained
    history, `health_check` screens the cache between chunks."""

    max_len: int = 2048
    use_sketch: bool = False
    temperature: float = 0.0        # 0 → greedy
    seed: int = 0
    slot_scheme: str = "uniform"
    cache_dtype: Any = jnp.bfloat16
    ckpt_dir: str | None = None
    ckpt_every: int = 0
    keep_last: int = 3
    health_check: bool = True


class Engine:
    """Single-host engine; the sharded variant jits with in_shardings from
    repro.sharding (see launch/serve.py)."""

    def __init__(self, cfg: ModelConfig, params: PyTree, sc: ServeConfig):
        self.cfg, self.params, self.sc = cfg, params, sc
        self.health = HealthReport()
        self.key = jax.random.PRNGKey(sc.seed)
        self._slot_key = jax.random.fold_in(self.key, _SLOT_STREAM)
        self._sample_key = jax.random.fold_in(self.key, _SAMPLE_STREAM)
        self._step = jax.jit(
            lambda p, c, t, i, s: decode_step(
                p, c, t, i, cfg, slots=s, use_sketch=sc.use_sketch
            )
        )
        self._prefill = jax.jit(
            lambda p, c, t, st: prefill_with_cache(p, t, cfg, c, slot_table=st)
        )
        self._decode = jax.jit(
            self._decode_scan, static_argnames=("n_steps", "use_sketch")
        )

    def new_cache(self, batch: int, use_sketch: bool | None = None) -> DecodeCache:
        """Fresh decode cache (exact KV or sketched per `sc.use_sketch`;
        `use_sketch` overrides — the degradation/resume paths build exact
        caches from a sketched engine)."""
        if use_sketch is None:
            use_sketch = self.sc.use_sketch
        return init_cache(
            self.cfg, batch, self.sc.max_len, self.sc.cache_dtype,
            use_sketch=use_sketch,
        )

    def stats(self) -> dict:
        """Engine health surface: degradation/resume events recorded so far."""
        return {"health_events": self.health.count(), "health": self.health.summary()}

    def _slots(self, pos) -> jax.Array:
        sa = self.cfg.sketch_attn
        return decode_slots(
            self._slot_key, pos, sa.d_slots, sa.m_r,
            scheme=self.sc.slot_scheme, max_len=self.sc.max_len,
        )

    def _slot_table(self, length: int) -> jax.Array:
        sa = self.cfg.sketch_attn
        return decode_slot_table(
            self._slot_key, length, sa.d_slots, sa.m_r,
            scheme=self.sc.slot_scheme, max_len=self.sc.max_len,
        )

    def prefill_tokens(
        self, cache: DecodeCache, prompts: np.ndarray
    ) -> tuple[DecodeCache, jax.Array]:
        """Batched one-dispatch prefill of all L prompt tokens (positions
        0..L-1). prompts: (B, L). Returns (cache, last-position logits)."""
        tokens = jnp.asarray(prompts)
        table = self._slot_table(tokens.shape[1]) if self.sc.use_sketch else None
        logits, cache = self._prefill(self.params, cache, tokens, table)
        return cache, logits

    def prefill_tokens_sequential(
        self, cache: DecodeCache, prompts: np.ndarray
    ) -> tuple[DecodeCache, jax.Array]:
        """Token-by-token decode-mode prefill (L jitted dispatches) — the
        pre-batched path, kept as the equivalence oracle for tests and the
        baseline for `benchmarks/attention_bench.py`. prompts: (B, L)."""
        logits = None
        for t in range(prompts.shape[1]):
            logits, cache = self._step(
                self.params, cache, jnp.asarray(prompts[:, t]), jnp.int32(t),
                self._slots(t),
            )
        return cache, logits

    def _decode_scan(
        self, params, cache, tok0, pos0, *, n_steps: int, use_sketch: bool | None = None
    ):
        """n_steps decode steps + samples as one jitted `lax.scan` dispatch.

        `use_sketch` (static) overrides the engine default so a degraded
        request can continue on the exact-attention path."""
        if use_sketch is None:
            use_sketch = self.sc.use_sketch

        def _body(carry, _):
            cache, tok, pos = carry
            logits, cache = decode_step(
                params, cache, tok, pos, self.cfg,
                slots=self._slots(pos), use_sketch=use_sketch,
            )
            nxt = self._sample(logits, pos + 1)
            return (cache, nxt, pos + 1), nxt

        (cache, _, _), toks = jax.lax.scan(
            _body, (cache, tok0, pos0), None, length=n_steps
        )
        return jnp.swapaxes(toks, 0, 1), cache

    # ---------------------------------------------------------------- resume

    def _request_extra(self, prompts, use_sketch: bool, n_emitted: int) -> dict:
        return {
            "prompt_sha": _prompt_digest(prompts),
            "seed": self.sc.seed,
            "slot_scheme": self.sc.slot_scheme,
            "max_len": self.sc.max_len,
            "temperature": self.sc.temperature,
            "use_sketch": bool(use_sketch),
            "n_emitted": int(n_emitted),
        }

    def _save_request(self, ckdir: str, cache, toks_done, use_sketch, prompts) -> None:
        ckpt.save(
            ckdir,
            {"cache": cache, "toks": np.asarray(toks_done, np.int32)},
            step=int(toks_done.shape[1]),
            extra=self._request_extra(prompts, use_sketch, toks_done.shape[1]),
            keep_last=self.sc.keep_last,
        )

    def _try_resume(self, ckdir: str, prompts: np.ndarray):
        """Load the newest usable request checkpoint, validating that it was
        written for this exact (prompts, seed, scheme, max_len, temperature)
        — anything else would break the bitwise guarantee, so a mismatch
        raises instead of silently generating different tokens. A corrupt
        newest step falls back to the prior one (health-recorded)."""
        B = prompts.shape[0]
        steps = ckpt.committed_steps(ckdir)
        digest = _prompt_digest(prompts)
        for i, s in enumerate(steps):
            try:
                extra = ckpt.read_meta(ckdir, s)["extra"]
            except Exception as e:  # noqa: BLE001 — unreadable meta == corrupt step
                self._record_skip(steps, i, e)
                continue
            fields = ("seed", "slot_scheme", "max_len", "temperature")
            want = self._request_extra(prompts, extra.get("use_sketch", False), 0)
            if extra.get("prompt_sha") != digest or any(
                extra.get(f) != want[f] for f in fields
            ):
                raise ValueError(
                    f"checkpoint {ckdir}/step_{s} was written for a different "
                    "request or engine config; refusing to resume (the bitwise "
                    "guarantee would not hold)"
                )
            use_sketch = bool(extra.get("use_sketch", self.sc.use_sketch))
            like = {
                "cache": self.new_cache(B, use_sketch=use_sketch),
                "toks": np.zeros((B, 1), np.int32),
            }
            try:
                state, _ = ckpt.restore(ckdir, like, step=s)
            except Exception as e:  # noqa: BLE001 — corrupt payload: try step N−1
                self._record_skip(steps, i, e)
                continue
            cache = jax.tree_util.tree_map(jnp.asarray, state["cache"])
            toks = np.asarray(state["toks"], np.int32)
            self.health.record(
                "ckpt.resume", rung_from="cold", rung_to=f"step_{s}",
                detail=f"resumed with {toks.shape[1]} tokens emitted",
            )
            return cache, toks, use_sketch
        return None

    def _record_skip(self, steps, i, err) -> None:
        nxt = f"step_{steps[i + 1]}" if i + 1 < len(steps) else "none"
        self.health.record(
            "ckpt.restore", rung_from=f"step_{steps[i]}", rung_to=nxt, detail=repr(err)
        )

    # ------------------------------------------------------------ health

    def _cache_bad(self, cache, use_sketch: bool) -> str:
        """Screen the cache between decode chunks (ONE host read, outside the
        jitted scan). Returns a reason string, or "" when healthy."""
        bad = jnp.zeros((), jnp.int32)
        for leaf in jax.tree_util.tree_leaves(cache):
            if jnp.issubdtype(leaf.dtype, jnp.floating):
                bad = bad + jnp.sum(~jnp.isfinite(leaf)).astype(jnp.int32)
        n_bad = int(bad)
        if n_bad:
            return f"{n_bad} non-finite cache entries"
        if use_sketch:
            nodes = jax.tree_util.tree_flatten(
                cache.blocks, is_leaf=lambda x: isinstance(x, SketchCache)
            )[0]
            mins = [
                jnp.min(jnp.sum(n.mass.astype(jnp.float32), axis=-1))
                for n in nodes
                if isinstance(n, SketchCache)
            ]
            if mins and float(jnp.min(jnp.stack(mins))) <= 0.0:
                return "sketched cache mass underflow"
        return ""

    def _rebuild_exact(self, prompts: np.ndarray, toks_done: np.ndarray) -> DecodeCache:
        """Exact-attention degrade: re-prefill prompt + emitted history into a
        fresh exact KV cache (generalizes the `max_len <= d_slots` identity
        path — correctness is preserved, only the flat-memory property is
        given up for this request)."""
        hist = np.concatenate(
            [np.asarray(prompts), np.asarray(toks_done[:, :-1])], axis=1
        )
        cache = self.new_cache(prompts.shape[0], use_sketch=False)
        _, cache = self._prefill(self.params, cache, jnp.asarray(hist), None)
        return cache

    # ---------------------------------------------------------------- serve

    def generate(
        self, prompts: np.ndarray, n_new: int, *, request_id: str | None = None
    ) -> tuple[np.ndarray, DecodeCache]:
        """Prefill `prompts` (B, L) and generate n_new tokens per sequence.

        Token 0 is sampled from the prefill logits; the scan then runs exactly
        n_new - 1 decode steps (each producing the next token), so no model
        forward's outputs are ever discarded. Returns ((B, n_new), cache).

        With `sc.ckpt_dir` set and a `request_id`, progress is checkpointed
        every `sc.ckpt_every` emitted tokens and an interrupted request
        resumes from <ckpt_dir>/<request_id> with bitwise-identical output
        (every slot draw and sample is a pure function of (seed, position),
        so cache + emitted tokens IS the complete resume state)."""
        B, L = prompts.shape
        use_sketch = self.sc.use_sketch
        ckdir = (
            os.path.join(self.sc.ckpt_dir, str(request_id))
            if self.sc.ckpt_dir and request_id is not None
            else None
        )
        resumed = self._try_resume(ckdir, prompts) if ckdir else None
        if resumed is not None:
            cache, toks_done, use_sketch = resumed
        else:
            cache = self.new_cache(B)
            cache, logits = self.prefill_tokens(cache, prompts)
            tok = self._sample(logits, jnp.int32(L))
            toks_done = np.asarray(tok)[:, None]
            if ckdir:
                self._save_request(ckdir, cache, toks_done, use_sketch, prompts)
        while toks_done.shape[1] < n_new:
            emitted = toks_done.shape[1]
            remaining = n_new - emitted
            chunk = (
                remaining if self.sc.ckpt_every <= 0
                else min(self.sc.ckpt_every, remaining)
            )
            # fault site: one arrival per decode dispatch ("kill" dies here;
            # "nan"/"inf"/"zero" poison the cache the health screen must catch)
            cache = faults.poison("decode.step", cache)
            if self.sc.health_check:
                reason = self._cache_bad(cache, use_sketch)
                if reason:
                    self.health.record(
                        "decode.cache",
                        rung_from="sketched" if use_sketch else "exact",
                        rung_to="exact-rebuild",
                        detail=reason,
                    )
                    cache = self._rebuild_exact(prompts, toks_done)
                    use_sketch = False
            toks, cache = self._decode(
                self.params, cache, jnp.asarray(toks_done[:, -1]),
                jnp.int32(L + emitted - 1), n_steps=chunk, use_sketch=use_sketch,
            )
            toks_done = np.concatenate([toks_done, np.asarray(toks)], axis=1)
            if ckdir:
                self._save_request(ckdir, cache, toks_done, use_sketch, prompts)
        return toks_done[:, :n_new], cache

    def _sample(self, logits: jax.Array, pos) -> jax.Array:
        if self.sc.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        k = jax.random.fold_in(self._sample_key, pos)  # rng-stream: sample-position
        return jax.random.categorical(k, logits / self.sc.temperature).astype(jnp.int32)
