"""Trace contracts: machine-checked memory/dispatch budgets per entry point.

A *contract* pins the invariants one public entry point must keep when traced
at a small probe shape:

  * ``budget`` — max peak intermediate bytes as an expression of the probe
    variables (``"4*n*(m*d + p) + 16*MiB"``), the no-quadratic-buffer rule;
  * ``measured_peak_bytes`` — a ratchet: the peak the trace actually binds
    today.  ``check`` fails if a PR regresses it upward;
    ``check --update`` re-measures and only ever ratchets it DOWN (like the
    coverage gate);
  * ``pallas_calls`` — EXACT static dispatch count (one K-pass per batch);
  * ``forbid`` — primitive names that must not appear (host callbacks on
    serving paths, …);
  * ``donation = true`` — the entry point's donated wrapper must really lower
    with buffer-donation attrs (`verify_donation`);
  * ``rng = true`` — the RNG-lineage checker must find no reused keys
    (`repro.analysis.rng`), the PR 8 bug class;
  * ``devices`` — minimum device count (8 for the sharded twins: those
    contracts only run under the forced-8-device CI leg).

The manifest lives in ``contracts.toml`` next to this file; the probe
builders (how to construct the traced call per entry point) live in
``ENTRY_POINTS`` below.  ``python -m repro.analysis check`` evaluates
everything plus the source-level `fold_in` sweep.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib

import jax
import jax.numpy as jnp

from repro.analysis import rng as rng_mod
from repro.analysis import trace as trace_mod

CONTRACTS_PATH = pathlib.Path(__file__).with_name("contracts.toml")

_EXPR_GLOBALS = {"KiB": 1024, "MiB": 1024 * 1024, "min": min, "max": max}


def eval_budget(expr: str, probe: dict) -> int:
    """Evaluate a budget expression over the probe variables (restricted eval:
    names resolve to probe params plus KiB/MiB/min/max only)."""
    tree = ast.parse(expr, mode="eval")
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            if node.id not in probe and node.id not in _EXPR_GLOBALS:
                raise ValueError(
                    f"budget expression {expr!r} uses unknown name {node.id!r}")
        elif isinstance(node, (ast.Call,)):
            if not (isinstance(node.func, ast.Name)
                    and node.func.id in ("min", "max")):
                raise ValueError(f"budget expression {expr!r}: only min/max calls")
    return int(eval(compile(tree, "<budget>", "eval"),
                    {"__builtins__": {}}, {**_EXPR_GLOBALS, **probe}))


# --------------------------------------------------------------------------- #
# manifest io — honest TOML via tomllib where available, with a fallback
# parser for the flat subset this file uses (py3.10 without tomli)
# --------------------------------------------------------------------------- #

def _parse_value(raw: str):
    raw = raw.strip()
    if raw == "true":
        return True
    if raw == "false":
        return False
    try:
        return ast.literal_eval(raw)
    except (ValueError, SyntaxError):
        return raw.strip('"')


def _parse_toml_flat(text: str) -> dict:
    out: dict = {}
    cur = None
    for line in text.splitlines():
        s = "" if line.strip().startswith("#") else line.split("#", 1)[0].strip()
        if not s:
            continue
        if s.startswith("[") and s.endswith("]"):
            cur = s[1:-1].strip().strip('"')
            out[cur] = {}
            continue
        if "=" in s and cur is not None:
            k, v = s.split("=", 1)
            out[cur][k.strip()] = _parse_value(v)
    return out


def load_manifest(path: pathlib.Path | str = CONTRACTS_PATH) -> dict:
    """Read contracts.toml into {name: {key: value}}."""
    text = pathlib.Path(path).read_text()
    try:
        import tomllib

        return tomllib.loads(text)
    except ImportError:
        return _parse_toml_flat(text)


def _emit_value(v) -> str:
    if isinstance(v, bool):
        return "true" if v else "false"
    if isinstance(v, (int, float)):
        return str(v)
    if isinstance(v, (list, tuple)):
        return "[" + ", ".join(_emit_value(x) for x in v) + "]"
    return '"' + str(v).replace('"', '\\"') + '"'


def dump_manifest(manifest: dict, path: pathlib.Path | str = CONTRACTS_PATH):
    """Write the manifest back out (``check --update``'s ratchet writer)."""
    lines = [
        "# Trace-contract manifest — evaluated by `python -m repro.analysis "
        "check`.",
        "# `budget` is the analytic ceiling f(probe vars); "
        "`measured_peak_bytes` is the",
        "# ratchet (today's trace, update with `check --update` — it only "
        "goes DOWN).",
        "",
    ]
    for name in sorted(manifest):
        lines.append(f"[{name}]")
        entry = manifest[name]
        for key in sorted(entry, key=lambda k: (k.startswith("probe_"), k)):
            lines.append(f"{key} = {_emit_value(entry[key])}")
        lines.append("")
    pathlib.Path(path).write_text("\n".join(lines))


# --------------------------------------------------------------------------- #
# probe builders — how to trace each public entry point
# --------------------------------------------------------------------------- #

@dataclasses.dataclass
class Target:
    """One traceable probe: the callable + args (and optionally a lowering
    whose donation attrs the contract verifies)."""

    fn: object
    args: tuple
    lowered: object = None     # () -> jax.stages.Lowered, for donation checks


_KEY = jax.random.PRNGKey(0)


def _dense_K(n: int):
    from repro.core.kernel_op import KernelOperator

    X = jax.random.uniform(jax.random.PRNGKey(1), (n, 4))
    return KernelOperator(X, "gaussian", bandwidth=0.6).dense()


def _operator_X(n: int, p: int):
    return jax.random.uniform(jax.random.PRNGKey(1), (n, p))


def _build_sketch_both(probe):
    from repro.core import apply as A
    from repro.core.sketch import make_accum_sketch

    n, d, m = probe["n"], probe["d"], probe["m"]
    K = _dense_K(n)
    sk = make_accum_sketch(_KEY, n, d, m)
    return Target(lambda K: A.sketch_both(K, sk, use_kernel=True), (K,))


def _build_accum_grow_batched(probe):
    from repro.core import apply as A

    n, d, B = probe["n"], probe["d"], probe["B"]
    K = _dense_K(n)
    state = A.accum_init(_KEY, n, d, B)
    return Target(
        lambda K, s: A.accum_grow_batched(K, s, B, use_kernel=True),
        (K, state),
        lowered=lambda: A._grow_batched_donated.lower(K, state, B, False),
    )


def _build_grow_sketch_both(probe):
    from repro.core import apply as A
    from repro.core.kernel_op import KernelOperator

    n, p, d, m_max = probe["n"], probe["p"], probe["d"], probe["m_max"]
    X = _operator_X(n, p)
    return Target(
        lambda X: A.grow_sketch_both(
            _KEY, KernelOperator(X, "gaussian", bandwidth=0.6), d,
            m_max=m_max, tol=0.5, use_kernel=False),
        (X,),
    )


def _build_krr_fit(probe):
    from repro.core.krr import krr_sketched_fit
    from repro.core.sketch import make_accum_sketch

    n, d, m = probe["n"], probe["d"], probe["m"]
    K = _dense_K(n)
    y = jnp.zeros((n,))
    sk = make_accum_sketch(_KEY, n, d, m)
    return Target(
        lambda K, y: krr_sketched_fit(K, y, 1e-2, sk, use_kernel=True).fitted,
        (K, y),
    )


def _build_krr_fit_matfree(probe):
    from repro.core.kernel_op import KernelOperator
    from repro.core.krr import krr_sketched_fit_matfree
    from repro.core.sketch import make_accum_sketch

    n, p, d, m = probe["n"], probe["p"], probe["d"], probe["m"]
    X = _operator_X(n, p)
    y = jnp.zeros((n,))
    sk = make_accum_sketch(_KEY, n, d, m)
    return Target(
        lambda X, y: krr_sketched_fit_matfree(
            KernelOperator(X, "gaussian", bandwidth=0.6), y, 1e-2, sk,
            use_kernel=False).fitted,
        (X, y),
    )


def _build_krr_fit_pcg(probe):
    from repro.core.kernel_op import KernelOperator
    from repro.core.krr import krr_sketched_fit_pcg
    from repro.core.sketch import make_accum_sketch

    n, p, d, m = probe["n"], probe["p"], probe["d"], probe["m"]
    X = _operator_X(n, p)
    y = jnp.zeros((n,))
    sk = make_accum_sketch(_KEY, n, d, m)
    return Target(
        lambda X, y: krr_sketched_fit_pcg(
            KernelOperator(X, "gaussian", bandwidth=0.6), y, 1e-2, sk,
            iters=8, use_kernel=False).fitted,
        (X, y),
    )


def _build_krr_fit_adaptive(probe):
    from repro.core.krr import krr_sketched_fit_adaptive

    n, d, m_max = probe["n"], probe["d"], probe["m_max"]
    K = _dense_K(n)
    y = jnp.zeros((n,))
    return Target(
        lambda K, y: krr_sketched_fit_adaptive(
            K, y, 1e-2, _KEY, d, tol=0.5, m_max=m_max,
            use_kernel=False).fitted,
        (K, y),
    )


def _build_spectral_cluster(probe):
    from repro.core.spectral import spectral_cluster

    n, d, k = probe["n"], probe["d"], probe["k"]
    K = _dense_K(n)
    return Target(
        lambda K: spectral_cluster(_KEY, K, k, d=d, m=probe["m"],
                                   use_kernel=False).labels,
        (K,),
    )


def _serve_setup(probe, use_sketch: bool):
    from repro.configs import ARCHS, reduced
    from repro.models.model import init_params
    from repro.serve.engine import Engine, ServeConfig

    cfg = reduced(ARCHS[probe.get("arch", "stablelm-3b")])
    params = init_params(_KEY, cfg)
    sc = ServeConfig(max_len=probe["L"] + probe.get("steps", 4) + 1,
                     use_sketch=use_sketch, temperature=0.7, seed=0)
    return cfg, params, Engine(cfg, params, sc)


def _build_prefill(probe):
    from repro.models.model import prefill_with_cache

    cfg, params, eng = _serve_setup(probe, use_sketch=True)
    B, L = probe["B"], probe["L"]
    cache = eng.new_cache(B)
    tokens = jnp.zeros((B, L), jnp.int32)
    table = eng._slot_table(L)
    return Target(
        lambda p, c, t: prefill_with_cache(p, t, cfg, c, slot_table=table),
        (params, cache, tokens),
    )


def _build_engine_decode(probe):
    cfg, params, eng = _serve_setup(probe, use_sketch=True)
    B, L, steps = probe["B"], probe["L"], probe["steps"]
    cache = eng.new_cache(B)
    tok0 = jnp.zeros((B,), jnp.int32)
    return Target(
        lambda p, c, t: eng._decode_scan(p, c, t, jnp.int32(L),
                                         n_steps=steps),
        (params, cache, tok0),
    )


def _build_engine_decode_degraded(probe):
    # the exact-attention rung a sketched engine degrades to after its health
    # screen trips: same engine, use_sketch=False override + an exact cache.
    # The contract pins that the degraded path is as clean as the primary one
    # (no host syncs, no pallas, straight RNG lineage).
    cfg, params, eng = _serve_setup(probe, use_sketch=True)
    B, L, steps = probe["B"], probe["L"], probe["steps"]
    cache = eng.new_cache(B, use_sketch=False)
    tok0 = jnp.zeros((B,), jnp.int32)
    return Target(
        lambda p, c, t: eng._decode_scan(p, c, t, jnp.int32(L),
                                         n_steps=steps, use_sketch=False),
        (params, cache, tok0),
    )


def _build_solve_psd_ladder(probe):
    from repro.resilience.degrade import solve_psd_ladder

    d = probe["d"]
    A = jax.random.uniform(jax.random.PRNGKey(1), (d, d))
    M = A @ A.T / d + jnp.eye(d)
    b = jnp.ones((d,))
    return Target(lambda M, b: solve_psd_ladder(M, b), (M, b))


def _build_sharded_sketch_both(probe):
    from repro.core import apply as A
    from repro.core import distributed as D
    from repro.core.kernel_op import KernelOperator
    from repro.core.sketch import make_accum_sketch

    n, p, d, m = probe["n"], probe["p"], probe["d"], probe["m"]
    X = _operator_X(n, p)
    sk = make_accum_sketch(_KEY, n, d, m)
    mesh = D.resolve_mesh(True)
    return Target(
        lambda X: A.sketch_both(
            KernelOperator(X, "gaussian", bandwidth=0.6), sk, mesh=mesh,
            use_kernel=False),
        (X,),
    )


def _build_sharded_grow_sketch_both(probe):
    from repro.core import apply as A
    from repro.core import distributed as D
    from repro.core.kernel_op import KernelOperator

    n, p, d, m_max = probe["n"], probe["p"], probe["d"], probe["m_max"]
    X = _operator_X(n, p)
    mesh = D.resolve_mesh(True)
    return Target(
        lambda X: A.grow_sketch_both(
            _KEY, KernelOperator(X, "gaussian", bandwidth=0.6), d,
            m_max=m_max, tol=None, mesh=mesh, use_kernel=False),
        (X,),
    )


ENTRY_POINTS = {
    "sketch_both": _build_sketch_both,
    "accum_grow_batched": _build_accum_grow_batched,
    "grow_sketch_both": _build_grow_sketch_both,
    "krr_sketched_fit": _build_krr_fit,
    "krr_sketched_fit_matfree": _build_krr_fit_matfree,
    "krr_sketched_fit_pcg": _build_krr_fit_pcg,
    "krr_sketched_fit_adaptive": _build_krr_fit_adaptive,
    "spectral_cluster": _build_spectral_cluster,
    "prefill_with_cache": _build_prefill,
    "engine_decode": _build_engine_decode,
    "engine_decode_degraded": _build_engine_decode_degraded,
    "solve_psd_ladder": _build_solve_psd_ladder,
    "sharded_sketch_both": _build_sharded_sketch_both,
    "sharded_grow_sketch_both": _build_sharded_grow_sketch_both,
}


# --------------------------------------------------------------------------- #
# evaluation
# --------------------------------------------------------------------------- #

@dataclasses.dataclass
class ContractResult:
    """Outcome of evaluating one contract at its probe shape."""

    name: str
    status: str                   # "pass" | "fail" | "skipped"
    violations: list = dataclasses.field(default_factory=list)
    report: dict = dataclasses.field(default_factory=dict)
    measured_peak_bytes: int | None = None

    def to_dict(self) -> dict:
        """JSON-ready form for the CI artifact."""
        return dataclasses.asdict(self)


def _probe_vars(entry: dict) -> dict:
    return {k[len("probe_"):]: v for k, v in entry.items()
            if k.startswith("probe_")}


def evaluate_contract(name: str, entry: dict) -> ContractResult:
    """Trace one entry point at its probe shape and check every budget."""
    devices = int(entry.get("devices", 1))
    if jax.device_count() < devices:
        return ContractResult(
            name, "skipped",
            report={"reason": f"needs {devices} devices, "
                              f"have {jax.device_count()}"})
    builder = ENTRY_POINTS.get(name)
    if builder is None:
        return ContractResult(
            name, "fail",
            violations=[f"no probe builder registered for {name!r} "
                        "(add one to repro.analysis.contracts.ENTRY_POINTS)"])
    probe = _probe_vars(entry)
    target = builder(probe)
    closed = jax.make_jaxpr(target.fn)(*target.args)
    rep = trace_mod.report_from_jaxpr(closed)

    violations: list[str] = []
    # 1) analytic peak-bytes budget
    budget = entry.get("budget")
    if budget is not None:
        limit = eval_budget(str(budget), probe)
        if rep.peak_bytes > limit:
            violations.append(
                f"peak intermediate {rep.peak_bytes} B (shape "
                f"{rep.peak_shape}, {rep.peak_dtype}) exceeds budget "
                f"{limit} B = {budget!r}")
    # 2) measured ratchet
    ratchet = entry.get("measured_peak_bytes")
    if ratchet is not None and rep.peak_bytes > int(ratchet):
        violations.append(
            f"peak intermediate {rep.peak_bytes} B regressed above the "
            f"ratchet {ratchet} B (shape {rep.peak_shape}; if intentional, "
            "rerun `python -m repro.analysis check --update` and justify "
            "the increase in the PR)")
    # 3) exact pallas dispatch count
    expected_pallas = entry.get("pallas_calls")
    if expected_pallas is not None and rep.pallas_calls != int(expected_pallas):
        violations.append(
            f"pallas_call count {rep.pallas_calls} != contracted "
            f"{expected_pallas}")
    # 4) forbidden primitives (host syncs by default)
    forbid = entry.get("forbid")
    if forbid is None:
        forbid = sorted(trace_mod.HOST_CALLBACK_PRIMITIVES)
    found = rep.forbidden(forbid)
    if found:
        violations.append(f"forbidden primitives in trace: {found}")
    # 5) donation really lowered
    if entry.get("donation"):
        if target.lowered is None:
            violations.append("contract sets donation=true but the probe "
                              "builder provides no lowering")
        elif not trace_mod.verify_donation(target.lowered()):
            violations.append(
                "declared donation did not lower: no "
                "jax.buffer_donor/tf.aliasing_output attr in the lowered "
                "module (dropped donate_argnums?)")
    # 6) RNG lineage
    rng_issues: list[str] = []
    if entry.get("rng"):
        rng_rep = rng_mod.report_from_jaxpr(closed)
        rng_issues = [str(i) for i in rng_rep.issues]
        violations.extend(rng_issues)

    return ContractResult(
        name,
        "fail" if violations else "pass",
        violations=violations,
        report={**rep.to_dict(), "rng_issues": rng_issues, "probe": probe},
        measured_peak_bytes=rep.peak_bytes,
    )


def run_check(manifest: dict | None = None, *, only: str | None = None,
              update: bool = False,
              path: pathlib.Path | str = CONTRACTS_PATH):
    """Evaluate every contract (plus the fold_in sweep); returns
    (results, sweep_violations, manifest).  With ``update=True`` the
    measured peaks are ratcheted DOWN into the manifest and written back."""
    if manifest is None:
        manifest = load_manifest(path)
    results = []
    for name, entry in sorted(manifest.items()):
        if only is not None and name != only:
            continue
        res = evaluate_contract(name, entry)
        results.append(res)
        measured = res.measured_peak_bytes
        if update and res.status != "skipped" and measured is not None:
            prev = entry.get("measured_peak_bytes")
            if prev is None or measured < int(prev):
                entry["measured_peak_bytes"] = measured
            # an upward move is NOT written — the ratchet only descends;
            # raising a budget is a reviewed manifest edit, not an --update
    sweep = rng_mod.check_fold_in_sites() if only is None else []
    if update:
        dump_manifest(manifest, path)
    return results, sweep, manifest
