"""CLI gate: ``python -m repro.analysis check``.

Evaluates every contract in ``contracts.toml`` at its pinned probe shape and
runs the source-level `fold_in` sweep; exits non-zero on any violation.

  check                 evaluate all contracts + the fold_in sweep
  check --only NAME     one contract (sweep skipped)
  check --update        re-measure and ratchet `measured_peak_bytes` DOWN
  check --json PATH     write the full JSON report (the CI artifact)
"""
from __future__ import annotations

import argparse
import json
import sys


def _cmd_check(args) -> int:
    from repro.analysis import contracts as C

    results, sweep, _ = C.run_check(only=args.only, update=args.update)
    failed = 0
    for res in results:
        mark = {"pass": "ok  ", "skipped": "skip", "fail": "FAIL"}[res.status]
        peak = res.measured_peak_bytes
        if peak is not None:
            extra = f"  peak={peak}B"
        else:
            extra = f"  ({res.report.get('reason', '')})"
        print(f"[{mark}] {res.name}{extra}")
        for v in res.violations:
            print(f"       - {v}")
        failed += res.status == "fail"
    if args.only is None:
        bad_sites = [s for s in sweep]
        if bad_sites:
            print(f"[FAIL] fold_in sweep: {len(bad_sites)} unregistered "
                  "site(s)")
            for s in bad_sites:
                print(f"       - {s.path}:{s.lineno}: {s.source.strip()}")
                print("         register a stream in repro.analysis.streams "
                      "(tag constant or `# rng-stream:` marker)")
            failed += 1
        else:
            print("[ok  ] fold_in sweep: every site registered")
    if args.json:
        payload = {
            "results": [r.to_dict() for r in results],
            "fold_in_violations": [
                {"path": str(s.path), "lineno": s.lineno,
                 "source": s.source.strip()}
                for s in sweep
            ],
            "failed": failed,
        }
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2, default=str)
        print(f"report written to {args.json}")
    if failed:
        print(f"{failed} violation group(s); see above. "
              "(`--update` only ratchets budgets DOWN — raising one is a "
              "reviewed edit to contracts.toml.)")
    return 1 if failed else 0


def main(argv=None) -> int:
    """Entry point for ``python -m repro.analysis``."""
    parser = argparse.ArgumentParser(prog="python -m repro.analysis")
    sub = parser.add_subparsers(dest="cmd", required=True)
    chk = sub.add_parser("check", help="evaluate all trace contracts")
    chk.add_argument("--only", default=None, metavar="NAME",
                     help="evaluate a single contract")
    chk.add_argument("--update", action="store_true",
                     help="ratchet measured peaks downward into the manifest")
    chk.add_argument("--json", default=None, metavar="PATH",
                     help="write the JSON report artifact")
    args = parser.parse_args(argv)
    return _cmd_check(args)


if __name__ == "__main__":
    sys.exit(main())
