"""One hardware model shared by the roofline and the trace-contract analyzer.

`launch/analysis.py` used to hardcode TPU v5e peak numbers at module scope, so
roofline terms and any other consumer of chip constants drifted independently.
This dataclass is the single source of truth: the roofline divides by its
bandwidths, and `repro.analysis` contracts can express budgets relative to the
same chip (e.g. "this entry point must stay under one HBM's worth of
intermediates").  Override per call site (`HardwareModel(peak_flops=...)`) or
swap the default with `set_default_hardware` — module-scope constants are
gone.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """Per-chip peak numbers used for roofline terms and trace budgets.

    Defaults describe a TPU v5e-class chip: bf16 matmul peak, HBM bandwidth,
    and per-link ICI bandwidth.  All consumers take an instance (defaulting to
    `DEFAULT_HARDWARE`) instead of reading module constants, so a v5p/v6e/GPU
    profile is one constructor call away.
    """

    name: str = "tpu-v5e"
    peak_flops: float = 197e12      # bf16 FLOP/s per chip
    hbm_bw: float = 819e9           # B/s per chip
    ici_bw: float = 50e9            # B/s per link
    hbm_bytes: float = 16e9         # HBM capacity per chip
    vmem_bytes: float = 128e6       # on-chip vector memory


TPU_V5E = HardwareModel()

DEFAULT_HARDWARE = TPU_V5E


def get_default_hardware() -> HardwareModel:
    """The process-wide default chip profile (used when no override is passed)."""
    return DEFAULT_HARDWARE


def set_default_hardware(hw: HardwareModel) -> HardwareModel:
    """Swap the process-wide default chip profile; returns the previous one."""
    global DEFAULT_HARDWARE
    prev = DEFAULT_HARDWARE
    DEFAULT_HARDWARE = hw
    return prev
