"""Jaxpr walker producing a `TraceReport`: the trace-contract analyzer's core.

The paper's value proposition is keeping the *effective matrix size* small;
the invariants that guarantee it — no O(n²) intermediates, one K-pass per
batch, O(1)-in-chunks jaxprs, donated growth buffers — are properties of the
*traced program*, not of any particular run.  This module walks a
`ClosedJaxpr` (recursing into `scan` / `while` / `cond` / `pjit` /
`pallas_call` sub-jaxprs, with trip-count multipliers for loops — the same
trick `repro.launch.analysis` plays on compiled HLO) and reports:

  * peak intermediate size (bytes and elements) — the no-quadratic-buffer rule;
  * a per-dtype buffer census (how many distinct buffers, total bytes);
  * dot/conv FLOPs, trip-count corrected;
  * `pallas_call` counts — static (call sites in the trace) and dispatched
    (× loop trip counts) — the one-K-pass-per-batch rule;
  * host-sync detection (`pure_callback` / `io_callback` / `debug_callback`):
    anything that forces the device to round-trip through Python;
  * donation verification against the *lowered* text (the jaxpr carries no
    donation info — only lowering does; see `verify_donation`).

The three hand-rolled walkers this library replaced
(`tests/test_grow_batched.py`, `tests/test_kernels.py`,
`tests/test_matfree.py`) live on as the compat helpers
`count_pallas_calls` / `max_intermediate_elems` / `all_shapes`, with one
planted positive control per test file proving the library still catches the
regression each hand-rolled copy was written for.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.analysis.hlo import donation_attrs_present

# Primitives that force a host round-trip (device blocks on Python).  The
# serving and fit hot paths must never contain one — a single callback turns
# a one-dispatch design back into a host-synced loop.
HOST_CALLBACK_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "infeed", "outfeed",
})

# Value-movement primitives whose output aliases/reshapes the input — not
# "real" intermediates for the dtype census (they'd double-count buffers).
_VIEW_PRIMITIVES = frozenset({
    "reshape", "squeeze", "broadcast_in_dim", "convert_element_type",
    "transpose", "bitcast_convert_type",
})


def _as_jaxpr(j):
    """Accept a Jaxpr, ClosedJaxpr, or anything wrapping one (duck-typed)."""
    if hasattr(j, "eqns"):
        return j
    if hasattr(j, "jaxpr"):
        return _as_jaxpr(j.jaxpr)
    raise TypeError(f"not a jaxpr: {type(j)!r}")


def _aval_elems(aval) -> int:
    shape = getattr(aval, "shape", None)
    if shape is None:
        return 0
    return int(np.prod(shape, dtype=np.int64)) if shape else 1


def _aval_bytes(aval) -> int:
    dtype = getattr(aval, "dtype", None)
    if dtype is None:
        return 0
    itemsize = getattr(dtype, "itemsize", None)
    if itemsize is None:        # extended dtypes (PRNG keys): 4-byte words
        itemsize = 4
    return _aval_elems(aval) * int(itemsize)


def _while_trip_count(eqn) -> float:
    """Largest integer literal in the loop condition — the `while`-loop
    trip-count trick from `launch/analysis.py`, transplanted from HLO text to
    the jaxpr: `fori_loop`/bounded `while_loop` conditions compare the
    counter against the (constant) bound, so the max literal IS the bound."""
    cond = eqn.params.get("cond_jaxpr")
    best = 1
    if cond is not None:
        closed = cond if hasattr(cond, "consts") else None
        inner = _as_jaxpr(cond)
        for ceqn in inner.eqns:
            for v in ceqn.invars:
                val = getattr(v, "val", None)
                if val is not None and np.ndim(val) == 0:
                    try:
                        iv = int(val)
                    except (TypeError, ValueError):
                        continue
                    best = max(best, iv)
        if closed is not None:
            for const in closed.consts:
                if np.ndim(const) == 0:
                    try:
                        best = max(best, int(const))
                    except (TypeError, ValueError):
                        pass
    return float(best)


def _sub_jaxprs(eqn) -> list[tuple[object, float]]:
    """(sub_jaxpr, multiplier) pairs for one eqn.  Loop bodies carry their
    trip count; branches and calls carry 1 (conservative: every branch of a
    `cond` is charged as if taken)."""
    name = eqn.primitive.name
    if name == "scan":
        length = float(eqn.params.get("length", 1) or 1)
        return [(eqn.params["jaxpr"], length)]
    if name == "while":
        trips = _while_trip_count(eqn)
        out = []
        if "cond_jaxpr" in eqn.params:
            out.append((eqn.params["cond_jaxpr"], trips))
        if "body_jaxpr" in eqn.params:
            out.append((eqn.params["body_jaxpr"], trips))
        return out
    # generic: anything in params that walks like a jaxpr (pjit, cond
    # branches, pallas_call, custom_jvp/vjp, remat, shard_map, ...)
    out = []
    for param in eqn.params.values():
        subs = param if isinstance(param, (tuple, list)) else (param,)
        for sub in subs:
            if hasattr(sub, "eqns") or hasattr(sub, "jaxpr"):
                out.append((sub, 1.0))
    return out


def _dot_flops(eqn) -> float:
    """2 · |out| · |contraction| for dot_general / conv (conv approximated
    by kernel-volume per output element; no conv in this repo's hot paths)."""
    name = eqn.primitive.name
    if name == "dot_general":
        out_elems = sum(_aval_elems(v.aval) for v in eqn.outvars)
        (lhs_c, _), _ = eqn.params["dimension_numbers"]
        lhs_shape = getattr(eqn.invars[0].aval, "shape", ())
        contract = 1
        for i in lhs_c:
            if i < len(lhs_shape):
                contract *= int(lhs_shape[i])
        return 2.0 * out_elems * contract
    if name == "conv_general_dilated":
        out_elems = sum(_aval_elems(v.aval) for v in eqn.outvars)
        rhs = getattr(eqn.invars[1].aval, "shape", ())
        out_shape = getattr(eqn.outvars[0].aval, "shape", ())
        kern = int(np.prod(rhs, dtype=np.int64)) if rhs else 1
        out_ch = int(out_shape[-1]) if out_shape else 1
        return 2.0 * out_elems * max(kern // max(out_ch, 1), 1)
    return 0.0


@dataclasses.dataclass
class TraceReport:
    """What the analyzer saw in one traced program.

    `peak_bytes`/`peak_elems` are the largest single intermediate bound
    anywhere in the program (max over loop iterations — a buffer inside a
    scan is the same buffer each step).  `flops` and `pallas_dispatches` are
    trip-count corrected; `pallas_calls` and `primitives` are static counts
    over the trace.  `host_callbacks` lists every host-sync primitive found
    (empty on a clean device-resident program).
    """

    peak_bytes: int = 0
    peak_elems: int = 0
    peak_shape: tuple = ()
    peak_dtype: str = ""
    dtype_census: dict = dataclasses.field(default_factory=dict)
    flops: float = 0.0
    pallas_calls: int = 0
    pallas_dispatches: float = 0.0
    host_callbacks: list = dataclasses.field(default_factory=list)
    primitives: dict = dataclasses.field(default_factory=dict)
    eqn_count: int = 0

    def forbidden(self, names) -> list[str]:
        """Which of `names` (primitive names) appear in the trace."""
        return sorted(n for n in names if self.primitives.get(n, 0) > 0)

    def to_dict(self) -> dict:
        """JSON-ready form (the CI artifact and `--json` output)."""
        d = dataclasses.asdict(self)
        d["peak_shape"] = list(self.peak_shape)
        return d


def _walk(jaxpr, mult: float, report: TraceReport) -> None:
    jaxpr = _as_jaxpr(jaxpr)
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        report.eqn_count += 1
        report.primitives[name] = report.primitives.get(name, 0) + 1
        report.flops += _dot_flops(eqn) * mult
        if name == "pallas_call":
            report.pallas_calls += 1
            report.pallas_dispatches += mult
        if name in HOST_CALLBACK_PRIMITIVES:
            report.host_callbacks.append(name)
        for v in tuple(eqn.invars) + tuple(eqn.outvars):
            aval = getattr(v, "aval", None)
            if aval is None or getattr(aval, "shape", None) is None:
                continue
            elems = _aval_elems(aval)
            nbytes = _aval_bytes(aval)
            if nbytes > report.peak_bytes or (
                nbytes == report.peak_bytes and elems > report.peak_elems
            ):
                report.peak_bytes = nbytes
                report.peak_elems = elems
                report.peak_shape = tuple(aval.shape)
                report.peak_dtype = str(aval.dtype)
            report.peak_elems = max(report.peak_elems, elems)
        # census: OUTPUT buffers only (each produced value counted once),
        # views excluded so reshape chains don't double-count
        if name not in _VIEW_PRIMITIVES:
            for v in eqn.outvars:
                aval = getattr(v, "aval", None)
                if aval is None or getattr(aval, "shape", None) is None:
                    continue
                key = str(getattr(aval, "dtype", "?"))
                slot = report.dtype_census.setdefault(
                    key, {"buffers": 0, "bytes": 0})
                slot["buffers"] += 1
                slot["bytes"] += _aval_bytes(aval)
        for sub, factor in _sub_jaxprs(eqn):
            _walk(sub, mult * factor, report)


def report_from_jaxpr(jaxpr) -> TraceReport:
    """Walk an already-traced Jaxpr/ClosedJaxpr into a `TraceReport`."""
    report = TraceReport()
    _walk(jaxpr, 1.0, report)
    return report


def trace_report(fn, *args, **kwargs) -> TraceReport:
    """Trace `fn(*args, **kwargs)` with `jax.make_jaxpr` and analyze it."""
    closed = jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)
    return report_from_jaxpr(closed)


# --------------------------------------------------------------------------- #
# compat helpers — the three hand-rolled test walkers, now library calls
# --------------------------------------------------------------------------- #

def count_pallas_calls(jaxpr) -> int:
    """Static `pallas_call` count, recursing into every sub-jaxpr.

    This is the one-K-pass-per-batch detector: a batched growth trace binds
    ONE pallas_call where B sequential steps bind B.
    """
    return report_from_jaxpr(jaxpr).pallas_calls


def max_intermediate_elems(jaxpr) -> int:
    """Largest array (element count) bound anywhere in the traced program.

    The no-quadratic-buffer detector: the matrix-free paths must never bind
    a buffer within an order of magnitude of n² (scalars count as 1).
    """
    return report_from_jaxpr(jaxpr).peak_elems


def peak_intermediate_bytes(jaxpr) -> int:
    """Largest single intermediate in BYTES (dtype-aware `max_intermediate_elems`)."""
    return report_from_jaxpr(jaxpr).peak_bytes


def all_shapes(jaxpr) -> set:
    """Every distinct array shape bound in the trace (recursive).

    `tests/test_kernels.py`'s detector for layout regressions: e.g. the left
    sketch kernel must never bind a transposed (c, N) copy of its input.
    """
    shapes: set = set()

    def walk(j):
        j = _as_jaxpr(j)
        for eqn in j.eqns:
            for v in tuple(eqn.invars) + tuple(eqn.outvars):
                aval = getattr(v, "aval", None)
                shape = getattr(aval, "shape", None)
                if shape is not None:
                    shapes.add(tuple(shape))
            for sub, _ in _sub_jaxprs(eqn):
                walk(sub)

    walk(jaxpr)
    return shapes


def verify_donation(lowered) -> bool:
    """True if a lowered computation really advertises buffer donation.

    Accepts a `jax.stages.Lowered` (or anything with `.as_text()`) or the
    lowered text itself.  A wrapper that declares `donate_argnums` but whose
    lowering lost the aliasing (captured args, donation under an outer trace)
    returns False — the dropped-donation bug class.
    """
    text = lowered if isinstance(lowered, str) else lowered.as_text()
    return donation_attrs_present(text)
