"""Shared HLO-text parsing helpers.

Extracted from `repro.launch.analysis` so the roofline extractor and the
trace-contract analyzer read compiled artifacts through one parser: dtype
byte widths, shape-string parsing, and donation-annotation detection.  The
roofline's full `HloModule` walker stays in `launch/analysis.py` (it is
roofline-specific); everything both layers need lives here.
"""
from __future__ import annotations

import re

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

# XLA spells input-output aliasing differently across versions/backends; a
# donated argument shows up as either attribute in the lowered StableHLO/HLO
# text.  (The jaxpr itself carries no donation info — only lowering does.)
DONATION_ATTRS = ("jax.buffer_donor", "tf.aliasing_output")


def shape_dims(type_str: str) -> list[tuple[str, list[int]]]:
    """All (dtype, dims) groups in an HLO type string (handles tuples)."""
    out = []
    for dt, dims in SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",") if x.strip()]
        out.append((dt, d))
    return out


def shape_bytes(type_str: str) -> int:
    """Total byte size of every shape group in an HLO type string."""
    total = 0
    for dt, dims in shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * DTYPE_BYTES[dt]
    return total


def donation_attrs_present(lowered_text: str) -> bool:
    """True if the lowered module advertises ANY input-output buffer aliasing.

    This is the machine-checkable form of "`donate_argnums` actually took":
    a jitted wrapper that declares donation but drops it (e.g. because the
    arguments were captured instead of passed) lowers with neither attribute.
    """
    return any(attr in lowered_text for attr in DONATION_ATTRS)
