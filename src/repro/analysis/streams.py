"""Registry of named RNG streams: every `fold_in` in `src/repro` is accounted for.

PR 8 shipped the bug class this registry exists to kill: two independent
consumers (decode slot draws and temperature sampling) both derived their
per-position key as `fold_in(key, pos)` — identical streams, correlated
draws.  The fix is *tagged* streams (`fold_in(fold_in(key, TAG), pos)`), but
a fix without a gate regresses: the AST sweep in `repro.analysis.rng`
inventories every `fold_in` call site under `src/repro` and requires each to
be either

  * inline-tagged — the fold data is one of the registered tag constants
    below (by name or by value, `TAG + phase` offsets included), or
  * marked — the call line (or the line above the statement) carries a
    ``# rng-stream: <name>`` comment naming a registered stream, for
    counter-folds (`fold_in(key, step)`) whose independence comes from an
    upstream tagging fold or from a structurally disjoint base key.

Adding a `fold_in` without registering it fails `python -m repro.analysis
check`.  Changing any tag VALUE changes draw distributions — that is a seed
break and must be called out in CHANGES.md (the bitwise-equivalence tests
pin the current values).

This module is deliberately import-light (no jax): `core/` and `serve/`
import their tag constants from here.
"""
from __future__ import annotations

import dataclasses

# ----------------------------- tag constants ------------------------------ #
# Values are part of the seed contract: changing one is a seed break.

#: Engine slot-draw stream (sketched decode cache placement).  PR 8 value.
SLOT_STREAM = 0x510C

#: Engine temperature-sampling stream.  PR 8 value.
SAMPLE_STREAM = 0x5A3E

#: Holdout-estimator row draws in the adaptive growth drivers.  PR 7 value
#: (was the inline literal 0x5E1D in `core/apply.py` / `core/distributed.py`).
HOLDOUT_STREAM = 0x5E1D

#: Leverage-refinement redraw base; phase ``i`` folds ``REFINE_STREAM + i``.
#: PR 7 value (was the inline literal 0x11E7 in `core/apply.py`).
REFINE_STREAM = 0x11E7


@dataclasses.dataclass(frozen=True)
class Stream:
    """One named RNG stream: a tag constant, or a documented counter fold."""

    name: str
    tag: int | None
    doc: str


#: name → Stream.  Tagged streams carry their fold constant; counter streams
#: (tag None) are position/step folds whose independence is documented here
#: and enforced structurally (upstream tagging fold or disjoint base key).
REGISTRY: dict[str, Stream] = {
    s.name: s
    for s in (
        Stream("serve-slots", SLOT_STREAM,
               "Engine slot draws: fold_in(key, SLOT_STREAM) once at engine "
               "init; per-position folds ride the tagged key."),
        Stream("serve-sample", SAMPLE_STREAM,
               "Engine temperature sampling: fold_in(key, SAMPLE_STREAM) at "
               "init, then per-position folds."),
        Stream("holdout", HOLDOUT_STREAM,
               "Holdout-estimator draws in grow_sketch_both and the sharded "
               "twin — disjoint from the slab index draws off the same key."),
        Stream("refine", REFINE_STREAM,
               "Leverage tail-refresh redraws: phase i folds REFINE_STREAM+i "
               "so refreshes never collide with slab or holdout draws."),
        Stream("slot-position", None,
               "decode_slots/decode_slot_table: fold_in(key, step). The key "
               "is the engine's SLOT_STREAM-tagged key (or a caller-owned "
               "key in tests); the step fold alone is the per-position "
               "stream."),
        Stream("sample-position", None,
               "Engine._sample: fold_in(sample_key, pos) — sample_key is the "
               "SAMPLE_STREAM-tagged key, so positions are independent of "
               "the slot draws at the same pos."),
        Stream("kmeanspp-iter", None,
               "k-means++ seeding: fold_in(key, i) per center. The base key "
               "is private to kmeans (split from the caller's key), so the "
               "counter fold cannot collide with another stream."),
        Stream("data-step-host", None,
               "Synthetic data pipeline: fold_in(fold_in(PRNGKey(seed), "
               "step), host_id) — the nested fold separates hosts within a "
               "step; the base key is derived from the data seed, not shared "
               "with model/serve streams."),
        Stream("compress-step-leaf", None,
               "Gradient-compression sketches: fold_in(fold_in(key, step), "
               "i) — per-step, per-leaf resample; key is the optimizer's "
               "private compression key."),
        Stream("init-block", None,
               "Parameter init: fold_in(keys[2], i) per superblock position; "
               "keys[2] comes from a split, so block streams are disjoint "
               "from embed/head init."),
    )
}

#: Identifier → stream name: the spellings the AST sweep accepts as inline
#: tags (module-local aliases with a leading underscore included).
TAG_CONSTANT_TO_STREAM = {
    "SLOT_STREAM": "serve-slots", "_SLOT_STREAM": "serve-slots",
    "SAMPLE_STREAM": "serve-sample", "_SAMPLE_STREAM": "serve-sample",
    "HOLDOUT_STREAM": "holdout", "_HOLDOUT_STREAM": "holdout",
    "REFINE_STREAM": "refine", "_REFINE_STREAM": "refine",
}

TAG_CONSTANT_NAMES = frozenset(TAG_CONSTANT_TO_STREAM)

#: Registered tag values (for literal-tag call sites).
TAG_VALUES = frozenset(s.tag for s in REGISTRY.values() if s.tag is not None)


def stream_for_tag(value: int) -> Stream | None:
    """The registered stream carrying tag `value`, if any."""
    for s in REGISTRY.values():
        if s.tag == value:
            return s
    return None
