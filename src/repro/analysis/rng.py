"""RNG-lineage checking: the PR 8 correlated-streams bug class, machine-checked.

Two layers:

* `rng_report` — a dataflow checker over a traced jaxpr.  PRNG keys are
  value-numbered structurally (`random_wrap` / `random_fold_in` /
  `random_split` build canonical tokens, so two `fold_in(key, pos)` calls
  with the same parent and the same position operand produce the SAME
  canonical key — exactly how the PR 8 bug looked in the trace).  A canonical
  key consumed by two independent sampling sites without an intervening
  split/fold is flagged (`reused-key`), as is a loop-invariant key consumed
  inside a scan/while body (`loop-reuse`: every iteration would redraw the
  same numbers).

* `sweep_fold_in_sites` — a source-level (AST) sweep that inventories every
  `fold_in` call under `src/repro` and requires each to carry a registered
  stream tag (`repro.analysis.streams`): an inline tag constant, or a
  ``# rng-stream: <name>`` marker for counter-folds whose independence comes
  from an upstream tagging fold.  New unregistered `fold_in` sites fail
  `python -m repro.analysis check`.

The subsampling literature (arXiv:2105.01552, arXiv:2205.08588) is explicit
that draw independence and inclusion-probability bookkeeping are
correctness-critical for the estimators this repo ships — stream hygiene is
not a style rule here.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
import re

import jax
import numpy as np

from repro.analysis import streams as streams_mod

try:
    from jax.extend.core import Literal as _JaxLiteral
except ImportError:                                    # older jax
    from jax.core import Literal as _JaxLiteral
_LITERAL_TYPES = (_JaxLiteral,)

# --------------------------------------------------------------------------- #
# jaxpr lineage checker
# --------------------------------------------------------------------------- #

# primitives that DERIVE fresh keys / move key values without consuming them
_DERIVE = frozenset({
    "random_wrap", "random_unwrap", "random_fold_in", "random_split",
    "random_clone", "copy",
})
_KEY_VIEW = frozenset({
    "slice", "dynamic_slice", "squeeze", "reshape", "broadcast_in_dim",
    "gather", "transpose", "concatenate",
})

#: sentinel site: inside a sampling-wrapper boundary (consumption already
#: recorded at the wrapper eqn; inner extractions are the same logical draw)
_SUPPRESS = object()


def _is_key_aval(aval) -> bool:
    dtype = getattr(aval, "dtype", None)
    if dtype is None:
        return False
    try:
        return jax.dtypes.issubdtype(dtype, jax.dtypes.prng_key)
    except TypeError:
        return False


@dataclasses.dataclass(frozen=True)
class _Canon:
    """Canonical value token + loop-variance taint."""

    token: tuple
    varies: bool = False


def _lit_canon(val) -> _Canon:
    if np.ndim(val) == 0:
        try:
            return _Canon(("lit", val.item() if hasattr(val, "item") else val))
        except (TypeError, ValueError):
            pass
    return _Canon(("lit-arr", id(val)))


@dataclasses.dataclass
class RngIssue:
    """One lineage violation found in a traced program."""

    kind: str            # "reused-key" | "loop-reuse"
    key: str             # canonical token (human-readable repr)
    sites: list[str]     # consuming call sites (jax-internal wrapper names)
    detail: str

    def __str__(self):
        return f"[{self.kind}] {self.detail}"


@dataclasses.dataclass
class RngReport:
    """All consumptions seen plus the violations derived from them."""

    issues: list = dataclasses.field(default_factory=list)
    consumptions: int = 0
    keys_seen: int = 0

    @property
    def ok(self) -> bool:
        """True when no lineage violation was found."""
        return not self.issues


class _Lineage:
    def __init__(self):
        # canonical key -> {site_id: site_name}; site = outermost jax-internal
        # sampling wrapper (pjit whose name starts with "_") or the bits eqn
        self.consumers: dict[tuple, dict[int, str]] = {}
        self.loop_hits: dict[tuple, str] = {}
        self.n_consumptions = 0
        self.key_tokens: set = set()

    def consume(self, canon: _Canon, site_id: int, site_name: str, mult: float):
        """Record `canon` being drawn from at one sampling site."""
        self.n_consumptions += 1
        self.consumers.setdefault(canon.token, {})[site_id] = site_name
        if mult > 1.0 and not canon.varies:
            self.loop_hits.setdefault(canon.token, site_name)

    def _walk(self, jaxpr, env: dict, mult: float, site):
        jaxpr = _as_open(jaxpr)
        for eqn in jaxpr.eqns:

            def canon_of(v):
                if isinstance(v, _LITERAL_TYPES):
                    return _lit_canon(v.val)
                if v in env:
                    return env[v]
                c = _Canon(("free", id(v)))
                env[v] = c
                return c

            name = eqn.primitive.name
            ins = [canon_of(v) for v in eqn.invars]
            varies = any(c.varies for c in ins)

            if name in ("random_wrap", "random_unwrap", "random_fold_in",
                        "random_split", "random_bits") and site is _SUPPRESS:
                # inside a sampling-wrapper boundary: derivations/extractions
                # are implementation detail of ONE logical draw (randint
                # splits its key; choice shuffles) — already recorded at the
                # boundary, so only propagate canon tokens here
                tok = ("inner", ins[0].token if ins else (), name, id(eqn))
                for ov in eqn.outvars:
                    env[ov] = _Canon(tok, varies)
                continue
            if name == "random_wrap":
                tok = ins[0].token
                if tok[0] == "unwrap":
                    out = _Canon(tok[1], varies)
                else:
                    out = _Canon(("wrap", tok), varies)
                env[eqn.outvars[0]] = out
            elif name == "random_unwrap":
                tok = ins[0].token
                if tok[0] == "wrap":
                    out = _Canon(tok[1], varies)
                else:
                    out = _Canon(("unwrap", tok), varies)
                env[eqn.outvars[0]] = out
            elif name == "random_fold_in":
                out = _Canon(("fold", ins[0].token, ins[1].token), varies)
                env[eqn.outvars[0]] = out
                self.key_tokens.add(out.token)
            elif name == "random_split":
                out = _Canon(("split", ins[0].token,
                              str(eqn.params.get("shape"))), varies)
                env[eqn.outvars[0]] = out
            elif name == "random_bits":
                self.consume(ins[0], id(eqn), "random_bits", mult)
                self.key_tokens.add(ins[0].token)
                for ov in eqn.outvars:
                    env[ov] = _Canon(("bits", ins[0].token), varies)
            else:
                subs = _call_subs(eqn)
                if subs:
                    for sub, factor, invar_map, out_map, sub_site in subs:
                        nxt_site = site
                        if sub_site is not None and site is not _SUPPRESS:
                            # a jax-internal sampling wrapper (_uniform,
                            # _randint, _choice, ...) consumes its key
                            # operands HERE — everything inside is one draw
                            for i, v in enumerate(eqn.invars):
                                if _is_key_aval(getattr(v, "aval", None)):
                                    self.consume(ins[i], id(eqn), sub_site,
                                                 mult)
                                    self.key_tokens.add(ins[i].token)
                            nxt_site = _SUPPRESS
                        sub_env = {}
                        for sub_v, outer_idx, force_vary in invar_map:
                            base = (ins[outer_idx] if outer_idx < len(ins)
                                    else _Canon(("pad", outer_idx)))
                            if force_vary:
                                base = _Canon(("loopvar", base.token),
                                              True)
                            sub_env[sub_v] = base
                        self._walk(sub, sub_env, mult * factor, nxt_site)
                        for sub_out, outer_out in out_map:
                            env[outer_out] = sub_env.get(
                                sub_out, _Canon(("out", id(outer_out))))
                    continue
                # structural value-numbering for plain ops (so fold data like
                # `pos + 1` canonicalizes); key-typed operands hitting a
                # non-derive primitive count as consumption
                for i, v in enumerate(eqn.invars):
                    aval = getattr(v, "aval", None)
                    if (_is_key_aval(aval) and name not in _DERIVE
                            and name not in _KEY_VIEW
                            and site is not _SUPPRESS):
                        self.consume(ins[i], id(eqn), name, mult)
                tok = ("prim", name,
                       tuple(c.token for c in ins), _params_key(eqn.params))
                for j, ov in enumerate(eqn.outvars):
                    env[ov] = _Canon(tok + (j,), varies)

    def issues(self) -> list[RngIssue]:
        """Materialize reused-key / loop-reuse findings from the lineage."""
        out = []
        for tok, sites in self.consumers.items():
            if len(sites) >= 2:
                out.append(RngIssue(
                    kind="reused-key",
                    key=repr(tok),
                    sites=sorted(set(sites.values())),
                    detail=(
                        f"key {tok!r} consumed by {len(sites)} independent "
                        f"sampling sites ({sorted(set(sites.values()))}) "
                        "without an intervening split/fold_in"
                    ),
                ))
        for tok, site in self.loop_hits.items():
            out.append(RngIssue(
                kind="loop-reuse",
                key=repr(tok),
                sites=[site],
                detail=(
                    f"loop-invariant key {tok!r} consumed inside a "
                    f"scan/while body at site {site!r} — every iteration "
                    "redraws the same numbers (fold in the loop counter)"
                ),
            ))
        return out


def _as_open(j):
    return j.jaxpr if hasattr(j, "jaxpr") and hasattr(j, "consts") else j


def _params_key(params) -> str:
    try:
        return str(sorted((k, str(v)) for k, v in params.items()
                          if not hasattr(v, "eqns") and not hasattr(v, "jaxpr")))
    except Exception:
        return "?"


def _call_subs(eqn):
    """For call-like eqns: (sub_jaxpr, mult_factor, invar_map, out_map, site).

    invar_map: (sub_invar, outer_invar_index, force_vary) triples.
    out_map: (sub_outvar, outer_outvar) pairs.  site: a jax-internal sampling
    wrapper name ("_uniform", "_normal", ...) or None.
    """
    name = eqn.primitive.name
    if name == "scan":
        closed = eqn.params["jaxpr"]
        sub = _as_open(closed)
        n_consts = eqn.params.get("num_consts", 0)
        n_carry = eqn.params.get("num_carry", 0)
        length = float(eqn.params.get("length", 1) or 1)
        invar_map = []
        for i, sv in enumerate(sub.invars):
            vary = i >= n_consts          # carry + xs vary per iteration
            invar_map.append((sv, i, vary))
        del n_carry  # outvars align positionally: [carry..., ys...]
        out_map = list(zip(sub.outvars, eqn.outvars))
        return [(sub, length, invar_map, out_map, None)]
    if name == "while":
        body = _as_open(eqn.params["body_jaxpr"])
        cond = _as_open(eqn.params["cond_jaxpr"])
        nb = eqn.params.get("body_nconsts", 0)
        nc = eqn.params.get("cond_nconsts", 0)
        from repro.analysis.trace import _while_trip_count

        trips = _while_trip_count(eqn)
        body_map = [(sv, nc + i, i >= nb) for i, sv in enumerate(body.invars)]
        cond_map = [
            (sv, (i if i < nc else nc + nb + (i - nc)), i >= nc)
            for i, sv in enumerate(cond.invars)
        ]
        return [(cond, trips, cond_map, [], None),
                (body, trips, body_map, list(zip(body.outvars, eqn.outvars)),
                 None)]
    if name == "cond":
        out = []
        branches = eqn.params.get("branches", ())
        for br in branches:
            sub = _as_open(br)
            invar_map = [(sv, i + 1, False) for i, sv in enumerate(sub.invars)]
            out.append((sub, 1.0, invar_map,
                        list(zip(sub.outvars, eqn.outvars)), None))
        return out
    closed = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
    if closed is not None and (hasattr(closed, "eqns")
                               or hasattr(closed, "jaxpr")):
        sub = _as_open(closed)
        pjit_name = eqn.params.get("name")
        site = pjit_name if (isinstance(pjit_name, str)
                             and pjit_name.startswith("_")) else None
        invar_map = [(sv, i, False) for i, sv in enumerate(sub.invars)]
        return [(sub, 1.0, invar_map,
                 list(zip(sub.outvars, eqn.outvars)), site)]
    # other sub-jaxpr carriers (pallas_call, custom_jvp, ...): skip lineage
    # inside — they do not consume PRNG keys in this codebase
    return []


def report_from_jaxpr(jaxpr) -> RngReport:
    """Run the lineage checker over an already-traced Jaxpr/ClosedJaxpr."""
    lin = _Lineage()
    open_j = _as_open(jaxpr)
    env = {v: _Canon(("in", i)) for i, v in enumerate(open_j.invars)}
    for i, v in enumerate(getattr(open_j, "constvars", ())):
        env[v] = _Canon(("const", i))
    lin._walk(open_j, env, 1.0, None)
    return RngReport(issues=lin.issues(),
                     consumptions=lin.n_consumptions,
                     keys_seen=len(lin.key_tokens))


def rng_report(fn, *args, **kwargs) -> RngReport:
    """Trace `fn(*args, **kwargs)` and run the lineage checker."""
    closed = jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)
    return report_from_jaxpr(closed)


# --------------------------------------------------------------------------- #
# source-level fold_in sweep
# --------------------------------------------------------------------------- #

_MARKER = re.compile(r"#\s*rng-stream:\s*([\w\-]+)")

SRC_ROOT = pathlib.Path(__file__).resolve().parents[1]   # src/repro


@dataclasses.dataclass
class FoldInSite:
    """One `fold_in` call site found by the AST sweep."""

    path: str            # relative to src/repro
    lineno: int
    source: str          # the call's first source line, stripped
    stream: str | None   # registered stream satisfied here (None = violation)
    via: str             # "tag" | "marker" | "nested" | "unregistered"

    @property
    def ok(self) -> bool:
        """True when the site carries a registered stream tag or marker."""
        return self.stream is not None


def _tag_stream_name(node: ast.expr) -> str | None:
    """Stream name if `node` is a registered inline tag expression."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        s = streams_mod.stream_for_tag(node.value)
        return s.name if s else None
    ident = None
    if isinstance(node, ast.Name):
        ident = node.id
    elif isinstance(node, ast.Attribute):
        ident = node.attr
    if ident is not None:
        name = streams_mod.TAG_CONSTANT_TO_STREAM.get(ident)
        if name is not None:
            return name
    if isinstance(node, ast.BinOp):
        return _tag_stream_name(node.left) or _tag_stream_name(node.right)
    return None


def _is_fold_in(node: ast.Call) -> bool:
    f = node.func
    return (isinstance(f, ast.Attribute) and f.attr == "fold_in") or (
        isinstance(f, ast.Name) and f.id == "fold_in"
    )


def sweep_fold_in_sites(root: pathlib.Path | str = SRC_ROOT) -> list[FoldInSite]:
    """Inventory every `fold_in` call site under `root` (default src/repro).

    A site is compliant when its data argument is a registered tag constant
    (inline or `TAG + offset`), when its key argument is itself a compliant
    `fold_in` (the two-level tagged pattern), or when a ``# rng-stream:``
    marker naming a registered stream sits on the call line / the line above.
    """
    root = pathlib.Path(root)
    sites: list[FoldInSite] = []
    for path in sorted(root.rglob("*.py")):
        rel = str(path.relative_to(root))
        text = path.read_text()
        lines = text.splitlines()
        try:
            tree = ast.parse(text)
        except SyntaxError:
            continue
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call) or not _is_fold_in(node):
                continue
            args = list(node.args)
            stream = via = None
            if len(args) >= 2:
                stream = _tag_stream_name(args[1])
                via = "tag" if stream else None
                nested = (stream is None and isinstance(args[0], ast.Call)
                          and _is_fold_in(args[0]) and len(args[0].args) >= 2)
                if nested:
                    inner = _tag_stream_name(args[0].args[1])
                    if inner:
                        stream, via = inner, "nested"
            if stream is None:
                lo = max(node.lineno - 2, 0)
                hi = min(getattr(node, "end_lineno", node.lineno), len(lines))
                for ln in lines[lo:hi]:
                    m = _MARKER.search(ln)
                    if m and m.group(1) in streams_mod.REGISTRY:
                        stream, via = m.group(1), "marker"
                        break
            sites.append(FoldInSite(
                path=rel,
                lineno=node.lineno,
                source=lines[node.lineno - 1].strip(),
                stream=stream,
                via=via or "unregistered",
            ))
    return sites


def check_fold_in_sites(root: pathlib.Path | str = SRC_ROOT) -> list[FoldInSite]:
    """The violations: unregistered `fold_in` sites under `root`."""
    return [s for s in sweep_fold_in_sites(root) if not s.ok]
