"""Trace-contract analyzer: jaxpr linter + RNG-lineage checker.

Static analysis over *traced programs* (jaxprs) rather than runs:

  * `repro.analysis.trace` — walk a jaxpr into a `TraceReport` (peak
    intermediate bytes, dtype census, FLOPs, pallas dispatch counts,
    host-sync detection, donation verification);
  * `repro.analysis.rng` — RNG-lineage checker (reused-key / loop-reuse
    detection, the PR 8 bug class) plus the source-level `fold_in` sweep;
  * `repro.analysis.streams` — the registry of named RNG streams every
    `fold_in` in `src/repro` must belong to;
  * `repro.analysis.contracts` — per-entry-point budget manifest
    (`contracts.toml`) and its evaluator;
  * `repro.analysis.hardware` — the overridable `HardwareModel` shared with
    the roofline extractor in `repro.launch.analysis`.

Gate: ``python -m repro.analysis check`` (``--update`` ratchets measured
peaks downward, like the coverage gate).
"""
from repro.analysis.hardware import (  # noqa: F401
    DEFAULT_HARDWARE,
    TPU_V5E,
    HardwareModel,
    get_default_hardware,
    set_default_hardware,
)
from repro.analysis.rng import (  # noqa: F401
    RngIssue,
    RngReport,
    check_fold_in_sites,
    report_from_jaxpr as rng_report_from_jaxpr,
    rng_report,
    sweep_fold_in_sites,
)
from repro.analysis.trace import (  # noqa: F401
    TraceReport,
    all_shapes,
    count_pallas_calls,
    max_intermediate_elems,
    peak_intermediate_bytes,
    report_from_jaxpr,
    trace_report,
    verify_donation,
)

__all__ = [
    "DEFAULT_HARDWARE",
    "TPU_V5E",
    "HardwareModel",
    "get_default_hardware",
    "set_default_hardware",
    "RngIssue",
    "RngReport",
    "check_fold_in_sites",
    "rng_report_from_jaxpr",
    "rng_report",
    "sweep_fold_in_sites",
    "TraceReport",
    "all_shapes",
    "count_pallas_calls",
    "max_intermediate_elems",
    "peak_intermediate_bytes",
    "report_from_jaxpr",
    "trace_report",
    "verify_donation",
]
