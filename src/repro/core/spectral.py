"""Sketched eigendecomposition and spectral clustering — the paper's second
flagship application (§4/§5, alongside KRR).

Everything here runs off the pair

    C = K S   (n, d)        W = Sᵀ K S   (d, d)

produced either by the fused one-sweep kernel path (``apply.sketch_both``) or
by the progressive accumulation engine (``apply.grow_sketch_both``), so no
routine ever pays more than O(n·d²) after the sketch:

  * ``nystrom_eigh`` — eigenpairs of the sketched operator K̂ = C W⁺ Cᵀ via
    the Nyström-style lift B = C W^{-1/2}: K̂ = B Bᵀ, so an SVD of the THIN
    (n, d) matrix B gives eigenvectors U and eigenvalues Σ² of K̂ directly.
  * ``sketched_spectral_embedding`` — the (optionally degree-normalized)
    top-k eigenvector embedding; the degree vector D = K̂ 1 = C (W⁺ (Cᵀ 1))
    also costs only O(n·d).
  * ``kmeans`` — a jit-compiled Lloyd solver with k-means++ seeding and
    restarts (used for the final assignment step).
  * ``spectral_cluster`` — the full pipeline; pass a fixed ``m`` or an error
    target ``tol`` to let the progressive engine choose m.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import apply as A
from repro.core.sketch import AccumSketch, make_accum_sketch


# --------------------------------------------------------------------------- #
# Sketched eigendecomposition
# --------------------------------------------------------------------------- #

def _w_pinv_factors(W: jax.Array, eps: float) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(U, λ⁺, λ^{-1/2}) of PSD W with eigenvalues below ``eps``·max zeroed.

    One d×d eigh shared by the degree vector and the eigenvector lift.
    (The progressive engine's ``apply._psd_apply_pinv`` deliberately uses
    Cholesky + jitter instead: it runs inside ``lax.while_loop`` where a full
    eigh per growth step would dominate; here W may be genuinely
    rank-deficient and the pseudo-inverse branch matters.)"""
    w, U = jnp.linalg.eigh(0.5 * (W + W.T))
    good = w > eps * (jnp.maximum(jnp.max(w), 0.0) + 1e-30)
    safe = jnp.where(good, w, 1.0)
    inv = jnp.where(good, 1.0 / safe, 0.0)
    inv_sqrt = jnp.where(good, 1.0 / jnp.sqrt(safe), 0.0)
    return U, inv, inv_sqrt


def nystrom_eigh(C: jax.Array, W: jax.Array, k: int | None = None,
                 *, eps: float = 1e-7, w_factors=None) -> tuple[jax.Array, jax.Array]:
    """Top-k eigenpairs of the sketched operator K̂ = C W⁺ Cᵀ.

    W = UΛU⁺ gives the lift B = C W^{-1/2} = C U Λ^{-1/2} Uᵀ with K̂ = B Bᵀ;
    the thin SVD B = P Σ Qᵀ then yields K̂ = P Σ² Pᵀ — eigenvalues Σ² and
    orthonormal eigenvectors P at O(n·d²) cost.  Eigenvalues of W below
    ``eps``·max are treated as zero (pseudo-inverse branch).  ``w_factors``
    accepts a precomputed ``_w_pinv_factors(W, eps)`` to share the eigh.

    Returns (eigvals (k,), eigvecs (n, k)) in DESCENDING eigenvalue order.
    """
    d = W.shape[0]
    k = d if k is None else k
    U, _, inv_sqrt = w_factors if w_factors is not None else _w_pinv_factors(W, eps)
    B = (C @ U) * inv_sqrt[None, :]                    # C W^{-1/2} (n, d)
    P, s, _ = jnp.linalg.svd(B, full_matrices=False)   # descending s
    return (s[:k] ** 2), P[:, :k]


def sketched_degrees(C: jax.Array, W: jax.Array, *, eps: float = 1e-7,
                     w_factors=None) -> jax.Array:
    """Degree vector of the sketched affinity, D = K̂ 1 = C (W⁺ (Cᵀ 1)) — O(n·d)."""
    U, inv, _ = w_factors if w_factors is not None else _w_pinv_factors(W, eps)
    v = jnp.sum(C, axis=0)                             # Cᵀ 1 (d,)
    return C @ (U @ (inv * (U.T @ v)))


def sketched_spectral_embedding(
    C: jax.Array, W: jax.Array, k: int, *, normalized: bool = True,
    eps: float = 1e-7,
) -> tuple[jax.Array, jax.Array]:
    """Top-k spectral embedding of the sketched affinity K̂ = C W⁺ Cᵀ.

    ``normalized`` (default) embeds with the normalized affinity
    D^{-1/2} K̂ D^{-1/2} (Ng–Jordan–Weiss): D comes from ``sketched_degrees``
    and folds into C — the operator stays in Nyström form, so the lift is
    still an (n, d) SVD and W (hence its one shared eigh) is unchanged.
    Returns (eigvals (k,), embedding (n, k))."""
    factors = _w_pinv_factors(W, eps)
    if normalized:
        deg = sketched_degrees(C, W, eps=eps, w_factors=factors)
        dinv = 1.0 / jnp.sqrt(jnp.maximum(deg, 1e-12))
        C = C * dinv[:, None]
    return nystrom_eigh(C, W, k, eps=eps, w_factors=factors)


# --------------------------------------------------------------------------- #
# k-means (Lloyd + k-means++ seeding, jit-compiled)
# --------------------------------------------------------------------------- #

def _sqdist(X: jax.Array, C: jax.Array) -> jax.Array:
    x2 = jnp.sum(X * X, axis=1)[:, None]
    c2 = jnp.sum(C * C, axis=1)[None, :]
    return jnp.maximum(x2 + c2 - 2.0 * X @ C.T, 0.0)


def _kmeanspp_init(key: jax.Array, X: jax.Array, k: int) -> jax.Array:
    n = X.shape[0]
    first = jax.random.choice(key, n)
    centers = jnp.zeros((k, X.shape[1]), X.dtype).at[0].set(X[first])
    d2min = jnp.sum((X - X[first][None, :]) ** 2, axis=1)

    def body(i, carry):
        centers, d2min = carry
        p = d2min / jnp.maximum(jnp.sum(d2min), 1e-30)
        nxt = jax.random.choice(  # rng-stream: kmeanspp-iter
            jax.random.fold_in(key, i), n, p=p)
        centers = centers.at[i].set(X[nxt])
        d2min = jnp.minimum(d2min, jnp.sum((X - X[nxt][None, :]) ** 2, axis=1))
        return centers, d2min

    centers, _ = jax.lax.fori_loop(1, k, body, (centers, d2min))
    return centers


@partial(jax.jit, static_argnames=("k", "iters", "restarts"))
def kmeans(key: jax.Array, X: jax.Array, k: int, *, iters: int = 25,
           restarts: int = 4) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Lloyd's algorithm with k-means++ seeding and ``restarts`` independent
    runs (best inertia wins).  Returns (labels (n,), centers (k, p), inertia)."""

    def one_run(key):
        c0 = _kmeanspp_init(key, X, k)

        def step(_, c):
            lab = jnp.argmin(_sqdist(X, c), axis=1)
            onehot = jax.nn.one_hot(lab, k, dtype=X.dtype)
            counts = jnp.sum(onehot, axis=0)
            sums = onehot.T @ X
            return jnp.where(counts[:, None] > 0, sums / jnp.maximum(
                counts, 1.0)[:, None], c)

        c = jax.lax.fori_loop(0, iters, step, c0)
        inertia = jnp.sum(jnp.min(_sqdist(X, c), axis=1))
        return c, inertia

    centers_all, inertia_all = jax.lax.map(one_run, jax.random.split(key, restarts))
    best = jnp.argmin(inertia_all)
    centers = centers_all[best]
    labels = jnp.argmin(_sqdist(X, centers), axis=1)
    return labels, centers, inertia_all[best]


# --------------------------------------------------------------------------- #
# Full pipeline
# --------------------------------------------------------------------------- #

@dataclasses.dataclass
class SpectralResult:
    """Output of ``spectral_cluster``."""

    labels: jax.Array       # (n,) int cluster assignments
    eigvals: jax.Array      # (k,) top sketched eigenvalues (descending)
    embedding: jax.Array    # (n, k) row-normalized spectral embedding
    sketch: AccumSketch     # the sketch that produced (C, W)
    info: dict              # {"m": ..., "err": ...} — engine stats


def spectral_cluster(
    key: jax.Array, K: jax.Array, n_clusters: int, *, d: int,
    m: int | None = None, tol: float | None = None, m_max: int = 32,
    probs: jax.Array | None = None, normalized: bool = True,
    use_kernel: bool | None = None, kmeans_restarts: int = 4,
    kmeans_iters: int = 25, mesh=None, schedule: str = "doubling",
    scheme: str = "uniform",
) -> SpectralResult:
    """Sketched spectral clustering of the affinity matrix K.

    ``K`` may be a dense (n, n) affinity or a matrix-free ``KernelOperator``
    (dataset + kernel name) — with an operator the affinity is never
    materialized: (C, W) come from row-streamed kernel evaluations and the
    whole pipeline stays O(n·d) memory.

    Pipeline: sketch → (C, W) → top-``n_clusters`` eigenvector embedding of
    the (normalized) sketched affinity → row-normalize → k-means.  Exactly one
    of ``m`` (fixed sketch size, fused ``sketch_both`` kernel path) or ``tol``
    (error target, progressive accumulation engine picks m ≤ m_max — batched
    rank-B growth on the doubling ``schedule`` by default, O(log m) data
    passes) should be given; ``m=None, tol=None`` defaults to the fixed fused
    path at m=m_max.

    ``mesh`` (operator only) computes (C, W) — the only n·m·d-sized work —
    data-parallel over a ``("data",)`` device mesh with identical sketch
    draws; the O(n·d²) eigenvector lift and k-means run on the row-sharded
    (n, d) pair unchanged.

    ``scheme`` selects the sampling scheme.  ``"poisson"`` works on both
    paths; ``"leverage"`` routes the fixed-m path through the progressive
    engine too (tol=None) so the probabilities can refine from the sketch
    itself between doubling batches.
    """
    ksk, kkm = jax.random.split(key)
    if tol is not None and m is not None:
        raise ValueError("pass either m= or tol=, not both")
    if tol is not None or scheme == "leverage":
        sk, C, W, info = A.grow_sketch_both(
            ksk, K, d, m_max=m_max if m is None else m, tol=tol, probs=probs,
            use_kernel=use_kernel, mesh=mesh, schedule=schedule,
            scheme=scheme)
    else:
        sk = make_accum_sketch(ksk, K.shape[0], d, m_max if m is None else m,
                               probs, scheme=scheme)
        C, W = A.sketch_both(K, sk, use_kernel=use_kernel, mesh=mesh)
        info = {"m": sk.m, "m_max": m_max, "err": float("nan")}
    eigvals, U = sketched_spectral_embedding(
        C.astype(jnp.float32), W.astype(jnp.float32), n_clusters,
        normalized=normalized)
    # row-normalize (NJW step 4): points live on the unit sphere of the
    # eigenspace, so k-means separates angular structure
    emb = U / jnp.maximum(jnp.linalg.norm(U, axis=1, keepdims=True), 1e-12)
    labels, _, _ = kmeans(kkm, emb, n_clusters, iters=kmeans_iters,
                          restarts=kmeans_restarts)
    return SpectralResult(labels=labels, eigvals=eigvals, embedding=emb,
                          sketch=sk, info=info)
