"""Sampling schemes for accumulation sketches — the ``scheme=`` knob.

The paper's premise is that a *suboptimal* sampling distribution P forces a
larger accumulation count m, and that growing m is how accumulation rescues
cheap schemes.  This module supplies the schemes themselves:

  * ``"uniform"``  — p_i = 1/n (the default everywhere; classical Nyström
    at m=1).  Nothing here runs for it; it is listed for completeness.
  * ``"leverage"`` — ridge-leverage-score probabilities
    ℓ_i(λ) = (K (K + nλI)⁻¹)_ii, estimated MATRIX-FREE from the current
    sketch itself: the Nyström lift of (C, W) (``spectral.nystrom_eigh``)
    gives K̂ = P Σ² Pᵀ, and ℓ̂_i = Σ_j P_ij² σ²_j/(σ²_j + nλ) — O(n·d²), no
    n×n matrix.  The progressive engine refines the probability vector as m
    grows (``refresh_tail`` redraws the not-yet-accumulated slabs from the
    new probs).  ``core.leverage`` stays as the O(n³) exact oracle the tests
    compare against.
  * ``"poisson"``  — each row enters a slab INDEPENDENTLY with probability
    π_i = min(1, d·p_i) (no replacement, variable count), padded to the
    fixed column budget d.  The stored per-row probability is π_i/d, so the
    universal combination coefficient r/√(d·m·p) equals r/√(m·π) — the
    Horvitz–Thompson normalization — and E[SSᵀ] = I holds exactly
    (``poisson_pieces`` folds the overflow correction into the signs).

Every engine/driver entry point (``make_accum_sketch``, ``grow_sketch_both``,
``krr_sketched_fit_adaptive``, ``spectral_cluster``, the sharded twins)
accepts ``scheme=`` and threads it here.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

SCHEMES = ("uniform", "leverage", "poisson")

# floor for Poisson inclusion probabilities: keeps π/d strictly positive so
# padding columns (sign 0) never divide 0/√0 into NaN in the coef formula
_PI_FLOOR = 1e-9


def validate_scheme(scheme: str) -> str:
    """Check ``scheme`` is one of ``SCHEMES`` and return it.

    Args:
        scheme: candidate scheme name.

    Returns:
        The validated name (unchanged).
    """
    if scheme not in SCHEMES:
        raise ValueError(f"unknown scheme {scheme!r}; expected one of {SCHEMES}")
    return scheme


# --------------------------------------------------------------------------- #
# Poisson sampling
# --------------------------------------------------------------------------- #

def poisson_inclusion(probs: jax.Array | None, n: int, d: int,
                      dtype=jnp.float32) -> jax.Array:
    """Per-row inclusion probabilities π_i = min(1, d·p_i) for Poisson slabs.

    Args:
        probs: base sampling distribution (n,), unnormalized accepted;
            ``None`` means uniform.
        n: ambient dimension.
        d: sketch column budget (expected slab size).
        dtype: dtype of the returned vector.

    Returns:
        (n,) inclusion probabilities in [floor, 1].
    """
    from repro.core.sketch import _normalize_probs

    base = _normalize_probs(probs, n, dtype)
    return jnp.clip(d * base, _PI_FLOOR, 1.0)


def poisson_pieces(key: jax.Array, pi: jax.Array, m: int, d: int, *,
                   dtype=jnp.float32, signed: bool = True):
    """Draw ``m`` Poisson sub-sampling slabs with inclusion probabilities π.

    Row i enters each slab independently with probability π_i.  The variable
    per-slab count N is padded/truncated to the fixed column budget ``d``:
    when N > d a uniformly-random size-d subset of the included rows is kept
    (the order statistic of u/π, which is U(0,1) conditional on inclusion)
    and the Horvitz–Thompson correction √(N/d) is folded into the signs, so
    the slab stays exactly unbiased; when N < d the trailing columns carry
    sign 0 and contribute nothing.

    Args:
        key: PRNG key.
        pi: (n,) inclusion probabilities (see ``poisson_inclusion``).
        m: number of slabs.
        d: column budget per slab.
        dtype: dtype for the signs.
        signed: multiply kept entries by i.i.d. Rademacher signs.

    Returns:
        ``(indices, signs)`` of shape (m, d): ``signs`` ∈ {0, ±√(N/kept)}
        — zero marks padding.  With the per-row probability stored as π/d,
        the universal coefficient r/√(d·m·p) equals the Horvitz–Thompson
        r/√(m·π).
    """
    n = pi.shape[0]
    ku, ks = jax.random.split(key)
    u = jax.random.uniform(ku, (m, n))
    inc = u < pi[None, :]
    # u/π | inclusion is U(0,1): sorting it picks a uniformly-random subset
    # of the included rows when the slab overflows the column budget
    score = jnp.where(inc, u / pi[None, :], jnp.inf)
    order = jnp.argsort(score, axis=1)
    indices = order[:, :d].astype(jnp.int32)
    count = jnp.sum(inc, axis=1)                        # N per slab
    kept = jnp.minimum(count, d)
    valid = jnp.arange(d)[None, :] < kept[:, None]
    scale = jnp.sqrt(jnp.maximum(count, 1) / jnp.maximum(kept, 1)).astype(dtype)
    if signed:
        sgn = jax.random.rademacher(ks, (m, d), dtype=dtype)
    else:
        sgn = jnp.ones((m, d), dtype=dtype)
    signs = jnp.where(valid, sgn * scale[:, None], 0.0).astype(dtype)
    return indices, signs


# --------------------------------------------------------------------------- #
# Sketch-estimated ridge leverage scores
# --------------------------------------------------------------------------- #

def sketch_leverage_scores(C: jax.Array, W: jax.Array, lam: float, *,
                           eps: float = 1e-7) -> jax.Array:
    """Ridge leverage scores of the SKETCHED operator K̂ = C W⁺ Cᵀ — O(n·d²).

    The Nyström lift (``spectral.nystrom_eigh``) gives K̂ = P Σ² Pᵀ with
    orthonormal P, so the plug-in estimate of
    ℓ_i(λ) = (K (K + nλI)⁻¹)_ii is

        ℓ̂_i = Σ_j P_ij² · σ²_j / (σ²_j + nλ),

    matching ``leverage.leverage_scores``'s K/n eigenvalue convention
    (σ²_j/(σ²_j+nλ) = μ_j/(μ_j+λ) for μ = σ²/n).  Estimated matrix-free
    from the current sketch itself: no n×n matrix is ever formed.

    Args:
        C: (n, d) sketch product K S.
        W: (d, d) small matrix Sᵀ K S.
        lam: ridge level λ (same convention as ``leverage.leverage_scores``).
        eps: relative eigenvalue cutoff for the W pseudo-inverse.

    Returns:
        (n,) estimated leverage scores in [0, 1).
    """
    from repro.core.spectral import nystrom_eigh

    n = C.shape[0]
    evals, evecs = nystrom_eigh(C.astype(jnp.float32), W.astype(jnp.float32),
                                eps=eps)
    ratio = evals / (evals + n * lam)
    return jnp.einsum("nk,k->n", evecs * evecs, ratio)


def sketch_leverage_probs(C: jax.Array, W: jax.Array, lam: float, *,
                          mix: float = 0.1, eps: float = 1e-7) -> jax.Array:
    """Sampling probabilities from sketch-estimated leverage scores.

    Mixes the normalized scores with the uniform distribution,
    p = (1−mix)·ℓ̂/Σℓ̂ + mix/n — the uniform floor bounds the combination
    coefficients (variance control) and keeps every p_i strictly positive.

    Args:
        C: (n, d) sketch product K S.
        W: (d, d) small matrix Sᵀ K S.
        lam: ridge level λ.
        mix: uniform mixing weight in [0, 1].
        eps: relative eigenvalue cutoff for the W pseudo-inverse.

    Returns:
        (n,) normalized sampling probabilities, each ≥ mix/n.
    """
    scores = sketch_leverage_scores(C, W, lam, eps=eps)
    n = scores.shape[0]
    total = jnp.maximum(jnp.sum(scores), 1e-30)
    return (1.0 - mix) * scores / total + mix / n


def state_leverage_probs(state, lam: float, *, mix: float = 0.1,
                         eps: float = 1e-7) -> jax.Array:
    """Refined sampling probabilities from a live engine state — trace-safe.

    Reads the state's running C and recomputes W = SᵀC from C row gathers at
    the driver level (instead of using ``state.W``), so the single-device and
    sharded engines — whose W accumulations reduce in different orders — feed
    the SAME arithmetic into the probability refresh and the redrawn slabs
    stay bitwise-identical across them.

    Args:
        state: ``AccumState`` with at least one slab accumulated.
        lam: ridge level λ for the leverage scores.
        mix: uniform mixing weight.
        eps: relative eigenvalue cutoff for the W pseudo-inverse.

    Returns:
        (n,) refined sampling probabilities (n = ``state.n``; sharded
        padding rows of C are excluded).
    """
    from repro.core import apply as A

    sk = state.masked_sketch()
    C = state.C[: state.n].astype(jnp.float32)   # engine states may pad C
    W = A.sketch_left(sk, C)
    W = 0.5 * (W + W.T)
    return sketch_leverage_probs(C, W, lam, mix=mix, eps=eps)


def refresh_tail(state, key: jax.Array, probs_new: jax.Array, *,
                 signed: bool = True):
    """Redraw the NOT-yet-accumulated slabs from a refined distribution.

    Slabs < m keep their indices/signs and their at-draw probabilities
    (``state.pdraw``) — their normalization is already folded into (C, W) —
    while slabs ≥ m are redrawn with replacement from ``probs_new`` and
    record the new probabilities.  Trace-safe (pure ``where`` masking on the
    static (m_max, d) buffers), so it composes with the ``lax.cond`` phases
    of the doubling ladder.

    Args:
        state: ``AccumState`` to refresh.
        key: PRNG key for the redraw (fold in the phase index upstream).
        probs_new: (n,) refined sampling distribution (normalized).
        signed: draw Rademacher signs for the redrawn slabs.

    Returns:
        A new ``AccumState`` with the tail redrawn and ``probs`` updated.
    """
    kidx, ksgn = jax.random.split(key)
    m_max, d = state.indices.shape
    idx_f = jax.random.choice(kidx, state.n, shape=(m_max, d), replace=True,
                              p=probs_new).astype(jnp.int32)
    if signed:
        sgn_f = jax.random.rademacher(ksgn, (m_max, d),
                                      dtype=state.signs.dtype)
    else:
        sgn_f = jnp.ones((m_max, d), dtype=state.signs.dtype)
    tail = jnp.arange(m_max)[:, None] >= state.m
    p_f = jnp.take(probs_new, idx_f, axis=0).astype(state.pdraw.dtype)
    return dataclasses.replace(
        state,
        indices=jnp.where(tail, idx_f, state.indices),
        signs=jnp.where(tail, sgn_f, state.signs),
        probs=probs_new.astype(state.probs.dtype),
        pdraw=jnp.where(tail, p_f, state.pdraw),
    )
