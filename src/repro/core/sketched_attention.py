"""AccumAttention — the paper's accumulation-of-sub-sampling sketch transported
to transformer attention.

The attention matrix A = softmax(QKᵀ/√h) is an empirical (asymmetric) kernel
matrix; the paper's sketched approximation A_S = A S (SᵀAS)⁻¹ SᵀA becomes, with
landmarks built by the accumulation sketch *in the key/query feature domain*:

    K̃ = Sᵀ K,  Q̃ = Sᵀ Q                                  (d landmarks, m accumulations)
    F = softmax(Q K̃ᵀ/√h)  (n×d),  W = softmax(Q̃ K̃ᵀ/√h)  (d×d),
    Bm = softmax(Q̃ Kᵀ/√h) (d×n)
    out = F · W⁺ · (Bm V)                                  — O(n·d) not O(n²)

m = 1 recovers Nyströmformer-style sub-sampled landmarks; m → ∞ approaches
Gaussian-projected landmarks (JL). W⁺ via Newton–Schulz iteration (TPU friendly:
matmuls only, no eigendecomp in the compiled graph).

Streaming decode (long-context serving): the sketch is applied *row-wise*
(every arriving token scatter-adds into `m_r` of the d landmark slots), which is
the transpose-streamed view of Algorithm 1 — per-position load is Binomial in
the batch construction and fixed `m_r` here; identical in expectation, and
E[SSᵀ] = I_n holds for both. Softmax positivity requires nonnegative slot
masses, so the decode path drops the Rademacher signs and instead tracks slot
mass for an exact log-mass correction (exact when slots are singletons, i.e. it
degrades gracefully to full attention when d ≥ seen tokens).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.sketch import AccumSketch, make_accum_sketch


# --------------------------------------------------------------------------- #
# Landmark construction
# --------------------------------------------------------------------------- #

def landmark_pool(x: jax.Array, sk: AccumSketch, *, normalize: bool = False) -> jax.Array:
    """Sᵀ x over the sequence axis. x: (..., S, D) → (..., d, D).

    Shared sketch across batch/head axes (indices index the sequence axis).

    `normalize=True` rescales each landmark by the total coefficient mass so a
    landmark is the *weighted mean* of its m pooled rows. This is the correct
    transport of Algorithm 1 into softmax attention: the Rademacher signs of the
    KRR sketch cancel inside the bilinear form K S but NOT through the softmax
    nonlinearity, and an unnormalized sum rescales key magnitudes (distorting
    softmax temperatures). A mean-pooled landmark stays on the key manifold —
    m=1 recovers sampled Nyströmformer landmarks, m→∞ approaches cluster means."""
    rows = jnp.take(x, sk.indices.reshape(-1), axis=-2)            # (..., m·d, D)
    shp = rows.shape[:-2] + (sk.m, sk.d, rows.shape[-1])
    coef = sk.coef.astype(x.dtype)
    pooled = jnp.einsum("...mdk,md->...dk", rows.reshape(shp), coef)
    if normalize:
        mass = jnp.sum(jnp.abs(coef), axis=0)                      # (d,)
        pooled = pooled / jnp.maximum(mass, 1e-30)[..., :, None]
    return pooled


def _newton_schulz_pinv(W: jax.Array, iters: int = 6) -> jax.Array:
    """Iterative pseudo-inverse of a (d, d) matrix (Nyströmformer's trick)."""
    d = W.shape[-1]
    eye = jnp.eye(d, dtype=W.dtype)
    norm = jnp.max(jnp.sum(jnp.abs(W), axis=-2), axis=-1) * jnp.max(
        jnp.sum(jnp.abs(W), axis=-1), axis=-1
    )
    Z = jnp.swapaxes(W, -1, -2) / norm[..., None, None]

    def body(Z, _):
        WZ = W @ Z
        Z = 0.25 * Z @ (13.0 * eye - WZ @ (15.0 * eye - WZ @ (7.0 * eye - WZ)))
        return Z, None

    Z, _ = jax.lax.scan(body, Z, None, length=iters)
    return Z


def accum_attention(
    q: jax.Array,          # (B, H, Sq, Dh)
    k: jax.Array,          # (B, H, Sk, Dh)
    v: jax.Array,          # (B, H, Sk, Dh)
    sk: AccumSketch,       # sketch over the key sequence axis (n = Sk)
    *,
    pinv_iters: int = 6,
) -> jax.Array:
    """Sketched (landmark) attention, O(S·d). Bidirectional (prefill/encoder).

    Returns (B, H, Sq, Dh). float32 accumulation for the softmaxes.
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    kt = landmark_pool(k, sk, normalize=True)                       # (B,H,d,Dh)
    qt = landmark_pool(q, sk, normalize=True)                       # (B,H,d,Dh)
    f32 = jnp.float32
    F = jax.nn.softmax((q.astype(f32) @ jnp.swapaxes(kt, -1, -2).astype(f32)) * scale, axis=-1)
    W = jax.nn.softmax((qt.astype(f32) @ jnp.swapaxes(kt, -1, -2).astype(f32)) * scale, axis=-1)
    Bm = jax.nn.softmax((qt.astype(f32) @ jnp.swapaxes(k, -1, -2).astype(f32)) * scale, axis=-1)
    Winv = _newton_schulz_pinv(W, pinv_iters)
    out = F @ (Winv @ (Bm @ v.astype(f32)))
    return out.astype(q.dtype)


def exact_attention(q, k, v, *, causal: bool = False) -> jax.Array:
    """O(S²) reference attention (oracle for tests / small configs)."""
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    logits = (q.astype(jnp.float32) @ jnp.swapaxes(k, -1, -2).astype(jnp.float32)) * scale
    if causal:
        sq, sk_ = q.shape[-2], k.shape[-2]
        mask = jnp.tril(jnp.ones((sq, sk_), bool), k=sk_ - sq)
        logits = jnp.where(mask, logits, -1e30)
    return (jax.nn.softmax(logits, axis=-1) @ v.astype(jnp.float32)).astype(q.dtype)


# --------------------------------------------------------------------------- #
# Streaming sketched KV cache (long-context decode)
# --------------------------------------------------------------------------- #

class SketchCache(NamedTuple):
    """Compressed KV cache: d_slots landmark slots per layer/head."""
    k_sum: jax.Array    # (B, Hkv, d_slots, Dh) — Σ c_i k_i per slot
    v_sum: jax.Array    # (B, Hkv, d_slots, Dh)
    mass: jax.Array     # (B, Hkv, d_slots)     — Σ c_i per slot


def init_sketch_cache(batch, kv_heads, d_slots, head_dim, dtype=jnp.float32) -> SketchCache:
    """Zero-initialized decode-time landmark cache (K-slots, V-slots, counts)."""
    z = jnp.zeros((batch, kv_heads, d_slots, head_dim), dtype)
    return SketchCache(z, z, jnp.zeros((batch, kv_heads, d_slots), dtype))


def update_sketch_cache(
    cache: SketchCache, k_t: jax.Array, v_t: jax.Array, slots: jax.Array
) -> SketchCache:
    """Scatter-add one new token into m_r slots.

    k_t, v_t: (B, Hkv, Dh); slots: (m_r,) int32 — host-side counter RNG draw,
    shared across batch/heads (one gather pattern → one vectorized scatter)."""
    m_r = slots.shape[0]
    c = 1.0 / jnp.sqrt(jnp.asarray(m_r, cache.k_sum.dtype))
    k_add = jnp.broadcast_to(
        (c * k_t)[:, :, None, :], k_t.shape[:2] + (m_r,) + k_t.shape[-1:]
    )
    v_add = jnp.broadcast_to(
        (c * v_t)[:, :, None, :], v_t.shape[:2] + (m_r,) + v_t.shape[-1:]
    )
    mass_add = jnp.full(cache.mass.shape[:2] + (m_r,), c, cache.mass.dtype)
    return SketchCache(
        cache.k_sum.at[:, :, slots, :].add(k_add),
        cache.v_sum.at[:, :, slots, :].add(v_add),
        cache.mass.at[:, :, slots].add(mass_add),
    )


def sketch_decode_attend(q_t: jax.Array, cache: SketchCache) -> jax.Array:
    """One-token attention over the compressed cache with log-mass correction.

    q_t: (B, H, Dh) with H = G·Hkv (GQA groups broadcast). Returns (B, H, Dh).
    logits_j = q·k̄_j/√h + log m_j,  k̄_j = k_sum_j / m_j — exact softmax
    attention when every slot holds one token."""
    B, H, Dh = q_t.shape
    Hkv = cache.k_sum.shape[1]
    G = H // Hkv
    f32 = jnp.float32
    mass = jnp.maximum(cache.mass.astype(f32), 1e-30)               # (B,Hkv,d)
    kbar = cache.k_sum.astype(f32) / mass[..., None]
    vbar = cache.v_sum.astype(f32) / mass[..., None]
    qg = q_t.reshape(B, Hkv, G, Dh).astype(f32)
    scale = 1.0 / jnp.sqrt(jnp.asarray(Dh, f32))
    logits = jnp.einsum("bhgk,bhdk->bhgd", qg, kbar) * scale
    logits = logits + jnp.log(mass)[:, :, None, :]
    empty = cache.mass[:, :, None, :] <= 0
    logits = jnp.where(jnp.broadcast_to(empty, logits.shape), -1e30, logits)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgd,bhdk->bhgk", p, vbar)
    return out.reshape(B, H, Dh).astype(q_t.dtype)


def decode_slots(key: jax.Array, step, d_slots: int, m_r: int) -> jax.Array:
    """Counter-based slot draw for position `step` (deterministic, resumable)."""
    return jax.random.randint(jax.random.fold_in(key, step), (m_r,), 0, d_slots)


def make_seq_sketch(key, seq_len: int, d: int, m: int = 1, *, local: bool = True) -> AccumSketch:
    """Accumulation sketch over sequence positions (prefill path).

    Unsigned: signs do not commute with softmax (see `landmark_pool`).

    `local=True` (default) draws one uniform center per column and pools the m
    contiguous positions of the *m-aligned window* containing it (the chunk
    [m·⌊c/m⌋, m·⌊c/m⌋+m)). The paper's framework requires only i.i.d.
    COLUMNS — "the coordinates in each column are correlated and can follow
    different distributions" — so an aligned block selected by an i.i.d.
    center is a faithful instance of Algorithm 1. For sequence data locality
    is the right correlation structure: pooling m adjacent tokens averages
    noise *within* a semantic cluster (the Nyströmformer segment-mean
    insight), and grid alignment keeps windows from straddling two clusters —
    an unaligned window crosses a boundary with probability ≈ m/cluster-len,
    and a straddling landmark is *worse* than a single sampled token, which
    inverted the error-vs-m trend. `local=False` gives the i.i.d.-uniform
    variant for ablation."""
    if not local or m == 1:
        return make_accum_sketch(key, seq_len, d, m=m, signed=False)
    probs = jnp.full((seq_len,), 1.0 / seq_len, dtype=jnp.float32)
    centers = jax.random.randint(key, (d,), 0, seq_len)
    start = (centers // m) * m                                        # align
    indices = (start[None, :] + jnp.arange(m)[:, None]) % seq_len     # (m, d)
    return AccumSketch(
        indices=indices.astype(jnp.int32),
        signs=jnp.ones((m, d), jnp.float32),
        probs=probs,
        n=seq_len,
    )
