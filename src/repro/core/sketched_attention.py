"""AccumAttention — the paper's accumulation-of-sub-sampling sketch transported
to transformer attention.

The attention matrix A = softmax(QKᵀ/√h) is an empirical (asymmetric) kernel
matrix; the paper's sketched approximation A_S = A S (SᵀAS)⁻¹ SᵀA becomes, with
landmarks built by the accumulation sketch *in the key/query feature domain*:

    K̃ = Sᵀ K,  Q̃ = Sᵀ Q                                  (d landmarks, m accumulations)
    F = softmax(Q K̃ᵀ/√h)  (n×d),  W = softmax(Q̃ K̃ᵀ/√h)  (d×d),
    Bm = softmax(Q̃ Kᵀ/√h) (d×n)
    out = F · W⁺ · (Bm V)                                  — O(n·d) not O(n²)

m = 1 recovers Nyströmformer-style sub-sampled landmarks; m → ∞ approaches
Gaussian-projected landmarks (JL). W⁺ via Newton–Schulz iteration (TPU friendly:
matmuls only, no eigendecomp in the compiled graph).

Streaming decode (long-context serving): the sketch is applied *row-wise*
(every arriving token scatter-adds into `m_r` of the d landmark slots), which is
the transpose-streamed view of Algorithm 1 — per-position load is Binomial in
the batch construction and fixed `m_r` here; identical in expectation, and
E[SSᵀ] = I_n holds for both. Softmax positivity requires nonnegative slot
masses, so the decode path drops the Rademacher signs and instead tracks slot
mass for an exact log-mass correction (exact when slots are singletons, i.e. it
degrades gracefully to full attention when d ≥ seen tokens).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.sketch import AccumSketch, make_accum_sketch


# --------------------------------------------------------------------------- #
# Landmark construction
# --------------------------------------------------------------------------- #

def landmark_pool(x: jax.Array, sk: AccumSketch, *, normalize: bool = False) -> jax.Array:
    """Sᵀ x over the sequence axis. x: (..., S, D) → (..., d, D).

    Shared sketch across batch/head axes (indices index the sequence axis).

    `normalize=True` rescales each landmark by the total coefficient mass so a
    landmark is the *weighted mean* of its m pooled rows. This is the correct
    transport of Algorithm 1 into softmax attention: the Rademacher signs of the
    KRR sketch cancel inside the bilinear form K S but NOT through the softmax
    nonlinearity, and an unnormalized sum rescales key magnitudes (distorting
    softmax temperatures). A mean-pooled landmark stays on the key manifold —
    m=1 recovers sampled Nyströmformer landmarks, m→∞ approaches cluster means."""
    rows = jnp.take(x, sk.indices.reshape(-1), axis=-2)            # (..., m·d, D)
    shp = rows.shape[:-2] + (sk.m, sk.d, rows.shape[-1])
    coef = sk.coef.astype(x.dtype)
    pooled = jnp.einsum("...mdk,md->...dk", rows.reshape(shp), coef)
    if normalize:
        mass = jnp.sum(jnp.abs(coef), axis=0)                      # (d,)
        pooled = pooled / jnp.maximum(mass, 1e-30)[..., :, None]
    return pooled


def _newton_schulz_pinv(W: jax.Array, iters: int = 6) -> jax.Array:
    """Iterative pseudo-inverse of a (d, d) matrix (Nyströmformer's trick)."""
    d = W.shape[-1]
    eye = jnp.eye(d, dtype=W.dtype)
    norm = jnp.max(jnp.sum(jnp.abs(W), axis=-2), axis=-1) * jnp.max(
        jnp.sum(jnp.abs(W), axis=-1), axis=-1
    )
    Z = jnp.swapaxes(W, -1, -2) / norm[..., None, None]

    def body(Z, _):
        WZ = W @ Z
        Z = 0.25 * Z @ (13.0 * eye - WZ @ (15.0 * eye - WZ @ (7.0 * eye - WZ)))
        return Z, None

    Z, _ = jax.lax.scan(body, Z, None, length=iters)
    return Z


def accum_attention(
    q: jax.Array,          # (B, H, Sq, Dh)
    k: jax.Array,          # (B, H, Sk, Dh)
    v: jax.Array,          # (B, H, Sk, Dh)
    sk: AccumSketch,       # sketch over the key sequence axis (n = Sk)
    *,
    pinv_iters: int = 6,
    use_kernel: bool | None = None,
) -> jax.Array:
    """Sketched (landmark) attention, O(S·d). Bidirectional (prefill/encoder).

    Returns (B, H, Sq, Dh). float32 accumulation for the softmaxes.

    ``use_kernel`` routes the two O(S·d) stages through the Pallas
    ``landmark_attention`` kernels (auto: True on TPU, overridable with
    ``REPRO_SKETCH_KERNEL`` — same gate as the KRR kernels); the fused
    single-sweep variant additionally avoids materializing the (d, S)
    ``Bm`` softmax (online-softmax accumulation of Bm·V).
    """
    if use_kernel is None:
        from repro.core.apply import default_use_kernel

        use_kernel = default_use_kernel()
    if use_kernel:
        from repro.kernels.landmark_attention.ops import accum_attention_kernel
        from repro.resilience.degrade import ladder_call

        def _xla():
            return accum_attention(q, k, v, sk, pinv_iters=pinv_iters,
                                   use_kernel=False)

        # a failing Pallas dispatch degrades to this function's own XLA body
        # (recorded in the global HealthReport), never to a wrong answer
        return ladder_call("kernel.dispatch", (
            ("pallas:accum_attention",
             lambda: accum_attention_kernel(q, k, v, sk,
                                            pinv_iters=pinv_iters)),
            ("xla:landmark_softmax", _xla),
        ))
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    kt = landmark_pool(k, sk, normalize=True)                       # (B,H,d,Dh)
    qt = landmark_pool(q, sk, normalize=True)                       # (B,H,d,Dh)
    f32 = jnp.float32
    F = jax.nn.softmax((q.astype(f32) @ jnp.swapaxes(kt, -1, -2).astype(f32)) * scale, axis=-1)
    W = jax.nn.softmax((qt.astype(f32) @ jnp.swapaxes(kt, -1, -2).astype(f32)) * scale, axis=-1)
    Bm = jax.nn.softmax((qt.astype(f32) @ jnp.swapaxes(k, -1, -2).astype(f32)) * scale, axis=-1)
    Winv = _newton_schulz_pinv(W, pinv_iters)
    out = F @ (Winv @ (Bm @ v.astype(f32)))
    return out.astype(q.dtype)


def exact_attention(q, k, v, *, causal: bool = False) -> jax.Array:
    """O(S²) reference attention (oracle for tests / small configs)."""
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    logits = (q.astype(jnp.float32) @ jnp.swapaxes(k, -1, -2).astype(jnp.float32)) * scale
    if causal:
        sq, sk_ = q.shape[-2], k.shape[-2]
        mask = jnp.tril(jnp.ones((sq, sk_), bool), k=sk_ - sq)
        logits = jnp.where(mask, logits, -1e30)
    return (jax.nn.softmax(logits, axis=-1) @ v.astype(jnp.float32)).astype(q.dtype)


# --------------------------------------------------------------------------- #
# Streaming sketched KV cache (long-context decode)
# --------------------------------------------------------------------------- #

class SketchCache(NamedTuple):
    """Compressed KV cache: d_slots landmark slots per layer/head."""
    k_sum: jax.Array    # (B, Hkv, d_slots, Dh) — Σ c_i k_i per slot
    v_sum: jax.Array    # (B, Hkv, d_slots, Dh)
    mass: jax.Array     # (B, Hkv, d_slots)     — Σ c_i per slot


def init_sketch_cache(batch, kv_heads, d_slots, head_dim, dtype=jnp.float32) -> SketchCache:
    """Zero-initialized decode-time landmark cache (K-slots, V-slots, counts).

    ``dtype`` applies to the k/v slot accumulators; ``mass`` stays float32
    always — it is a running count feeding the log-mass logit correction, and
    bf16's 8-bit mantissa stops resolving +c increments after a few hundred
    tokens (count saturation ⇒ silently wrong attention weights)."""
    z = jnp.zeros((batch, kv_heads, d_slots, head_dim), dtype)
    return SketchCache(z, z, jnp.zeros((batch, kv_heads, d_slots), jnp.float32))


def _slot_contrib(x: jax.Array, m_r: int, dtype) -> jax.Array:
    """Per-slot contribution c·x (c = 1/√m_r so E[SSᵀ] = I), computed in f32
    and rounded ONCE to the cache dtype — the shared definition that keeps the
    sequential (`update_sketch_cache`) and batched (`prefill_sketch_cache`)
    paths bitwise identical."""
    c = 1.0 / jnp.sqrt(jnp.asarray(m_r, jnp.float32))
    return (c * x.astype(jnp.float32)).astype(dtype)


def update_sketch_cache(
    cache: SketchCache, k_t: jax.Array, v_t: jax.Array, slots: jax.Array
) -> SketchCache:
    """Scatter-add one new token into m_r slots.

    k_t, v_t: (B, Hkv, Dh); slots: (m_r,) int32 — host-side counter RNG draw,
    shared across batch/heads (one gather pattern → one vectorized scatter).
    Out-of-range slot indices (the Poisson scheme's padding marker, see
    `decode_slots`) are dropped by JAX scatter semantics."""
    m_r = slots.shape[0]
    k_add = jnp.broadcast_to(
        _slot_contrib(k_t, m_r, cache.k_sum.dtype)[:, :, None, :],
        k_t.shape[:2] + (m_r,) + k_t.shape[-1:],
    )
    v_add = jnp.broadcast_to(
        _slot_contrib(v_t, m_r, cache.v_sum.dtype)[:, :, None, :],
        v_t.shape[:2] + (m_r,) + v_t.shape[-1:],
    )
    c_mass = 1.0 / jnp.sqrt(jnp.asarray(m_r, cache.mass.dtype))
    mass_add = jnp.full(cache.mass.shape[:2] + (m_r,), c_mass, cache.mass.dtype)
    return SketchCache(
        cache.k_sum.at[:, :, slots, :].add(k_add),
        cache.v_sum.at[:, :, slots, :].add(v_add),
        cache.mass.at[:, :, slots].add(mass_add),
    )


def prefill_sketch_cache(
    cache: SketchCache, k_seq: jax.Array, v_seq: jax.Array, slot_table: jax.Array
) -> SketchCache:
    """Scatter-add ALL L tokens into their slots in one vectorized segment-sum.

    k_seq, v_seq: (B, Hkv, L, Dh); slot_table: (L, m_r) int32 (row t = the draw
    `decode_slots(key, t, ...)` would make). One scatter with the L·m_r updates
    flattened token-major — the same values in the same order as folding
    `update_sketch_cache` over tokens, so the result is bitwise identical to
    the sequential loop's cache (pinned by test). Out-of-range slot indices
    (Poisson padding) are dropped."""
    B, Hkv, L, Dh = k_seq.shape
    m_r = slot_table.shape[-1]
    flat = slot_table.reshape(-1)                                   # (L·m_r,)
    k_add = jnp.broadcast_to(
        _slot_contrib(k_seq, m_r, cache.k_sum.dtype)[:, :, :, None, :],
        (B, Hkv, L, m_r, Dh),
    ).reshape(B, Hkv, L * m_r, Dh)
    v_add = jnp.broadcast_to(
        _slot_contrib(v_seq, m_r, cache.v_sum.dtype)[:, :, :, None, :],
        (B, Hkv, L, m_r, Dh),
    ).reshape(B, Hkv, L * m_r, Dh)
    c_mass = 1.0 / jnp.sqrt(jnp.asarray(m_r, cache.mass.dtype))
    mass_add = jnp.full((B, Hkv, L * m_r), c_mass, cache.mass.dtype)
    return SketchCache(
        cache.k_sum.at[:, :, flat, :].add(k_add),
        cache.v_sum.at[:, :, flat, :].add(v_add),
        cache.mass.at[:, :, flat].add(mass_add),
    )


def sketch_prefill_attend(
    q_seq: jax.Array, k_seq: jax.Array, v_seq: jax.Array, cache: SketchCache,
    slot_table: jax.Array, *, chunk: int = 128,
) -> tuple[jax.Array, SketchCache]:
    """Decode-semantics attention for all L prefill positions in one dispatch.

    q_seq: (B, H, L, Dh); k_seq, v_seq: (B, Hkv, L, Dh); slot_table: (L, m_r).
    Position t attends over the EVOLVING cache state after its own token's
    scatter (exactly what the sequential `update_sketch_cache` →
    `sketch_decode_attend` loop sees), yet nothing per-position is
    materialized: within a chunk of size c the cumulative cache never exists —
    the logit/value contributions split into a past-carry term plus an
    intra-chunk term through the (c, c) token-score matrix,

        q_t·k_sum_t[j] = q_t·carry_k[j] + Σ_{s≤t} (q_t·k_s)·w[s, j]
        out_t          = p̃_t·carry_v    + Σ_{s≤t} (p̃_t·wᵀ)[s]·v_s

    with w the (c, d_slots) slot-weight matrix of the chunk (the accumulation
    sketch restricted to the chunk) and p̃ = softmax / mass. The chunk carry is
    advanced with the same token-major scatter as `prefill_sketch_cache`, so
    the returned cache is bitwise identical to the sequential loop's; outputs
    agree to float-associativity (≤1e-5 rel, pinned by the serve tests).
    Returns (out (B, H, L, Dh) in q's dtype, final SketchCache)."""
    B, H, L, Dh = q_seq.shape
    Hkv = k_seq.shape[1]
    G = H // Hkv
    d_slots = cache.k_sum.shape[2]
    m_r = slot_table.shape[-1]
    f32 = jnp.float32
    cm = min(chunk, L)
    pad = (-L) % cm
    if pad:
        zpad4 = ((0, 0), (0, 0), (0, pad), (0, 0))
        q_seq = jnp.pad(q_seq, zpad4)
        k_seq = jnp.pad(k_seq, zpad4)
        v_seq = jnp.pad(v_seq, zpad4)
        # padded tokens target the out-of-range slot index → dropped by scatter
        slot_table = jnp.pad(slot_table, ((0, pad), (0, 0)),
                             constant_values=d_slots)
    nc = (L + pad) // cm
    qs = q_seq.reshape(B, Hkv, G, nc, cm, Dh).transpose(3, 0, 1, 2, 4, 5)
    ks = k_seq.reshape(B, Hkv, nc, cm, Dh).transpose(2, 0, 1, 3, 4)
    vs = v_seq.reshape(B, Hkv, nc, cm, Dh).transpose(2, 0, 1, 3, 4)
    ss = slot_table.reshape(nc, cm, m_r)
    scale = 1.0 / jnp.sqrt(jnp.asarray(Dh, f32))
    c_mass = 1.0 / jnp.sqrt(jnp.asarray(m_r, f32))
    tril = jnp.tril(jnp.ones((cm, cm), bool))                       # s ≤ t

    def body(carry, xs):
        qc, kc, vc, sl = xs
        # (cm, d_slots) slot weights: Σ_r one-hot(slot) · c; out-of-range
        # padding rows match nothing and stay zero
        w = (sl[:, :, None] == jnp.arange(d_slots)[None, None, :])
        w = jnp.sum(w, axis=1).astype(f32) * c_mass
        mass_prev = carry.mass.astype(f32)                          # (B,Hkv,d)
        k_prev = carry.k_sum.astype(f32)
        v_prev = carry.v_sum.astype(f32)
        qf, kf, vf = qc.astype(f32), kc.astype(f32), vc.astype(f32)
        mass_cum = mass_prev[:, :, None, :] + jnp.cumsum(w, axis=0)[None, None]
        A = jnp.einsum("bhgtd,bhsd->bhgts", qf, kf)
        A = jnp.where(tril[None, None, None], A, 0.0)
        qk = (jnp.einsum("bhgtd,bhjd->bhgtj", qf, k_prev)
              + jnp.einsum("bhgts,sj->bhgtj", A, w))
        mass_c = jnp.maximum(mass_cum, 1e-30)
        logits = scale * qk / mass_c[:, :, None] + jnp.log(mass_c)[:, :, None]
        logits = jnp.where((mass_cum <= 0)[:, :, None], -1e30, logits)
        pn = jax.nn.softmax(logits, axis=-1) / mass_c[:, :, None]
        pw = jnp.einsum("bhgtj,sj->bhgts", pn, w)
        pw = jnp.where(tril[None, None, None], pw, 0.0)
        o = (jnp.einsum("bhgtj,bhjd->bhgtd", pn, v_prev)
             + jnp.einsum("bhgts,bhsd->bhgtd", pw, vf))
        return prefill_sketch_cache(carry, kc, vc, sl), o

    cache, outs = jax.lax.scan(body, cache, (qs, ks, vs, ss))
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(B, H, L + pad, Dh)
    return out[:, :, :L].astype(q_seq.dtype), cache


def sketch_decode_attend(
    q_t: jax.Array, cache: SketchCache, *, use_kernel: bool | None = None
) -> jax.Array:
    """One-token attention over the compressed cache with log-mass correction.

    q_t: (B, H, Dh) with H = G·Hkv (GQA groups broadcast). Returns (B, H, Dh).
    logits_j = q·k̄_j/√h + log m_j,  k̄_j = k_sum_j / m_j — exact softmax
    attention when every slot holds one token.

    ``use_kernel`` routes the softmax·V contraction through the Pallas
    ``landmark_attention`` kernel with the log-mass correction folded into its
    bias lane (auto: True on TPU / REPRO_SKETCH_KERNEL, like the KRR path)."""
    B, H, Dh = q_t.shape
    Hkv = cache.k_sum.shape[1]
    G = H // Hkv
    f32 = jnp.float32
    mass = jnp.maximum(cache.mass.astype(f32), 1e-30)               # (B,Hkv,d)
    kbar = cache.k_sum.astype(f32) / mass[..., None]
    vbar = cache.v_sum.astype(f32) / mass[..., None]
    qg = q_t.reshape(B, Hkv, G, Dh).astype(f32)
    if use_kernel is None:
        from repro.core.apply import default_use_kernel

        use_kernel = default_use_kernel()
    bias = jnp.where(cache.mass <= 0, -1e30, jnp.log(mass))         # (B,Hkv,d)
    if use_kernel:
        from repro.kernels.landmark_attention.ops import landmark_attend

        out = jax.vmap(landmark_attend)(
            qg.reshape(B * Hkv, G, Dh),
            kbar.reshape(B * Hkv, -1, Dh),
            vbar.reshape(B * Hkv, -1, Dh),
            bias.reshape(B * Hkv, -1),
        )
        return out.reshape(B, H, Dh).astype(q_t.dtype)
    scale = 1.0 / jnp.sqrt(jnp.asarray(Dh, f32))
    logits = jnp.einsum("bhgk,bhdk->bhgd", qg, kbar) * scale
    logits = logits + bias[:, :, None, :]
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgd,bhdk->bhgk", p, vbar)
    return out.reshape(B, H, Dh).astype(q_t.dtype)


DECODE_SLOT_SCHEMES = ("uniform", "poisson")


def decode_slots(
    key: jax.Array, step, d_slots: int, m_r: int, *,
    scheme: str = "uniform", max_len: int | None = None,
) -> jax.Array:
    """Counter-based slot draw for position `step` (deterministic, resumable).

    Returns (m_r,) int32 slot indices; entries equal to ``d_slots`` are
    padding (dropped by the scatter — JAX out-of-bounds update semantics).

    ``scheme`` picks the streaming view of the PR 7 sampling zoo:
      * ``"uniform"`` — m_r i.i.d. uniform slots (with replacement), the
        transpose-streamed batch sketch: per-slot load Binomial(L, m_r/d).
      * ``"poisson"`` — every slot flips an independent coin with inclusion
        probability π = m_r/d_slots (arXiv:2205.08588's Poisson sampling):
        the draw count is Binomial(d_slots, π) with mean m_r, truncated to at
        most m_r slots (a uniform subset on overflow, ranked by the inclusion
        uniforms — the same overflow rule as `schemes.poisson_pieces`). No
        Horvitz–Thompson reweighting is needed: scaling every token's
        contribution by the same constant shifts log-mass uniformly and
        cancels in the decode softmax.

    ``max_len``: when the engine knows the whole stream fits in the slots
    (max_len ≤ d_slots), the draw degrades to the identity — slot t for
    position t — so every slot is a singleton and sketched decode IS exact
    attention (the module docstring's "degrades gracefully" claim)."""
    if scheme not in DECODE_SLOT_SCHEMES:
        raise ValueError(
            f"unknown decode slot scheme {scheme!r}; pick from {DECODE_SLOT_SCHEMES}"
        )
    if max_len is not None and max_len <= d_slots:
        pos = jnp.asarray(step, jnp.int32) % jnp.int32(d_slots)
        return jnp.full((m_r,), pos, jnp.int32)
    k = jax.random.fold_in(key, step)  # rng-stream: slot-position
    if scheme == "uniform":
        return jax.random.randint(k, (m_r,), 0, d_slots)
    u = jax.random.uniform(k, (d_slots,))
    pi = jnp.minimum(1.0, m_r / d_slots)
    inc = u < pi
    order = jnp.argsort(jnp.where(inc, u, 2.0))[:m_r]   # included slots first
    valid = jnp.arange(m_r) < jnp.sum(inc)
    return jnp.where(valid, order, d_slots).astype(jnp.int32)


def decode_slot_table(
    key: jax.Array, length: int, d_slots: int, m_r: int, *,
    scheme: str = "uniform", max_len: int | None = None, offset: int = 0,
) -> jax.Array:
    """(length, m_r) stacked `decode_slots` draws for positions offset..offset+L.

    Row t is bit-for-bit the draw the sequential decode loop makes at position
    offset + t — the prefill path's slot schedule."""
    steps = jnp.arange(length, dtype=jnp.int32) + offset
    return jax.vmap(
        lambda s: decode_slots(key, s, d_slots, m_r, scheme=scheme, max_len=max_len)
    )(steps)


def make_seq_sketch(key, seq_len: int, d: int, m: int = 1, *, local: bool = True) -> AccumSketch:
    """Accumulation sketch over sequence positions (prefill path).

    Unsigned: signs do not commute with softmax (see `landmark_pool`).

    `local=True` (default) draws one uniform center per column and pools the m
    contiguous positions of the *m-aligned window* containing it (the chunk
    [m·⌊c/m⌋, m·⌊c/m⌋+m)). The paper's framework requires only i.i.d.
    COLUMNS — "the coordinates in each column are correlated and can follow
    different distributions" — so an aligned block selected by an i.i.d.
    center is a faithful instance of Algorithm 1. For sequence data locality
    is the right correlation structure: pooling m adjacent tokens averages
    noise *within* a semantic cluster (the Nyströmformer segment-mean
    insight), and grid alignment keeps windows from straddling two clusters —
    an unaligned window crosses a boundary with probability ≈ m/cluster-len,
    and a straddling landmark is *worse* than a single sampled token, which
    inverted the error-vs-m trend. `local=False` gives the i.i.d.-uniform
    variant for ablation."""
    if not local or m == 1:
        return make_accum_sketch(key, seq_len, d, m=m, signed=False)
    probs = jnp.full((seq_len,), 1.0 / seq_len, dtype=jnp.float32)
    centers = jax.random.randint(key, (d,), 0, seq_len)
    start = (centers // m) * m                                        # align
    indices = (start[None, :] + jnp.arange(m)[:, None]) % seq_len     # (m, d)
    return AccumSketch(
        indices=indices.astype(jnp.int32),
        signs=jnp.ones((m, d), jnp.float32),
        probs=probs,
        n=seq_len,
    )
