"""Multi-device sharded sketching: row-shard X (and C) over a 1-D data mesh.

The paper's pitch is that accumulation pins the *effective* matrix size at
d×d while n grows without bound — but every earlier path computed C = K·S on
a single device, capping n at one host's memory.  This layer removes that cap
with a ``shard_map``-based data-parallel decomposition over a ``("data",)``
mesh:

  * X (n, p) and C (n, d) are sharded along rows; each device computes its
    (n/D, d) tile of C through the EXISTING backends (the fused Pallas
    kernel-eval→GEMM kernel or the ``lax.scan`` streaming path) with the
    m·d landmark rows and combination coefficients replicated — kernel
    evaluations never cross devices;
  * every n-reduction — W = SᵀC, CᵀC / Cᵀy in the KRR solvers, the holdout
    row gathers, the Hutchinson probe contractions, and the progressive
    engine's T̃ᵀC piece — reduces with a ``psum`` over the data axis; only
    d-vectors and d×d blocks ever cross devices;
  * sketch CONSTRUCTION is untouched: indices/signs/probs are drawn exactly
    as on one device (replicated RNG), so the sharded paths produce bitwise
    identical index draws to the single-device ones — dense ≡ sharded
    equivalence is a reduction-order question only (≤ 1e-5 rel, pinned by
    ``tests/test_distributed.py``).

Row counts that do not divide the mesh are zero-padded up to it; padded C
rows are masked to exact zeros inside the mapped bodies (so downstream psum
reductions are exact) and sliced off at the public boundary.

Entry points are threaded through the usual dispatchers — pass ``mesh=`` (a
``jax.sharding.Mesh`` with a ``"data"`` axis, ``True`` for one over all
devices, or an int device count) to ``apply.sketch_both``, the engine
(``accum_step`` / ``accum_grow*`` / ``grow_sketch_both``), the estimator
factories, ``krr_sketched_fit*``, and ``spectral_cluster``.  Force D local
devices on CPU with ``XLA_FLAGS=--xla_force_host_platform_device_count=D``
(before the first jax import), as the CI leg and
``benchmarks/distributed_bench.py`` do.
"""
from __future__ import annotations

import dataclasses
import functools
import inspect

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.analysis.streams import HOLDOUT_STREAM as _HOLDOUT_STREAM
from repro.core import apply as A
from repro.core.kernel_op import (
    KernelOperator,
    _scan_row_chunks,
    stream_cols,
    stream_cols_slabs,
)
from repro.core.sketch import AccumSketch, AccumState

DATA_AXIS = "data"


def _shard_map():
    """Version-shimmed shard_map (jax 0.4.x ships it in experimental, newer
    jax at the top level; check_rep was renamed check_vma)."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    chk = ("check_vma" if "check_vma" in inspect.signature(sm).parameters
           else "check_rep")
    return functools.partial(sm, **{chk: False})


# --------------------------------------------------------------------------- #
# mesh plumbing
# --------------------------------------------------------------------------- #

def make_data_mesh(num_devices: int | None = None) -> Mesh:
    """1-D ``("data",)`` mesh over the first ``num_devices`` devices (all by
    default)."""
    devs = jax.devices()
    num = len(devs) if num_devices is None else num_devices
    if num > len(devs):
        raise ValueError(
            f"data mesh needs {num} devices, found {len(devs)} — set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={num} before "
            "the first jax import to emulate them on CPU")
    return Mesh(np.asarray(devs[:num]), (DATA_AXIS,))


def resolve_mesh(mesh) -> Mesh:
    """Normalize the ``mesh=`` argument the dispatchers accept: ``True`` →
    a data mesh over all devices, a positive int → over that many, a
    ``Mesh`` → itself (must carry a ``"data"`` axis).  ``False``/``0`` are
    rejected explicitly — the dispatchers gate on ``mesh is not None``, so
    the unsharded path is ``mesh=None``, and silently building an empty mesh
    would crash with an opaque division error deep in the padding."""
    if mesh is True:
        return make_data_mesh()
    if isinstance(mesh, bool):
        raise ValueError("mesh=False is not a disable switch — pass "
                         "mesh=None for the unsharded path")
    if isinstance(mesh, int):
        if mesh < 1:
            raise ValueError(f"mesh device count must be ≥ 1, got {mesh}")
        return make_data_mesh(mesh)
    if isinstance(mesh, Mesh):
        if DATA_AXIS not in mesh.axis_names:
            raise ValueError(
                f"mesh {mesh.axis_names} has no '{DATA_AXIS}' axis")
        return mesh
    raise TypeError(f"mesh must be True, an int, or a Mesh; got {mesh!r}")


def _data_size(mesh: Mesh) -> int:
    return mesh.shape[DATA_AXIS]


def shard_rows(arr: jax.Array, mesh: Mesh) -> jax.Array:
    """Place ``arr`` row-sharded over the data axis (benchmarks; the mapped
    entry points reshard their inputs as needed, so this is never required
    for correctness)."""
    spec = P(DATA_AXIS, *([None] * (arr.ndim - 1)))
    return jax.device_put(arr, NamedSharding(mesh, spec))


def _padded_rows(n: int, D: int) -> int:
    return -(-n // D) * D


def _pad_to(arr: jax.Array, total: int) -> jax.Array:
    pad = total - arr.shape[0]
    if pad == 0:
        return arr
    return jnp.pad(arr, ((0, pad),) + ((0, 0),) * (arr.ndim - 1))


def _operator_required(K) -> KernelOperator:
    op = A._operator(K)
    if op is None:
        raise ValueError(
            "mesh= sharding requires a matrix-free KernelOperator — a dense "
            "(n, n) K already fits on one device, which is the regime "
            "sharding exists to escape")
    return op


# --------------------------------------------------------------------------- #
# reduction primitives: gathers / grams over row-sharded arrays
# --------------------------------------------------------------------------- #

def sharded_take_rows(M: jax.Array, idx: jax.Array, mesh: Mesh) -> jax.Array:
    """M[idx] (|idx|, c) for row-sharded M: each device contributes the rows
    it owns (masked local gather), summed with a psum — the data-dependent
    gather SPMD propagation would otherwise realize by replicating M."""
    mesh = resolve_mesh(mesh)
    D = _data_size(mesh)
    N = M.shape[0]
    rows = _padded_rows(N, D) // D
    Mp = _pad_to(M, rows * D)

    def body(mb, ib):
        lo = jax.lax.axis_index(DATA_AXIS) * rows
        inside = (ib >= lo) & (ib < lo + rows)
        local = jnp.where(inside, ib - lo, 0)
        r = jnp.take(mb, local, axis=0) * inside[:, None].astype(mb.dtype)
        return jax.lax.psum(r, DATA_AXIS)

    return _shard_map()(
        body, mesh=mesh, in_specs=(P(DATA_AXIS, None), P(None)),
        out_specs=P(None, None))(Mp, idx)


def sharded_gram(Am: jax.Array, Bm: jax.Array, mesh: Mesh) -> jax.Array:
    """Aᵀ B (x, y) for row-sharded A (N, x), B (N, y): per-device partial
    grams psum-reduced — the N-sized contraction never leaves its shard."""
    mesh = resolve_mesh(mesh)
    D = _data_size(mesh)
    assert Am.shape[0] == Bm.shape[0], (Am.shape, Bm.shape)
    total = _padded_rows(Am.shape[0], D)
    Ap, Bp = _pad_to(Am, total), _pad_to(Bm, total)

    def body(ab, bb):
        part = jax.lax.dot_general(
            ab.astype(jnp.float32), bb.astype(jnp.float32),
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return jax.lax.psum(part, DATA_AXIS)

    return _shard_map()(
        body, mesh=mesh, in_specs=(P(DATA_AXIS, None), P(DATA_AXIS, None)),
        out_specs=P(None, None))(Ap, Bp)


def sharded_sketch_left(sk: AccumSketch, M: jax.Array, mesh: Mesh) -> jax.Array:
    """W = Sᵀ M (d, c) for row-sharded M: the m·d landmark rows are gathered
    shard-locally and psum-reduced, then contracted with the (replicated)
    combination coefficients."""
    rows = sharded_take_rows(M, sk.indices.reshape(-1), mesh)       # (m·d, c)
    rows = rows.reshape(sk.m, sk.d, M.shape[-1])
    return jnp.einsum("mdc,md->dc", rows,
                      sk.coef.astype(rows.dtype))


# --------------------------------------------------------------------------- #
# sharded C = K(·)·S — per-device tiles through the existing backends
# --------------------------------------------------------------------------- #

def _tile_cols_fn(op: KernelOperator, use_kernel: bool, chunk: int | None,
                  *, slabwise: bool = False):
    """(X_tile, landmarks, coef) → C_tile through the backend the
    single-device path would use (Pallas kernel-eval→GEMM or scanned jnp).
    ``slabwise`` routes multi-slab blocks through ``stream_cols_slabs`` —
    the batched engine's narrow-GEMM accumulation — instead of the wide
    slab (the Pallas path keeps the wide block either way)."""
    kf = op.kernel_fn

    def tile(xb, lm, coef):
        if use_kernel:
            from repro.kernels.accum_apply.ops import matfree_cols_kernel
            return matfree_cols_kernel(xb, lm, coef, kernel=op.kernel,
                                       bandwidth=op.bandwidth, nu=op.nu)
        if slabwise and coef.shape[0] > 1:
            return stream_cols_slabs(xb, lm, coef, kf,
                                     chunk=None if chunk is None
                                     else min(chunk, xb.shape[0]))
        return stream_cols(xb, lm, coef, kf,
                           chunk=None if chunk is None
                           else min(chunk, xb.shape[0]))

    return tile


def sharded_weighted_cols(
    op: KernelOperator, Xq: jax.Array, idx: jax.Array, coef: jax.Array,
    mesh: Mesh, *, chunk: int | None = None, use_kernel: bool | None = None,
) -> jax.Array:
    """K(Xq, ·)·S (nq, d) with Xq row-sharded over the data mesh — the
    sharded core primitive behind C, prediction, and the engine's slabs.
    Landmarks ride replicated; each device evaluates only its tile's kernel
    block."""
    mesh = resolve_mesh(mesh)
    D = _data_size(mesh)
    if use_kernel is None:
        use_kernel = A.default_use_kernel()
    nq = Xq.shape[0]
    rows = _padded_rows(nq, D) // D
    if chunk is None:
        # slab-size budget, independent of the per-device row count — gating
        # on rows would re-disable streaming for exactly the large-n
        # workloads sharding spreads below the row threshold
        chunk = op._auto_chunk(idx.size)
    lm = jnp.take(op.X, idx.reshape(-1), axis=0)
    tile = _tile_cols_fn(op, use_kernel, chunk)

    def body(xb, lm_, cf):
        return tile(xb, lm_, cf)

    C = _shard_map()(
        body, mesh=mesh,
        in_specs=(P(DATA_AXIS, None), P(None, None), P(None, None)),
        out_specs=P(DATA_AXIS, None))(_pad_to(Xq, rows * D), lm, coef)
    return C[:nq] if rows * D != nq else C


def sharded_sketch_both(
    op: KernelOperator, sk: AccumSketch, mesh: Mesh, *,
    chunk: int | None = None, use_kernel: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """(C, W) = (K S, SᵀK S) in ONE mapped launch: each device computes its
    C tile locally, gathers the landmark rows it owns, and W arrives as a
    psum of the per-shard SᵀC partials — no second pass over C."""
    mesh = resolve_mesh(mesh)
    D = _data_size(mesh)
    if use_kernel is None:
        use_kernel = A.default_use_kernel()
    n = op.n
    rows = _padded_rows(n, D) // D
    m, d = sk.indices.shape
    if chunk is None:
        chunk = op._auto_chunk(sk.indices.size)    # slab budget, as above
    lm = jnp.take(op.X, sk.indices.reshape(-1), axis=0)
    coef = sk.coef
    tile = _tile_cols_fn(op, use_kernel, chunk)

    def body(xb, lm_, cf, idx_flat):
        lo = jax.lax.axis_index(DATA_AXIS) * rows
        cb = tile(xb, lm_, cf)
        # padded global rows → exact zeros (they are sliced off the public C,
        # but the W gather and any later reduction must not see garbage)
        live = (lo + jnp.arange(rows)) < n
        cb = jnp.where(live[:, None], cb, 0)
        inside = (idx_flat >= lo) & (idx_flat < lo + rows)
        local = jnp.where(inside, idx_flat - lo, 0)
        crows = jnp.take(cb, local, axis=0) * inside[:, None].astype(cb.dtype)
        Wp = jnp.einsum("mdc,md->dc", crows.reshape(m, d, d),
                        cf.astype(crows.dtype))
        return cb, jax.lax.psum(Wp, DATA_AXIS)

    C, W = _shard_map()(
        body, mesh=mesh,
        in_specs=(P(DATA_AXIS, None), P(None, None), P(None, None), P(None)),
        out_specs=(P(DATA_AXIS, None), P(None, None)))(
            _pad_to(op.X, rows * D), lm, coef, sk.indices.reshape(-1))
    return (C[:n] if rows * D != n else C), W


def sharded_matvec(
    op: KernelOperator, Z: jax.Array, mesh: Mesh, *, chunk: int | None = None,
) -> jax.Array:
    """K @ Z with the output rows sharded: each device streams kernel evals
    of its X tile against the replicated X (O(rows·n) peak per device).
    Only the Hutchinson probe precompute needs this."""
    mesh = resolve_mesh(mesh)
    D = _data_size(mesh)
    n = op.n
    rows = _padded_rows(n, D) // D
    Zm = Z[:, None] if Z.ndim == 1 else Z
    Xp = _pad_to(op.X, rows * D)
    Zp = _pad_to(Zm.astype(jnp.float32), rows * D)  # zero rows kill padded cols
    if chunk is None:
        chunk = max(8, (4 * 1024 * 1024) // max(rows * D, 1))
    kf = op.kernel_fn

    def body(xb, Xall, Zall):
        def blk(xc):
            return kf(xc, Xall).astype(jnp.float32) @ Zall

        out = _scan_row_chunks(xb, min(chunk, xb.shape[0]), blk)
        lo = jax.lax.axis_index(DATA_AXIS) * rows
        live = (lo + jnp.arange(rows)) < n
        return jnp.where(live[:, None], out, 0.0)

    out = _shard_map()(
        body, mesh=mesh,
        in_specs=(P(DATA_AXIS, None), P(None, None), P(None, None)),
        out_specs=P(DATA_AXIS, None))(Xp, Xp, Zp)
    out = out[:n] if rows * D != n else out
    return out[:, 0] if Z.ndim == 1 else out


# --------------------------------------------------------------------------- #
# progressive engine: sharded incremental slabs
# --------------------------------------------------------------------------- #

def _pad_engine(op: KernelOperator, state: AccumState, mesh: Mesh):
    """Pad X and the running C up to the mesh once per grow call (the loop
    then runs pad-free); returns (padded operator, padded state)."""
    D = _data_size(mesh)
    total = _padded_rows(op.n, D)
    if total == op.n:
        return op, state
    opp = KernelOperator(_pad_to(op.X, total), op.kernel, op.bandwidth, op.nu)
    return opp, dataclasses.replace(state, C=_pad_to(state.C, total))


def _unpad_state(state: AccumState, n: int) -> AccumState:
    if state.C.shape[0] == n:
        return state
    return dataclasses.replace(state, C=state.C[:n])


def _sharded_step(opp: KernelOperator, state: AccumState, mesh: Mesh,
                  use_kernel: bool, n_real: int) -> AccumState:
    """One m → m+1 slab on pre-padded (X, C) — the same arithmetic as
    ``apply.accum_step`` with the column block computed per-shard and the
    T̃ᵀC gather psum-reduced."""
    D = _data_size(mesh)
    rows = opp.n // D
    t = state.m
    # same normalization/recurrence as apply.accum_step, via the shared
    # helpers — only the n-sized pieces differ (per-shard tile + psum gather)
    idx_new, coef_new, a = A.slab_pieces(state)
    Ksub = opp.submatrix(idx_new, idx_new)
    lm = jnp.take(opp.X, idx_new, axis=0)
    tile = _tile_cols_fn(opp, use_kernel, None)

    def body(xb, cb, lm_, cf, idx_, a_):
        lo = jax.lax.axis_index(DATA_AXIS) * rows
        g = tile(xb, lm_, cf[None, :]).astype(jnp.float32)
        live = (lo + jnp.arange(rows)) < n_real
        g = jnp.where(live[:, None], g, 0.0)
        c_new = a_ * cb + g
        inside = (idx_ >= lo) & (idx_ < lo + rows)
        local = jnp.where(inside, idx_ - lo, 0)
        crows = jnp.take(cb, local, axis=0) * inside[:, None].astype(cb.dtype)
        return c_new, jax.lax.psum(crows, DATA_AXIS)

    C_new, Crows = _shard_map()(
        body, mesh=mesh,
        in_specs=(P(DATA_AXIS, None), P(DATA_AXIS, None), P(None, None),
                  P(None), P(None), P()),
        out_specs=(P(DATA_AXIS, None), P(None, None)))(
            opp.X, state.C, lm, coef_new, idx_new, a)

    TtC = coef_new[:, None] * Crows
    W_new = A.slab_w_update(state, TtC, Ksub, coef_new, a)
    return dataclasses.replace(state, C=C_new, W=W_new, m=t + 1)


def _sharded_batched(opp: KernelOperator, state: AccumState, B: int,
                     mesh: Mesh, use_kernel: bool, n_real: int) -> AccumState:
    """One m → m+B batch on pre-padded (X, C): the same arithmetic as
    ``apply.accum_grow_batched`` with the B-slab column block computed
    per-shard in ONE mapped launch and BOTH d×d W-piece gathers (TᵀC from
    the old C, TᵀG from the G the launch just produced) psum-reduced from
    the same pass — the sharded engine reads each X shard once per batch.
    Draws are the replicated pre-draw, so they stay bitwise-identical to the
    single-device batched (and sequential) paths."""
    D = _data_size(mesh)
    rows = opp.n // D
    idx_blk, coef_blk, a = A.batch_pieces(state, B)
    d = state.d
    lm = jnp.take(opp.X, idx_blk.reshape(-1), axis=0)
    tile = _tile_cols_fn(opp, use_kernel, None, slabwise=True)

    def body(xb, cb, lm_, cf, idx_flat, a_):
        lo = jax.lax.axis_index(DATA_AXIS) * rows
        g = tile(xb, lm_, cf).astype(jnp.float32)
        live = (lo + jnp.arange(rows)) < n_real
        g = jnp.where(live[:, None], g, 0.0)
        c_new = a_ * cb + g
        inside = (idx_flat >= lo) & (idx_flat < lo + rows)
        local = jnp.where(inside, idx_flat - lo, 0)
        mask = inside[:, None].astype(jnp.float32)
        grows = jnp.take(g, local, axis=0) * mask
        crows = jnp.take(cb, local, axis=0) * mask
        return (c_new, jax.lax.psum(grows, DATA_AXIS),
                jax.lax.psum(crows, DATA_AXIS))

    C_new, Grows, Crows = _shard_map()(
        body, mesh=mesh,
        in_specs=(P(DATA_AXIS, None), P(DATA_AXIS, None), P(None, None),
                  P(None, None), P(None), P()),
        out_specs=(P(DATA_AXIS, None), P(None, None), P(None, None)))(
            opp.X, state.C, lm, coef_blk, idx_blk.reshape(-1), a)

    TtG = jnp.einsum("bdc,bd->dc", Grows.reshape(B, d, d), coef_blk)
    TtC = jnp.einsum("bdc,bd->dc", Crows.reshape(B, d, d), coef_blk)
    W_new = A.batch_w_update(state, TtC, TtG, a)
    return dataclasses.replace(state, C=C_new, W=W_new, m=state.m + B)


def sharded_accum_step(K, state: AccumState, mesh, *,
                       use_kernel: bool | None = None) -> AccumState:
    """``apply.accum_step`` on a row-sharded operator (standalone form: pads
    and unpads around the step; the grow loops pad once instead)."""
    mesh = resolve_mesh(mesh)
    op = _operator_required(K)
    if use_kernel is None:
        use_kernel = A.default_use_kernel()
    opp, st = _pad_engine(op, state, mesh)
    return _unpad_state(_sharded_step(opp, st, mesh, use_kernel, op.n), op.n)


def sharded_accum_grow_batched(K, state: AccumState, B: int, mesh, *,
                               use_kernel: bool | None = None) -> AccumState:
    """``apply.accum_grow_batched`` on a row-sharded operator: all B slabs in
    one mapped sweep per shard (standalone form: pads/unpads around the
    batch; the doubling driver pads once instead)."""
    mesh = resolve_mesh(mesh)
    op = _operator_required(K)
    if use_kernel is None:
        use_kernel = A.default_use_kernel()
    opp, st = _pad_engine(op, state, mesh)
    return _unpad_state(_sharded_batched(opp, st, B, mesh, use_kernel, op.n),
                        op.n)


def sharded_accum_grow(K, state: AccumState, steps: int, mesh, *,
                       use_kernel: bool | None = None) -> AccumState:
    """``apply.accum_grow`` on a row-sharded operator: ``steps`` sequential
    slab updates, each a mapped sweep per shard (one pad/unpad around the
    whole loop)."""
    mesh = resolve_mesh(mesh)
    op = _operator_required(K)
    if use_kernel is None:
        use_kernel = A.default_use_kernel()
    opp, st = _pad_engine(op, state, mesh)

    def body(_, s):
        return _sharded_step(opp, s, mesh, use_kernel, op.n)

    return _unpad_state(jax.lax.fori_loop(0, steps, body, st), op.n)


def sharded_accum_grow_doubling(
    K, state: AccumState, mesh, *, tol: float, estimator,
    use_kernel: bool | None = None, refine=None,
) -> tuple[AccumState, jax.Array]:
    """The doubling schedule on the sharded engine: the SHARED
    ``apply.doubling_ladder`` driver (so the stopping decisions — hence the
    chosen m — cannot drift from the single-device engine run with the same
    draws and a matching estimator), with each batch ONE mapped sweep over
    the shards.  ``refine`` is the optional per-phase probability refresh
    (``apply.make_leverage_refine`` — it reads C through driver-level
    gathers, so the padded rows never enter).  Returns ``(state, passes)``."""
    mesh = resolve_mesh(mesh)
    op = _operator_required(K)
    if use_kernel is None:
        use_kernel = A.default_use_kernel()
    opp, st = _pad_engine(op, state, mesh)

    def apply_batch(s, B):
        return _sharded_batched(opp, s, B, mesh, use_kernel, op.n)

    state, passes = A.doubling_ladder(st, st.m_max, tol, apply_batch,
                                      estimator, refine=refine)
    return _unpad_state(state, op.n), passes


def sharded_accum_grow_adaptive(
    K, state: AccumState, mesh, *, tol: float, estimator,
    check_every: int = 1, use_kernel: bool | None = None,
    schedule: str = "unit",
) -> AccumState:
    """Adaptive growth with the sharded step; ``estimator`` sees states whose
    C is padded to the mesh (the shard-aware factories below handle that).
    ``schedule="doubling"`` delegates to the batched rank-B ladder."""
    if schedule == "doubling":
        state, _ = sharded_accum_grow_doubling(
            K, state, mesh, tol=tol, estimator=estimator,
            use_kernel=use_kernel)
        return state
    mesh = resolve_mesh(mesh)
    op = _operator_required(K)
    if use_kernel is None:
        use_kernel = A.default_use_kernel()
    opp, st = _pad_engine(op, state, mesh)
    m_max = st.m_max

    def cond(s):
        return jnp.logical_and(s.m < m_max, s.err > tol)

    def body(s):
        s = _sharded_step(opp, s, mesh, use_kernel, op.n)
        do_check = jnp.logical_or(s.m % check_every == 0, s.m >= m_max)
        err = jax.lax.cond(do_check, estimator, lambda x: x.err, s)
        return dataclasses.replace(s, err=err)

    return _unpad_state(jax.lax.while_loop(cond, body, st), op.n)


# --------------------------------------------------------------------------- #
# shard-aware plug-in stopping estimators
# --------------------------------------------------------------------------- #

def make_sharded_holdout_estimator(key: jax.Array, K, mesh, num: int = 64,
                                   *, jitter: float = 1e-6):
    """The holdout rule with the C row gather psum-reduced.  Same key → the
    SAME holdout draw as ``apply.make_holdout_estimator`` (replicated RNG)."""
    mesh = resolve_mesh(mesh)
    op = _operator_required(K)
    n = op.n
    hold = jax.random.choice(key, n, shape=(min(num, n),), replace=False)
    Kh = op.submatrix(hold, hold).astype(jnp.float32)
    denom = jnp.maximum(jnp.linalg.norm(Kh), 1e-30)

    def estimate(state: AccumState) -> jax.Array:
        Ch = sharded_take_rows(state.C, hold, mesh)
        Khat = Ch @ A._psd_apply_pinv(state.W, Ch.T, jitter)
        est = jnp.linalg.norm(Kh - Khat) / denom
        return jnp.where(jnp.isfinite(est), est, jnp.inf).astype(jnp.float32)

    return estimate


def make_sharded_hutchinson_estimator(key: jax.Array, K, mesh,
                                      num_probes: int = 8, *,
                                      jitter: float = 1e-6):
    """Hutchinson trace rule: the one-time K Z precompute streams per-shard
    (``sharded_matvec``) and each evaluation's CᵀZ reduces via psum.  Same
    key → the same Rademacher probes as the single-device factory."""
    mesh = resolve_mesh(mesh)
    op = _operator_required(K)
    n = op.n
    Z = jax.random.rademacher(key, (n, num_probes), dtype=jnp.float32)
    KZ = sharded_matvec(op, Z, mesh)
    zKz = jnp.diagonal(sharded_gram(Z, KZ, mesh))
    denom = jnp.maximum(jnp.mean(zKz), 1e-30)

    def estimate(state: AccumState) -> jax.Array:
        Zp = _pad_to(Z, state.C.shape[0])       # engine states carry padded C
        CtZ = sharded_gram(state.C, Zp, mesh)
        zKhatz = jnp.einsum("dq,dq->q", CtZ,
                            A._psd_apply_pinv(state.W, CtZ, jitter))
        est = jnp.maximum(jnp.mean(zKz - zKhatz), 0.0) / denom
        return jnp.where(jnp.isfinite(est), est, jnp.inf).astype(jnp.float32)

    return estimate


# --------------------------------------------------------------------------- #
# one-call sharded driver (used by apply.grow_sketch_both)
# --------------------------------------------------------------------------- #

def sharded_grow_sketch_both(
    key: jax.Array, K, d: int, mesh, *, m_max: int = 32,
    tol: float | None = None, probs: jax.Array | None = None,
    signed: bool = True, estimator=None, check_every: int = 1,
    use_kernel: bool | None = None, schedule: str = "doubling",
    scheme: str = "uniform", scheme_lam: float | None = None,
    scheme_mix: float = 0.1,
):
    """The mesh branch of ``apply.grow_sketch_both``: identical RNG (the
    pre-draw happens replicated, before anything is sharded), sharded growth,
    same return contract (``schedule="doubling"`` by default — batched
    rank-B passes, ``info["passes"]`` counts them).

    ``scheme`` matches the single-device driver bitwise: the pre-draw and
    every leverage probability refresh run replicated at the driver level
    (``apply.make_leverage_refine`` built from the SAME key, reading C
    through driver-level gathers), so the index/sign draws are identical to
    the unsharded run."""
    from repro.core.schemes import validate_scheme

    validate_scheme(scheme)
    if scheme == "leverage" and schedule != "doubling":
        raise ValueError("scheme='leverage' refines between batches and "
                         "needs schedule='doubling'")
    mesh = resolve_mesh(mesh)
    op = _operator_required(K)
    state = A.accum_init(key, op.n, d, m_max, probs, signed=signed,
                         scheme=scheme)
    refine = None
    if scheme == "leverage":
        refine = A.make_leverage_refine(
            key, lam=1e-3 if scheme_lam is None else scheme_lam,
            mix=scheme_mix, signed=signed)
    passes = None
    if tol is None:
        if refine is None:
            # one batched mapped sweep, as in the single-device driver
            state = sharded_accum_grow_batched(op, state, m_max, mesh,
                                               use_kernel=use_kernel)
            passes = jnp.ones((), jnp.int32)
        else:
            # leverage at fixed size walks the doubling ladder with the
            # refresh between batches — same phases/keys as the single-device
            # driver, so the draws stay identical
            sched = A.doubling_schedule(0, m_max)
            for i, B in enumerate(sched):
                state = sharded_accum_grow_batched(op, state, B, mesh,
                                                   use_kernel=use_kernel)
                if i < len(sched) - 1:
                    state = refine(state, i)
            passes = jnp.full((), len(sched), jnp.int32)
    else:
        if estimator is None:
            estimator = make_sharded_holdout_estimator(
                jax.random.fold_in(key, _HOLDOUT_STREAM), op, mesh)
        if schedule == "doubling":
            state, passes = sharded_accum_grow_doubling(
                op, state, mesh, tol=tol, estimator=estimator,
                use_kernel=use_kernel, refine=refine)
        else:
            state = sharded_accum_grow_adaptive(
                op, state, mesh, tol=tol, estimator=estimator,
                check_every=check_every, use_kernel=use_kernel,
                schedule=schedule)
    return A.finish_grow(state, m_max, passes=passes)
