"""Kernel ridge regression: exact (paper eq. 2) and sketched (paper eq. 3).

Exact:     f̂(x)   = K(x, X) (K + nλ I)⁻¹ Y
Sketched:  f̂_S(x) = K(x, X) S (SᵀK²S + nλ SᵀKS)⁻¹ SᵀK Y        (Woodbury form)

Four application paths:
  * dense sketch S (Gaussian / sparse RP baselines)          — O(n²d)
  * structural AccumSketch on a precomputed K                — O(n·m·d)
  * matrix-free AccumSketch straight from X (never forms K)  — O(n·m·d) kernel evals
  * adaptive (``*_adaptive``): the progressive accumulation engine grows m
    one O(n·d) incremental slab at a time until a plug-in error estimate
    clears the caller's tolerance, and the solve reuses the incrementally
    accumulated (C, W)

Every SKETCHED K-taking entry point (``krr_sketched_fit*``) also accepts a
matrix-free ``repro.core.kernel_op.KernelOperator`` (dataset + kernel name)
in place of the dense matrix — the production configuration at n beyond ~10⁴,
where the n×n Gram matrix must never exist.  The exact solvers
(``krr_exact_fit*``) genuinely need the materialized matrix.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import apply as A
from repro.core.sketch import AccumSketch


def _solve_psd(M: jax.Array, b: jax.Array) -> jax.Array:
    """Solve M x = b for PSD M through the resilience solve ladder: trace-scaled
    jitter + Cholesky, escalating ×10 jitter retries on non-finite results,
    terminal lstsq — all in-graph (``lax.while_loop`` / ``lax.cond``, no host
    syncs; pinned by the ``solve_psd_ladder`` trace contract).

    On a healthy PSD input this is bitwise the old single-attempt solve (the
    level-0 jitter is unchanged); the extra rungs trace but never execute."""
    from repro.resilience.degrade import solve_psd_ladder

    return solve_psd_ladder(M, b)[0]


# --------------------------------------------------------------------------- #
# Exact KRR
# --------------------------------------------------------------------------- #

def krr_exact_fit(K: jax.Array, y: jax.Array, lam: float) -> jax.Array:
    """α = (K + nλI)⁻¹ y; fitted values are K @ α."""
    n = K.shape[0]
    return _solve_psd(K + n * lam * jnp.eye(n, dtype=K.dtype), y)


def krr_exact_fitted(K: jax.Array, y: jax.Array, lam: float) -> jax.Array:
    """Fitted values f̂ = K α of the exact KRR solve — the O(n³) reference
    every sketched error is measured against."""
    return K @ krr_exact_fit(K, y, lam)


# --------------------------------------------------------------------------- #
# Sketched KRR
# --------------------------------------------------------------------------- #

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SketchedKRR:
    """Fitted sketched-KRR model. predict() is O(n_test · m · d).

    ``op`` carries the matrix-free ``KernelOperator`` when the model was fit
    through one; predict then routes K(X_test, landmarks)·θ through the
    operator (fused Pallas path on TPU) — test rows never meet an n×n
    matrix.

    Registered as a pytree (array-bearing fields are leaves, ``kernel_fn`` is
    aux) so models pass through ``jax.jit``/``vmap``/``shard_map`` boundaries:
    ``jax.jit(SketchedKRR.predict)(model, X)`` traces instead of failing on
    the unregistered dataclass, and fitted models can be batched or carried
    through scans.  ``info`` rides as a leaf subtree, not aux — its ``m``/
    ``err`` values are jax scalars (traced under jit on the adaptive paths)."""

    theta: jax.Array                   # (d,) dual coefficients in sketch space
    sk: AccumSketch | None             # structural sketch (None for dense S)
    S_dense: jax.Array | None          # dense sketch (baselines)
    X_train: jax.Array | None
    kernel_fn: Callable | None
    fitted: jax.Array                  # in-sample f̂_S(X) (n,)
    info: dict | None = None           # adaptive-fit stats {"m", "err", ...}
    op: "KernelOperator | None" = None  # matrix-free operator (predict routing)

    def tree_flatten(self):
        """Pytree leaves = arrays/submodels; the kernel callable is aux."""
        children = (self.theta, self.sk, self.S_dense, self.X_train,
                    self.fitted, self.info, self.op)
        return children, (self.kernel_fn,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        """Inverse of ``tree_flatten`` (jax pytree protocol)."""
        theta, sk, S_dense, X_train, fitted, info, op = children
        return cls(theta=theta, sk=sk, S_dense=S_dense, X_train=X_train,
                   kernel_fn=aux[0], fitted=fitted, info=info, op=op)

    def predict(self, X_test: jax.Array, *, mesh=None) -> jax.Array:
        """Out-of-sample prediction K(X_test, landmarks) θ — O(n_test·m·d)
        kernel evaluations, never an n_test × n matrix.  ``mesh`` shards the
        test rows (operator-fitted models only)."""
        if self.op is not None and self.sk is not None:
            return self.op.cross_cols(X_test, self.sk, mesh=mesh) @ self.theta
        if mesh is not None:
            # every other mesh entry point raises for non-operator inputs;
            # silently running single-device here would be a lie
            raise ValueError("mesh= predict requires a model fitted through "
                             "a KernelOperator")
        assert self.X_train is not None and self.kernel_fn is not None
        if self.sk is not None:
            # landmarks come from the TRAINING rows (the sketch indexes X_train;
            # gathering from X_test — as the seed did via sketch_kernel_cols —
            # read out-of-bounds whenever n_test < n_train and filled NaN)
            from repro.core.kernel_op import stream_cols

            lm = jnp.take(self.X_train, self.sk.indices.reshape(-1), axis=0)
            C_test = stream_cols(X_test, lm, self.sk.coef, self.kernel_fn)
        else:
            K_test = self.kernel_fn(X_test, self.X_train)
            C_test = K_test @ self.S_dense
        return C_test @ self.theta


def _fit_from_C(C: jax.Array, W: jax.Array, y: jax.Array, lam: float,
                mesh=None):
    """Given C = K S (n,d) and W = SᵀKS (d,d), solve the Woodbury system.

    With ``mesh`` (row-sharded C) the two n-contractions reduce via psum —
    the d×d solve and the row-wise fitted values need no communication.
    Returns (theta, fitted, solve-health) — the health dict carries the solve
    ladder's traced scalars and is threaded into ``SketchedKRR.info``."""
    n = C.shape[0]
    if mesh is not None:
        from repro.core import distributed as D

        CtC = D.sharded_gram(C, C, mesh)
        rhs = D.sharded_gram(C, y[:, None], mesh)[:, 0]
    else:
        CtC = C.T @ C
        rhs = C.T @ y                          # SᵀK Y  (K symmetric)
    from repro.resilience.degrade import solve_psd_ladder

    M = CtC + n * lam * W                      # SᵀK²S + nλ SᵀKS
    theta, health = solve_psd_ladder(M, rhs.astype(M.dtype))
    return theta, C @ theta, health


def krr_sketched_fit(
    K: jax.Array, y: jax.Array, lam: float, sk: AccumSketch,
    X_train: jax.Array | None = None, kernel_fn: Callable | None = None,
    *, use_kernel: bool | None = None, mesh=None,
) -> SketchedKRR:
    """Structural path on K — a precomputed matrix or a matrix-free
    ``KernelOperator``: C and W in one pass, O(n·m·d).

    ``use_kernel`` (auto: True on TPU) routes dense (C, W) through the fused
    single-sweep Pallas kernel instead of two XLA gather passes; an operator
    routes through the fused kernel-eval→GEMM kernel and never forms K.
    With an operator, predict() is wired up automatically (no X_train /
    kernel_fn needed).

    ``mesh`` (operator only) row-shards X and C over a ``("data",)`` device
    mesh: per-device kernel-eval tiles, with W = SᵀC, CᵀC, and Cᵀy reducing
    across shards — only d-vectors and d×d blocks cross devices, so the
    Woodbury solve and predict are unchanged."""
    op = A._operator(K)
    C, W = A.sketch_both(K, sk, use_kernel=use_kernel, mesh=mesh)
    theta, fitted, health = _fit_from_C(C, W, y, lam, mesh=mesh)
    if op is not None:
        return SketchedKRR(theta, sk, None, op.X, op.kernel_fn, fitted,
                           info=health, op=op)
    return SketchedKRR(theta, sk, None, X_train, kernel_fn, fitted, info=health)


def krr_sketched_fit_dense(
    K: jax.Array, y: jax.Array, lam: float, S: jax.Array,
    X_train: jax.Array | None = None, kernel_fn: Callable | None = None,
) -> SketchedKRR:
    """Dense-sketch baseline path (Gaussian sketching, sparse RP): O(n²d)."""
    C = K @ S
    W = S.T @ C
    theta, fitted, health = _fit_from_C(C, W, y, lam)
    return SketchedKRR(theta, None, S, X_train, kernel_fn, fitted, info=health)


def _sketch_left_routed(sk, C, use_kernel: bool | None):
    """W = Sᵀ C through the Pallas left-apply kernel (auto on TPU) or XLA
    gathers (the mesh paths get W from the fused ``sharded_sketch_both``
    launch instead — no second pass over C)."""
    if use_kernel is None:
        use_kernel = A.default_use_kernel()
    if use_kernel:
        from repro.kernels.accum_apply.ops import sketch_left_kernel
        return sketch_left_kernel(sk, C).astype(C.dtype)
    return A.sketch_left(sk, C)


def krr_sketched_fit_matfree(
    X, y: jax.Array, lam: float, sk: AccumSketch,
    kernel_fn: Callable | None = None, *, chunk: int | None = None,
    use_kernel: bool | None = None, mesh=None,
) -> SketchedKRR:
    """Matrix-free path: never forms K. C = K S from O(n·m·d) kernel evals;
    W = Sᵀ C is a row gather of C (routed through the Pallas kernel on TPU).
    This is the production configuration.

    ``X`` may be the raw (n, p) data with an explicit ``kernel_fn`` callable,
    or a ``KernelOperator`` (kernel_fn omitted) — the operator additionally
    unlocks the fused Pallas kernel-eval→GEMM path for C, and ``mesh``
    (operator only) shards the whole fit over a data mesh."""
    op = A._operator(X)
    if mesh is not None and op is None:
        raise ValueError("mesh= sharding requires a KernelOperator input")
    if op is not None:
        if mesh is not None:
            # fused single launch: W gathered in-body, no second pass over C
            C, W = op.sketch_both(sk, chunk=chunk, use_kernel=use_kernel,
                                  mesh=mesh)
        else:
            C = op.sketch_cols(sk, chunk=chunk, use_kernel=use_kernel)
            W = _sketch_left_routed(sk, C, use_kernel)
        X, kernel_fn = op.X, op.kernel_fn
    else:
        C = A.sketch_kernel_cols(X, sk, kernel_fn, chunk=chunk)
        W = _sketch_left_routed(sk, C, use_kernel)
    # symmetrize W: SᵀKS is symmetric in exact arithmetic
    W = 0.5 * (W + W.T)
    theta, fitted, health = _fit_from_C(C, W, y, lam, mesh=mesh)
    return SketchedKRR(theta, sk, None, X, kernel_fn, fitted, info=health, op=op)


def _pcg_solve(C: jax.Array, W: jax.Array, y: jax.Array, lam: float,
               iters: int, mesh=None) -> jax.Array:
    """Preconditioned CG on the Woodbury system (CᵀC + nλ W) θ = Cᵀy with the
    Cholesky of (W + jitter) as preconditioner.  Never materializes CᵀC.

    With ``mesh`` (row-sharded C) each CG iteration stays communication-thin:
    C@t is a per-shard matvec, Cᵀ(·) a psum of d-vectors — the preconditioner
    solve and every other CG vector is d-sized and replicated."""
    n, d = C.shape
    if mesh is not None:
        from repro.core import distributed as D

        def _ct(v):
            return D.sharded_gram(C, v[:, None], mesh)[:, 0]
    else:
        def _ct(v):
            return C.T @ v
    jitter = 1e-8 * (jnp.trace(W) / d + 1e-30)
    L, lower = jax.scipy.linalg.cho_factor(
        W + jitter * jnp.eye(d, dtype=W.dtype), lower=True)

    def matvec(t):
        return _ct(C @ t) + n * lam * (W @ t)

    def precond(r):
        # (nλ W)⁻¹ ≈ the dominant small-eigenvalue part of the operator
        return jax.scipy.linalg.cho_solve((L, lower), r) / (n * lam)

    rhs = _ct(y)
    # tol below f32 CG's stagnation floor: iterate to maxiter (or stagnation)
    # rather than parking at cg's loose 1e-5 default — the solutions two
    # reduction orders converge to must agree to ≤ 1e-5, not just their
    # residual norms
    theta, _ = jax.scipy.sparse.linalg.cg(matvec, rhs, M=precond,
                                          maxiter=iters, tol=1e-7)
    return theta


def krr_sketched_fit_pcg(
    X, y: jax.Array, lam: float, sk: AccumSketch,
    kernel_fn: Callable | None = None, *, iters: int = 30,
    chunk: int | None = None, use_kernel: bool | None = None, mesh=None,
) -> SketchedKRR:
    """Falkon-flavoured solver (Rudi et al. 2017) on the accumulation sketch:
    preconditioned CG on the Woodbury system

        (CᵀC + nλ W) θ = Cᵀy,   C = K S (matrix-free),  W = SᵀKS

    with the Cholesky of (W + nλ-scaled jitter) as preconditioner — the
    paper's point in §3.3: accumulation keeps the preconditioner d×d (one
    Cholesky of the SMALL matrix) where a vanilla md-landmark Nyström solve
    would factor an (md)×(md) system. O(n·m·d·iters), never forms K, and never
    materializes CᵀC (CG touches it only through matvecs).

    ``X``: raw data + ``kernel_fn`` callable, or a ``KernelOperator``
    (required for ``mesh`` sharding)."""
    op = A._operator(X)
    if mesh is not None and op is None:
        raise ValueError("mesh= sharding requires a KernelOperator input")
    if op is not None:
        if mesh is not None:
            # fused single launch: W gathered in-body, no second pass over C
            C, W = op.sketch_both(sk, chunk=chunk, use_kernel=use_kernel,
                                  mesh=mesh)
        else:
            C = op.sketch_cols(sk, chunk=chunk, use_kernel=use_kernel)
            W = _sketch_left_routed(sk, C, use_kernel)
        X, kernel_fn = op.X, op.kernel_fn
    else:
        C = A.sketch_kernel_cols(X, sk, kernel_fn, chunk=chunk)
        W = _sketch_left_routed(sk, C, use_kernel)
    W = 0.5 * (W + W.T)
    theta = _pcg_solve(C, W, y, lam, iters, mesh=mesh)
    return SketchedKRR(theta, sk, None, X, kernel_fn, C @ theta, op=op)


# --------------------------------------------------------------------------- #
# Adaptive (progressive-accumulation) variants
# --------------------------------------------------------------------------- #

def krr_sketched_fit_adaptive(
    K: jax.Array, y: jax.Array, lam: float, key: jax.Array, d: int, *,
    tol: float = 1e-2, m_max: int = 32, probs: jax.Array | None = None,
    estimator=None, check_every: int = 1,
    X_train: jax.Array | None = None, kernel_fn: Callable | None = None,
    use_kernel: bool | None = None, mesh=None, schedule: str = "doubling",
    scheme: str = "uniform", scheme_lam: float | None = None,
) -> SketchedKRR:
    """Sketched KRR with the sketch size chosen by the progressive engine:
    grow m one slab at a time (O(n·d) incremental (C, W) updates) until the
    plug-in error estimate clears ``tol`` or ``m_max`` is reached, then solve
    the Woodbury system with the (C, W) already accumulated — no recompute.

    This is the paper's rescue of suboptimal sampling: callers specify an
    error target, not m, and cheap uniform / approximate-leverage
    probabilities simply buy more slabs.  Growth runs on the DOUBLING
    schedule by default — batched rank-B slabs, O(log m) data passes
    (``info["passes"]``); pass ``schedule="unit"`` for one-slab-per-pass.
    ``K`` may be dense or a ``KernelOperator`` (the engine then grows
    matrix-free: each batch is ONE kernel-eval column-block sweep), and
    ``mesh`` (operator only) runs the whole growth data-parallel with
    identical index draws.

    ``scheme`` selects the sampling scheme (``"uniform"`` / ``"leverage"`` /
    ``"poisson"``).  ``scheme_lam`` is the ridge level at which the leverage
    refinement estimates ridge-leverage scores; it is deliberately decoupled
    from the fit's λ (default: the engine's 1e-3) — scores estimated at a
    coarse ridge whose statistical dimension is O(d) resolve exactly the
    directions a d-column sketch can capture, whereas a tiny fit λ flattens
    the score profile toward rank indicators."""
    op = A._operator(K)
    sk, C, W, info = A.grow_sketch_both(
        key, K, d, m_max=m_max, tol=tol, probs=probs, estimator=estimator,
        check_every=check_every, use_kernel=use_kernel, mesh=mesh,
        schedule=schedule, scheme=scheme, scheme_lam=scheme_lam)
    theta, fitted, health = _fit_from_C(C, W, y, lam, mesh=mesh)
    info = {**info, **health}
    if op is not None:
        return SketchedKRR(theta, sk, None, op.X, op.kernel_fn, fitted,
                           info=info, op=op)
    return SketchedKRR(theta, sk, None, X_train, kernel_fn, fitted, info=info)


def krr_sketched_fit_pcg_adaptive(
    K: jax.Array, y: jax.Array, lam: float, key: jax.Array, d: int, *,
    tol: float = 1e-2, m_max: int = 32, iters: int = 30,
    probs: jax.Array | None = None, estimator=None, check_every: int = 1,
    X_train: jax.Array | None = None, kernel_fn: Callable | None = None,
    use_kernel: bool | None = None, mesh=None, schedule: str = "doubling",
    scheme: str = "uniform", scheme_lam: float | None = None,
) -> SketchedKRR:
    """Adaptive-m Falkon-style PCG: the progressive engine grows (C, W) to the
    error target (doubling schedule by default — O(log m) data passes), then
    CG reuses the incremental pair directly — the d×d preconditioner never
    changes size while m grows (paper §3.3).  ``K`` may be dense or a
    matrix-free ``KernelOperator`` (required for ``mesh``).  ``scheme``
    selects the sampling scheme; ``scheme_lam`` the leverage-estimation ridge
    (default: the engine's 1e-3, decoupled from the fit's λ — see
    ``krr_sketched_fit_adaptive``)."""
    op = A._operator(K)
    sk, C, W, info = A.grow_sketch_both(
        key, K, d, m_max=m_max, tol=tol, probs=probs, estimator=estimator,
        check_every=check_every, use_kernel=use_kernel, mesh=mesh,
        schedule=schedule, scheme=scheme, scheme_lam=scheme_lam)
    theta = _pcg_solve(C, W, y, lam, iters, mesh=mesh)
    if op is not None:
        return SketchedKRR(theta, sk, None, op.X, op.kernel_fn, C @ theta,
                           info=info, op=op)
    return SketchedKRR(theta, sk, None, X_train, kernel_fn, C @ theta, info=info)


def insample_error(f_a: jax.Array, f_b: jax.Array) -> jax.Array:
    """‖f_a − f_b‖_n² = (1/n) Σ_i (f_a(x_i) − f_b(x_i))²  (empirical L2 norm)."""
    d = f_a - f_b
    return jnp.mean(d * d)
