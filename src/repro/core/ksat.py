"""K-satisfiability (paper Definition 3) — the property driving Theorem 6.

A sketch S is K-satisfiable for δ if
  ‖U₁ᵀ S Sᵀ U₁ − I_{d_δ}‖_op ≤ 1/2
  ‖Sᵀ U₂ Σ₂^{1/2}‖_op ≤ c √δ
where U₁ spans the top-d_δ eigenspace of K/n. Used by tests/benchmarks to
verify Theorem 8's (d, m) conditions empirically.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.leverage import KrrSpectrum, d_delta, spectrum
from repro.core.sketch import AccumSketch


class KSatResult(NamedTuple):
    """Outcome of the K-satisfiability certificate (see ``ksat_check``)."""

    top_deviation: jax.Array     # ‖U₁ᵀSSᵀU₁ − I‖_op
    tail_norm: jax.Array         # ‖SᵀU₂Σ₂^{1/2}‖_op
    tail_bound: jax.Array        # c·√δ reference (c=1)
    satisfied: jax.Array         # bool for c = 2 (constant from the theorem)


def ksat_check(
    K: jax.Array, S_or_sketch, delta: float,
    spec: KrrSpectrum | None = None, c: float = 2.0,
) -> KSatResult:
    """K-satisfiability certificate for a drawn sketch: the top d_δ
    eigendirections must be near-isometrically preserved
    (‖U₁ᵀS SᵀU₁ − I‖ ≤ 1/2) and the spectral tail must stay small
    (‖SᵀU₂Σ₂^{1/2}‖ ≤ c√δ).  A sketch that passes supports the paper's
    downstream KRR/spectral error bounds at level δ."""
    spec = spec or spectrum(K)
    dd = max(d_delta(spec, delta), 1)
    if isinstance(S_or_sketch, AccumSketch):
        S = S_or_sketch.dense()
    else:
        S = S_or_sketch
    U1 = spec.eigvecs[:, :dd]
    U2 = spec.eigvecs[:, dd:]
    s2 = jnp.sqrt(jnp.maximum(spec.eigvals[dd:], 0.0))
    StU1 = S.T @ U1                                   # (d, d_δ)
    top = StU1.T @ StU1 - jnp.eye(dd, dtype=S.dtype)
    top_dev = jnp.linalg.norm(top, ord=2)
    tail = (S.T @ U2) * s2[None, :]                   # Sᵀ U₂ Σ₂^{1/2}
    tail_norm = jnp.linalg.norm(tail, ord=2)
    bound = jnp.sqrt(jnp.asarray(delta, S.dtype))
    ok = (top_dev <= 0.5) & (tail_norm <= c * bound)
    return KSatResult(top_dev, tail_norm, bound, ok)
