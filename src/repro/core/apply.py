"""Structural application of an AccumSketch — the paper's efficiency claim.

The identities (paper §3.3):

    K S     = Σ_i K S_(i)          — O(n·m·d) instead of O(n²·d)
    Sᵀ K S  = Σ_i S_(i)ᵀ (K S)     — O(m·d²)  instead of O(n·d²)

Because each S_(i) has one non-zero per column, K S_(i) is a signed/rescaled
column gather of K, and S_(i)ᵀ M is a signed/rescaled row gather of M.
None of these routines materializes S.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sketch import AccumSketch


def sketch_right(K: jax.Array, sk: AccumSketch) -> jax.Array:
    """K S for K of shape (r, n) → (r, d). O(r·m·d)."""
    cols = jnp.take(K, sk.indices.reshape(-1), axis=1)          # (r, m*d)
    cols = cols.reshape(K.shape[0], sk.m, sk.d)
    return jnp.einsum("rmd,md->rd", cols, sk.coef)


def sketch_left(sk: AccumSketch, M: jax.Array) -> jax.Array:
    """Sᵀ M for M of shape (n, c) → (d, c). O(m·d·c)."""
    rows = jnp.take(M, sk.indices.reshape(-1), axis=0)           # (m*d, c)
    rows = rows.reshape(sk.m, sk.d, M.shape[-1])
    return jnp.einsum("mdc,md->dc", rows, sk.coef)


def sketch_vec(sk: AccumSketch, v: jax.Array) -> jax.Array:
    """Sᵀ v for v of shape (n,) → (d,)."""
    return sketch_left(sk, v[:, None])[:, 0]


def unsketch_vec(sk: AccumSketch, w: jax.Array) -> jax.Array:
    """S w for w of shape (d,) → (n,) via segment-sum (scatter-add)."""
    contrib = (sk.coef * w[None, :]).reshape(-1)                 # (m*d,)
    return jnp.zeros((sk.n,), w.dtype).at[sk.indices.reshape(-1)].add(contrib)


def unsketch_mat(sk: AccumSketch, W: jax.Array) -> jax.Array:
    """S W for W of shape (d, c) → (n, c)."""
    contrib = sk.coef[..., None] * W[None, ...]                  # (m, d, c)
    return (
        jnp.zeros((sk.n, W.shape[-1]), W.dtype)
        .at[sk.indices.reshape(-1)]
        .add(contrib.reshape(-1, W.shape[-1]))
    )


def sketch_both(K: jax.Array, sk: AccumSketch) -> tuple[jax.Array, jax.Array]:
    """(K S, Sᵀ K S) sharing the K S intermediate, as in the paper."""
    KS = sketch_right(K, sk)
    return KS, sketch_left(sk, KS)


def gram_sketch(sk: AccumSketch) -> jax.Array:
    """Sᵀ S (d, d) without materializing S.

    SᵀS[j,j'] = Σ over coincident indices of coef products; computed via the
    (m·d)-sparse representation: SᵀS = CᵀC where C is the (n, d) dense form —
    but done through a (m·d)² coincidence check, O((md)²) ≪ O(n d²) when md ≪ n.
    """
    idx = sk.indices.reshape(-1)     # (md,)
    cf = sk.coef.reshape(-1)         # (md,)
    coincide = (idx[:, None] == idx[None, :]).astype(cf.dtype)   # (md, md)
    weighted = coincide * (cf[:, None] * cf[None, :])
    # column of S each flat entry belongs to:
    col = jnp.tile(jnp.arange(sk.d), sk.m)
    onehot = jax.nn.one_hot(col, sk.d, dtype=cf.dtype)           # (md, d)
    return onehot.T @ weighted @ onehot


def sketch_kernel_cols(
    X: jax.Array, sk: AccumSketch, kernel_fn, *, chunk: int | None = None
) -> jax.Array:
    """C = K S without ever forming K:  O(n·m·d) kernel evaluations.

    kernel_fn(A, B) -> (|A|, |B|) kernel matrix. Gathers the m·d landmark points,
    evaluates the (n, m·d) slab, and contracts with the combination coefficients.
    `chunk` optionally processes rows of X in chunks to bound peak memory.
    """
    landmarks = jnp.take(X, sk.indices.reshape(-1), axis=0)      # (m*d, d_X)

    def _block(xb):
        slab = kernel_fn(xb, landmarks)                          # (b, m*d)
        return jnp.einsum("bmd,md->bd", slab.reshape(xb.shape[0], sk.m, sk.d), sk.coef)

    if chunk is None or X.shape[0] <= chunk:
        return _block(X)
    nfull = (X.shape[0] // chunk) * chunk
    body = jax.lax.map(_block, X[:nfull].reshape(-1, chunk, X.shape[1]))
    out = body.reshape(nfull, sk.d)
    if nfull < X.shape[0]:
        out = jnp.concatenate([out, _block(X[nfull:])], axis=0)
    return out
