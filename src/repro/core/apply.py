"""Structural application of an AccumSketch — the paper's efficiency claim.

The identities (paper §3.3):

    K S     = Σ_i K S_(i)          — O(n·m·d) instead of O(n²·d)
    Sᵀ K S  = Σ_i S_(i)ᵀ (K S)     — O(m·d²)  instead of O(n·d²)

Because each S_(i) has one non-zero per column, K S_(i) is a signed/rescaled
column gather of K, and S_(i)ᵀ M is a signed/rescaled row gather of M.
None of these routines materializes S.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sketch import AccumSketch
from repro.util import env_flag


def default_use_kernel() -> bool:
    """Route structural applications through the Pallas kernels by default on
    TPU (compiled MXU path); XLA's fused gathers win elsewhere.

    Overridable with REPRO_SKETCH_KERNEL=0/1."""
    return env_flag("REPRO_SKETCH_KERNEL", jax.default_backend() == "tpu")


def sketch_right(K: jax.Array, sk: AccumSketch) -> jax.Array:
    """K S for K of shape (r, n) → (r, d). O(r·m·d)."""
    cols = jnp.take(K, sk.indices.reshape(-1), axis=1)          # (r, m*d)
    cols = cols.reshape(K.shape[0], sk.m, sk.d)
    return jnp.einsum("rmd,md->rd", cols, sk.coef)


def sketch_left(sk: AccumSketch, M: jax.Array) -> jax.Array:
    """Sᵀ M for M of shape (n, c) → (d, c). O(m·d·c)."""
    rows = jnp.take(M, sk.indices.reshape(-1), axis=0)           # (m*d, c)
    rows = rows.reshape(sk.m, sk.d, M.shape[-1])
    return jnp.einsum("mdc,md->dc", rows, sk.coef)


def sketch_vec(sk: AccumSketch, v: jax.Array) -> jax.Array:
    """Sᵀ v for v of shape (n,) → (d,)."""
    return sketch_left(sk, v[:, None])[:, 0]


def unsketch_vec(sk: AccumSketch, w: jax.Array) -> jax.Array:
    """S w for w of shape (d,) → (n,) via segment-sum (scatter-add)."""
    contrib = (sk.coef * w[None, :]).reshape(-1)                 # (m*d,)
    return jnp.zeros((sk.n,), w.dtype).at[sk.indices.reshape(-1)].add(contrib)


def unsketch_mat(sk: AccumSketch, W: jax.Array) -> jax.Array:
    """S W for W of shape (d, c) → (n, c)."""
    contrib = sk.coef[..., None] * W[None, ...]                  # (m, d, c)
    return (
        jnp.zeros((sk.n, W.shape[-1]), W.dtype)
        .at[sk.indices.reshape(-1)]
        .add(contrib.reshape(-1, W.shape[-1]))
    )


def sketch_both(
    K: jax.Array, sk: AccumSketch, *, use_kernel: bool | None = None
) -> tuple[jax.Array, jax.Array]:
    """(K S, Sᵀ K S) sharing the K S intermediate, as in the paper.

    With ``use_kernel`` (auto: True on TPU) the pair is computed by the fused
    single-sweep Pallas kernel — one pass over K, W accumulated in-kernel —
    instead of two gather passes."""
    if use_kernel is None:
        use_kernel = default_use_kernel()
    if use_kernel:
        from repro.kernels.accum_apply.ops import sketch_both_kernel
        # W stays float32: it was accumulated in f32 VMEM and feeds the d×d
        # solve — downcasting to a low-precision K dtype would throw that away
        return sketch_both_kernel(K, sk)
    KS = sketch_right(K, sk)
    return KS, sketch_left(sk, KS)


def gram_sketch(sk: AccumSketch) -> jax.Array:
    """Sᵀ S (d, d) without materializing S.

    Scatter-add formulation: the m·d non-zero entries are grouped by their row
    index (segment-sum over the ≤ m·d *distinct* sampled rows), giving the
    compressed (md, d) row block B with B[rank(k), j] = S[k, j]; then
    SᵀS = BᵀB. O(m·d) scatter + one (d × md × d) GEMM, O(m·d²) memory —
    replaces the seed's (md)² coincidence matrix, which blew up at
    production m·d."""
    idx = sk.indices.reshape(-1)     # (md,)
    cf = sk.coef.reshape(-1)         # (md,)
    col = jnp.tile(jnp.arange(sk.d), sk.m)
    # rank of each entry among the distinct sampled rows (static size: md)
    _, ranks = jnp.unique(idx, return_inverse=True, size=idx.shape[0],
                          fill_value=-1)
    B = jnp.zeros((idx.shape[0], sk.d), cf.dtype).at[ranks, col].add(cf)
    return B.T @ B


def sketch_kernel_cols(
    X: jax.Array, sk: AccumSketch, kernel_fn, *, chunk: int | None = None
) -> jax.Array:
    """C = K S without ever forming K:  O(n·m·d) kernel evaluations.

    kernel_fn(A, B) -> (|A|, |B|) kernel matrix. Gathers the m·d landmark points,
    evaluates the (n, m·d) slab, and contracts with the combination coefficients.
    `chunk` optionally processes rows of X in chunks to bound peak memory.
    """
    landmarks = jnp.take(X, sk.indices.reshape(-1), axis=0)      # (m*d, d_X)

    def _block(xb):
        slab = kernel_fn(xb, landmarks)                          # (b, m*d)
        return jnp.einsum("bmd,md->bd", slab.reshape(xb.shape[0], sk.m, sk.d), sk.coef)

    if chunk is None or X.shape[0] <= chunk:
        return _block(X)
    nfull = (X.shape[0] // chunk) * chunk
    body = jax.lax.map(_block, X[:nfull].reshape(-1, chunk, X.shape[1]))
    out = body.reshape(nfull, sk.d)
    if nfull < X.shape[0]:
        out = jnp.concatenate([out, _block(X[nfull:])], axis=0)
    return out
