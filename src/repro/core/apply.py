"""Structural application of an AccumSketch — the paper's efficiency claim.

The identities (paper §3.3):

    K S     = Σ_i K S_(i)          — O(n·m·d) instead of O(n²·d)
    Sᵀ K S  = Σ_i S_(i)ᵀ (K S)     — O(m·d²)  instead of O(n·d²)

Because each S_(i) has one non-zero per column, K S_(i) is a signed/rescaled
column gather of K, and S_(i)ᵀ M is a signed/rescaled row gather of M.
None of these routines materializes S.

The PROGRESSIVE ACCUMULATION ENGINE (``accum_init`` / ``accum_step`` /
``accum_grow`` / ``accum_grow_adaptive`` / ``grow_sketch_both``) turns the
one-shot sketch into the paper's actual strategy: grow m step-by-step,
folding one new sub-sampling matrix into the running (C, W) with a rank-d
incremental update,

    S_{m+1} = sqrt(m/(m+1))·S_m + T̃_{m+1}
    C_{m+1} = sqrt(m/(m+1))·C_m + K T̃_{m+1}             (one column gather)
    W_{m+1} = (m/(m+1))·W_m + a·(T̃ᵀC_m + C_mᵀT̃) + T̃ᵀK T̃  (row gathers)

at O(n·d) per step instead of the O(n·m·d) from-scratch recompute — so a
cheap sampling distribution (uniform / approximate leverage) can buy accuracy
by growing m until a plug-in error estimate clears the caller's tolerance.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.analysis.streams import HOLDOUT_STREAM as _HOLDOUT_STREAM
from repro.analysis.streams import REFINE_STREAM as _REFINE_STREAM
from repro.core.sketch import AccumSketch, AccumState, make_accum_sketch
from repro.util import env_flag


def default_use_kernel() -> bool:
    """Route structural applications through the Pallas kernels by default on
    TPU (compiled MXU path); XLA's fused gathers win elsewhere.

    Overridable with REPRO_SKETCH_KERNEL=0/1."""
    return env_flag("REPRO_SKETCH_KERNEL", jax.default_backend() == "tpu")


def _operator(K):
    """The KernelOperator behind K, or None for a dense array.

    Every K-consuming routine here dispatches through this so callers can pass
    either a materialized (n, n) kernel matrix or the matrix-free
    ``repro.core.kernel_op.KernelOperator`` (lazy import: kernel_op imports
    this module for the structural applications)."""
    from repro.core.kernel_op import KernelOperator

    return K if isinstance(K, KernelOperator) else None


def sketch_right(K: jax.Array, sk: AccumSketch) -> jax.Array:
    """K S for K of shape (r, n) → (r, d). O(r·m·d)."""
    cols = jnp.take(K, sk.indices.reshape(-1), axis=1)          # (r, m*d)
    cols = cols.reshape(K.shape[0], sk.m, sk.d)
    return jnp.einsum("rmd,md->rd", cols, sk.coef)


def sketch_left(sk: AccumSketch, M: jax.Array) -> jax.Array:
    """Sᵀ M for M of shape (n, c) → (d, c). O(m·d·c)."""
    rows = jnp.take(M, sk.indices.reshape(-1), axis=0)           # (m*d, c)
    rows = rows.reshape(sk.m, sk.d, M.shape[-1])
    return jnp.einsum("mdc,md->dc", rows, sk.coef)


def sketch_vec(sk: AccumSketch, v: jax.Array) -> jax.Array:
    """Sᵀ v for v of shape (n,) → (d,)."""
    return sketch_left(sk, v[:, None])[:, 0]


def unsketch_vec(sk: AccumSketch, w: jax.Array) -> jax.Array:
    """S w for w of shape (d,) → (n,) via segment-sum (scatter-add)."""
    contrib = (sk.coef * w[None, :]).reshape(-1)                 # (m*d,)
    return jnp.zeros((sk.n,), w.dtype).at[sk.indices.reshape(-1)].add(contrib)


def unsketch_mat(sk: AccumSketch, W: jax.Array) -> jax.Array:
    """S W for W of shape (d, c) → (n, c)."""
    contrib = sk.coef[..., None] * W[None, ...]                  # (m, d, c)
    return (
        jnp.zeros((sk.n, W.shape[-1]), W.dtype)
        .at[sk.indices.reshape(-1)]
        .add(contrib.reshape(-1, W.shape[-1]))
    )


def sketch_both(
    K: jax.Array, sk: AccumSketch, *, use_kernel: bool | None = None,
    mesh=None,
) -> tuple[jax.Array, jax.Array]:
    """(K S, Sᵀ K S) sharing the K S intermediate, as in the paper.

    ``K`` may be a dense (n, n) array or a matrix-free ``KernelOperator`` —
    the operator path streams kernel evaluations in row tiles and never
    allocates the n×n matrix.  With ``use_kernel`` (auto: True on TPU) the
    dense pair is computed by the fused single-sweep Pallas kernel — one pass
    over K, W accumulated in-kernel — instead of two gather passes (the
    operator routes through the fused kernel-eval→GEMM kernel instead).

    ``mesh`` (a ``("data",)`` mesh / True / a device count — operator only)
    row-shards X and C over the devices: per-device kernel-eval tiles, W
    psum-reduced (``repro.core.distributed``)."""
    op = _operator(K)
    if mesh is not None:
        from repro.core import distributed as D

        return D.sharded_sketch_both(D._operator_required(K), sk,
                                     D.resolve_mesh(mesh),
                                     use_kernel=use_kernel)
    if op is not None:
        return op.sketch_both(sk, use_kernel=use_kernel)
    if use_kernel is None:
        use_kernel = default_use_kernel()

    def _xla():
        KS = sketch_right(K, sk)
        return KS, sketch_left(sk, KS)

    if use_kernel:
        from repro.kernels.accum_apply.ops import sketch_both_kernel
        from repro.resilience.degrade import ladder_call

        # W stays float32: it was accumulated in f32 VMEM and feeds the d×d
        # solve — downcasting to a low-precision K dtype would throw that away.
        # A failing Pallas dispatch degrades to the XLA gather pair (recorded
        # in the global HealthReport), never to a wrong answer.
        return ladder_call("kernel.dispatch", (
            ("pallas:sketch_both", lambda: sketch_both_kernel(K, sk)),
            ("xla:gather", _xla),
        ))
    return _xla()


def gram_sketch(sk: AccumSketch) -> jax.Array:
    """Sᵀ S (d, d) without materializing S.

    Scatter-add formulation: the m·d non-zero entries are grouped by their row
    index (segment-sum over the ≤ m·d *distinct* sampled rows), giving the
    compressed (md, d) row block B with B[rank(k), j] = S[k, j]; then
    SᵀS = BᵀB. O(m·d) scatter + one (d × md × d) GEMM, O(m·d²) memory —
    replaces the seed's (md)² coincidence matrix, which blew up at
    production m·d."""
    idx = sk.indices.reshape(-1)     # (md,)
    cf = sk.coef.reshape(-1)         # (md,)
    col = jnp.tile(jnp.arange(sk.d), sk.m)
    # rank of each entry among the distinct sampled rows (static size: md)
    _, ranks = jnp.unique(idx, return_inverse=True, size=idx.shape[0],
                          fill_value=-1)
    B = jnp.zeros((idx.shape[0], sk.d), cf.dtype).at[ranks, col].add(cf)
    return B.T @ B


# --------------------------------------------------------------------------- #
# Progressive accumulation engine
# --------------------------------------------------------------------------- #

def _psd_apply_pinv(W: jax.Array, B: jax.Array, jitter: float = 1e-6) -> jax.Array:
    """W⁺ B for PSD W via trace-scaled jitter + Cholesky (d×d, cheap)."""
    d = W.shape[0]
    eps = jitter * (jnp.trace(W) / d) + 1e-30
    L, lower = jax.scipy.linalg.cho_factor(
        W + eps * jnp.eye(d, dtype=W.dtype), lower=True)
    return jax.scipy.linalg.cho_solve((L, lower), B)


def accum_init(key: jax.Array, n: int, d: int, m_max: int,
               probs: jax.Array | None = None, *, signed: bool = True,
               scheme: str = "uniform") -> AccumState:
    """Draw all ``m_max`` sub-sampling matrices up front (same RNG scheme as
    ``make_accum_sketch``, so growing to m_max replays the one-shot draw at
    m_max exactly; a stop at m < m_max yields a prefix of that draw) and
    return the empty accumulation state.

    ``scheme`` threads to the constructor (``"poisson"`` pre-draws Poisson
    slabs; ``"leverage"`` starts from ``probs`` — or uniform when ``None`` —
    and lets the grow drivers refine the tail as m grows).  ``pdraw`` records
    the probabilities of the initial draw."""
    if scheme == "leverage":
        # the engine refines leverage probs itself — seed the pre-draw from
        # the caller's pilot distribution (or uniform), NOT the one-shot
        # constructor, which demands explicit leverage probs
        sk = make_accum_sketch(key, n, d, m_max, probs, signed=signed)
        sk = dataclasses.replace(sk, scheme=scheme)
    else:
        sk = make_accum_sketch(key, n, d, m_max, probs, signed=signed,
                               scheme=scheme)
    return AccumState(
        indices=sk.indices, signs=sk.signs, probs=sk.probs,
        pdraw=jnp.take(sk.probs, sk.indices, axis=0),
        C=jnp.zeros((n, d), jnp.float32), W=jnp.zeros((d, d), jnp.float32),
        m=jnp.zeros((), jnp.int32), err=jnp.full((), jnp.inf, jnp.float32),
        n=n, scheme=scheme,
    )


def slab_pieces(state: AccumState):
    """(idx_new, coef_new, a) for folding slab number ``state.m``: the new
    sub-sampling matrix's indices, its combination coefficients normalized
    for the GROWN size m = t+1 (coef = r / sqrt(d (t+1) p)), and the
    survivors' rescale a = sqrt(t/(t+1)) (t=0 → 0: C_1 = K T̃_1).

    Shared by the dense and sharded (``repro.core.distributed``) engines so
    the normalization cannot drift between them."""
    t = state.m
    tf = t.astype(jnp.float32)
    d = state.d
    idx_new = jax.lax.dynamic_index_in_dim(state.indices, t, axis=0,
                                           keepdims=False)
    sgn_new = jax.lax.dynamic_index_in_dim(state.signs, t, axis=0,
                                           keepdims=False)
    # at-draw probabilities, NOT take(probs, indices): the leverage scheme
    # refines probs while m grows and the slab keeps the distribution it was
    # actually drawn from
    p_new = jax.lax.dynamic_index_in_dim(state.pdraw, t, axis=0,
                                         keepdims=False).astype(jnp.float32)
    coef_new = sgn_new.astype(jnp.float32) / jnp.sqrt(d * (tf + 1.0) * p_new)
    a = jnp.sqrt(tf / (tf + 1.0))
    return idx_new, coef_new, a


def slab_w_update(state: AccumState, TtC: jax.Array, Ksub: jax.Array,
                  coef_new: jax.Array, a: jax.Array) -> jax.Array:
    """The W recurrence for one slab, from the d×d pieces:
    W_{t+1} = a²·W_t + a·(T̃ᵀC + (T̃ᵀC)ᵀ) + T̃ᵀK T̃, exact-arithmetic
    symmetrized.  Shared by the dense and sharded engines."""
    TtKT = coef_new[:, None] * Ksub.astype(jnp.float32) * coef_new[None, :]
    W_new = (a * a) * state.W + a * (TtC + TtC.T) + TtKT
    return 0.5 * (W_new + W_new.T)


def batch_pieces(state: AccumState, B: int):
    """(idx_blk, coef_blk, a) for folding slabs [t, t+B) in ONE batch: the
    B-row index/coefficient block normalized directly for the GROWN size
    t+B (coef = r / sqrt(d (t+B) p)) and the telescoped survivor rescale
    a = sqrt(t/(t+B)) — the per-step sqrt(k/(k+1)) rescales of B sequential
    ``slab_pieces`` steps collapse into exactly these two factors, which is
    what makes the batch one pass instead of B.

    Shared by the dense and sharded engines (same reason as ``slab_pieces``).
    ``B`` must be static; the caller guarantees t + B ≤ m_max (the slice
    would clamp and silently re-read earlier slabs otherwise)."""
    t = state.m
    tf = t.astype(jnp.float32)
    d = state.d
    idx_blk = jax.lax.dynamic_slice_in_dim(state.indices, t, B, axis=0)
    sgn_blk = jax.lax.dynamic_slice_in_dim(state.signs, t, B, axis=0)
    # at-draw probabilities (see slab_pieces) — leverage refines state.probs
    p_blk = jax.lax.dynamic_slice_in_dim(state.pdraw, t, B,
                                         axis=0).astype(jnp.float32)
    coef_blk = sgn_blk.astype(jnp.float32) / jnp.sqrt(d * (tf + B) * p_blk)
    a = jnp.sqrt(tf / (tf + B))
    return idx_blk, coef_blk, a


def block_left(idx_blk: jax.Array, coef_blk: jax.Array, M: jax.Array) -> jax.Array:
    """Tᵀ M (d, c) for the batch block T described by idx/coef (B, d): a
    B·d-row gather of M contracted with the coefficients — the d×d W pieces
    of the batched update (TᵀC from the running C, TᵀKT = Tᵀ(KT) from the
    same G the C update produced; no second pass over anything n-sized)."""
    B, d = idx_blk.shape
    rows = jnp.take(M, idx_blk.reshape(-1), axis=0).reshape(B, d, M.shape[-1])
    return jnp.einsum("bdc,bd->dc", rows.astype(jnp.float32), coef_blk)


def batch_w_update(state: AccumState, TtC: jax.Array, TtG: jax.Array,
                   a: jax.Array) -> jax.Array:
    """The batched W recurrence: W_{t+B} = a²·W_t + a·(TᵀC + (TᵀC)ᵀ) + TᵀKT,
    exact-arithmetic symmetrized.  Shared by the dense and sharded engines."""
    W_new = (a * a) * state.W + a * (TtC + TtC.T) + TtG
    return 0.5 * (W_new + W_new.T)


def finish_grow(state: AccumState, m_max: int, passes: jax.Array | None = None):
    """The grow drivers' shared return contract: (sketch, C, W, info) with
    jax-scalar info and the trace-safe masked sketch under a tracer.
    ``passes`` is the number of data sweeps the growth took (== m on the
    unit schedule, O(log m) on the doubling schedule)."""
    info = {"m": state.m, "m_max": m_max, "err": state.err,
            "passes": state.m if passes is None else passes}
    if isinstance(state.m, jax.core.Tracer):
        return state.masked_sketch(), state.C, state.W, info
    return state.sketch(), state.C, state.W, info


def _concrete_args(*trees) -> bool:
    """True iff no leaf is a tracer — the condition for routing through the
    buffer-donating jitted wrappers (nested jit would silently drop the
    donation and warn)."""
    return not any(isinstance(leaf, jax.core.Tracer)
                   for t in trees for leaf in jax.tree_util.tree_leaves(t))


def accum_step(K: jax.Array, state: AccumState, *,
               use_kernel: bool | None = None, mesh=None) -> AccumState:
    """Fold ONE new sub-sampling matrix into (C, W): the rank-d incremental
    update, O(n·d) per step.

    ``K`` may be dense or a ``KernelOperator`` — the operator evaluates the
    slab's column block K(X, X[idx]) directly from data (O(n·d) kernel evals,
    the matrix-free analogue of the column gather) and the d×d piece from d²
    evals.  With ``use_kernel`` (auto: True on TPU) the dense C update runs
    through the single-slab Pallas entry point (``sketch_step_kernel``) and
    the operator through the fused matfree kernel; the W pieces are d×d
    gathers either way.  ``mesh`` (operator only) computes the slab's column
    block per data shard and psum-reduces the T̃ᵀC gather."""
    if mesh is not None:
        from repro.core import distributed as D

        return D.sharded_accum_step(K, state, mesh, use_kernel=use_kernel)
    op = _operator(K)
    if use_kernel is None:
        use_kernel = default_use_kernel()
    t = state.m
    idx_new, coef_new, a = slab_pieces(state)

    # W update from d×d gathers only:  T̃ᵀC_t and (T̃ᵀK T̃)[i,j] = c_i K[n_i,n_j] c_j
    TtC = coef_new[:, None] * jnp.take(state.C, idx_new, axis=0)
    if op is not None:
        Ksub = op.submatrix(idx_new, idx_new)
    else:
        Ksub = jnp.take(jnp.take(K, idx_new, axis=0), idx_new, axis=1)
    W_new = slab_w_update(state, TtC, Ksub, coef_new, a)

    if op is not None:
        G = op.weighted_cols(op.X, idx_new[None, :], coef_new[None, :],
                             use_kernel=use_kernel)
        # the loop carry C is always f32 (AccumState contract); an f64
        # operator (x64 mode) must not promote it or the while/fori carry
        # dtype check rejects the step
        C_new = a * state.C + G.astype(jnp.float32)
    elif use_kernel:
        from repro.kernels.accum_apply.ops import sketch_step_kernel
        C_new = sketch_step_kernel(K, idx_new, coef_new, state.C, a)
    else:
        G = jnp.take(K, idx_new, axis=1).astype(jnp.float32) * coef_new[None, :]
        C_new = a * state.C + G
    return dataclasses.replace(state, C=C_new, W=W_new, m=t + 1)


@functools.partial(jax.jit, static_argnames=("steps", "use_kernel"),
                   donate_argnums=(1,))
def _grow_loop_donated(K, state: AccumState, steps: int,
                       use_kernel: bool) -> AccumState:
    """The unconditional growth loop under jit with the state DONATED: the
    incoming (C, W) buffers are reused for the outputs, so an eager grow call
    keeps one n·d C resident instead of functionally rebuilding a second."""
    def body(_, s):
        return accum_step(K, s, use_kernel=use_kernel)

    return jax.lax.fori_loop(0, steps, body, state)


def accum_grow(K: jax.Array, state: AccumState, steps: int, *,
               use_kernel: bool | None = None, mesh=None,
               donate: bool = True) -> AccumState:
    """Unconditionally fold in ``steps`` more slabs (``lax.fori_loop``).

    Eager calls route through a jitted wrapper that DONATES the state — the
    caller's ``state`` buffers are consumed (its C/W must not be reused
    afterwards; pass ``donate=False`` to keep them, e.g. when timing repeated
    calls on the same state).  Traced calls inline (nested donation would be
    dropped silently)."""
    if mesh is not None:
        from repro.core import distributed as D

        return D.sharded_accum_grow(K, state, steps, mesh,
                                    use_kernel=use_kernel)
    if use_kernel is None:
        use_kernel = default_use_kernel()
    if donate and _concrete_args(K, state):
        return _grow_loop_donated(K, state, steps, use_kernel)

    def body(_, s):
        return accum_step(K, s, use_kernel=use_kernel)

    return jax.lax.fori_loop(0, steps, body, state)


def _accum_grow_batched_impl(K, state: AccumState, B: int,
                             use_kernel: bool) -> AccumState:
    op = _operator(K)
    idx_blk, coef_blk, a = batch_pieces(state, B)
    if op is not None:
        # ONE kernel-evaluation sweep for all B slabs: the fused Pallas
        # kernel-eval→GEMM kernel takes the (B, d) block whole (the MXU wants
        # the wide GEMM); the streaming path accumulates slab-by-slab at the
        # narrow GEMM shape (``stream_cols_slabs`` — XLA's wide-output CPU
        # tiling degrades ~2× by B·d ≈ 1024).  TᵀKT reuses G, no extra evals
        if use_kernel:
            G = op.weighted_cols(op.X, idx_blk, coef_blk,
                                 use_kernel=True).astype(jnp.float32)
        else:
            from repro.core.kernel_op import stream_cols_slabs

            lm = jnp.take(op.X, idx_blk.reshape(-1), axis=0)
            G = stream_cols_slabs(op.X, lm, coef_blk,
                                  op.kernel_fn).astype(jnp.float32)
        C_new = a * state.C + G
        TtG = block_left(idx_blk, coef_blk, G)
        TtC = block_left(idx_blk, coef_blk, state.C)
    elif use_kernel:
        from repro.kernels.accum_apply.ops import accum_grow_kernel
        C_new, TtG, TtC = accum_grow_kernel(K, idx_blk, coef_blk, state.C, a)
    else:
        n = K.shape[0]
        cols = jnp.take(K, idx_blk.reshape(-1), axis=1).astype(jnp.float32)
        G = jnp.einsum("nbd,bd->nd", cols.reshape(n, B, state.d), coef_blk)
        C_new = a * state.C + G
        TtG = block_left(idx_blk, coef_blk, G)
        TtC = block_left(idx_blk, coef_blk, state.C)
    W_new = batch_w_update(state, TtC, TtG, a)
    return dataclasses.replace(state, C=C_new, W=W_new, m=state.m + B)


@functools.partial(jax.jit, static_argnames=("B", "use_kernel"),
                   donate_argnums=(1,))
def _grow_batched_donated(K, state: AccumState, B: int,
                          use_kernel: bool) -> AccumState:
    return _accum_grow_batched_impl(K, state, B, use_kernel)


def accum_grow_batched(K: jax.Array, state: AccumState, B: int, *,
                       use_kernel: bool | None = None, mesh=None,
                       donate: bool = True) -> AccumState:
    """Fold the next ``B`` pre-drawn slabs into (C, W) in ONE pass over the
    data — the batched rank-B counterpart of ``accum_step``.

    The per-step survivor rescales telescope (``batch_pieces``), so the whole
    batch is: one column-block application G = K·T (a single fused Pallas
    launch / kernel-eval sweep / gather, read of K or X exactly once), the
    C update a·C + G, and two d×d gathers for W — bitwise-identical in draws
    to B sequential ``accum_step`` calls (same pre-drawn indices/signs) and
    ≤ 1e-5-rel-equivalent in (C, W) values (summation order only).

    Eager calls donate the state buffers as in ``accum_grow``
    (``donate=False`` opts out).  ``B`` must be static, with
    state.m + B ≤ m_max."""
    # validate BEFORE the mesh dispatch: an overrun would make batch_pieces'
    # dynamic_slice clamp and silently re-fold earlier slabs on either path
    if not 1 <= B <= state.m_max:
        raise ValueError(f"batch size B={B} outside [1, m_max={state.m_max}]")
    if not isinstance(state.m, jax.core.Tracer) and int(state.m) + B > state.m_max:
        raise ValueError(
            f"batch of {B} slabs from m={int(state.m)} overruns the "
            f"pre-drawn m_max={state.m_max}")
    if mesh is not None:
        from repro.core import distributed as D

        return D.sharded_accum_grow_batched(K, state, B, mesh,
                                            use_kernel=use_kernel)
    if use_kernel is None:
        use_kernel = default_use_kernel()
    if donate and _concrete_args(K, state):
        return _grow_batched_donated(K, state, B, use_kernel)
    return _accum_grow_batched_impl(K, state, B, use_kernel)


def doubling_schedule(m_start: int, m_max: int) -> list[int]:
    """Static batch sizes 1, 2, 4, … (clamped into the remaining budget) that
    grow ``m_start`` → ``m_max``: O(log m_max) batches, each ONE data pass,
    instead of m_max unit steps."""
    out, t, B = [], m_start, 1
    while t < m_max:
        b = min(B, m_max - t)
        out.append(b)
        t += b
        B *= 2
    return out


def make_holdout_estimator(key: jax.Array, K: jax.Array, num: int = 64,
                           *, jitter: float = 1e-6, mesh=None):
    """Plug-in stopping rule: relative Nyström-reconstruction error of the
    sketched operator K̂ = C W⁺ Cᵀ on a fixed random holdout principal
    submatrix — O(h²·d + d³) per evaluation, independent of n.  With a
    ``KernelOperator`` the h×h holdout block comes from h² kernel evals;
    with ``mesh`` the C row gather additionally psum-reduces over the data
    shards (same key → the same holdout draw)."""
    if mesh is not None:
        from repro.core import distributed as D

        return D.make_sharded_holdout_estimator(key, K, mesh, num,
                                                jitter=jitter)
    op = _operator(K)
    n = K.shape[0]
    hold = jax.random.choice(key, n, shape=(min(num, n),), replace=False)
    if op is not None:
        Kh = op.submatrix(hold, hold).astype(jnp.float32)
    else:
        Kh = jnp.take(jnp.take(K, hold, axis=0), hold, axis=1).astype(jnp.float32)
    denom = jnp.maximum(jnp.linalg.norm(Kh), 1e-30)

    def estimate(state: AccumState) -> jax.Array:
        Ch = jnp.take(state.C, hold, axis=0)
        Khat = Ch @ _psd_apply_pinv(state.W, Ch.T, jitter)
        est = jnp.linalg.norm(Kh - Khat) / denom
        return jnp.where(jnp.isfinite(est), est, jnp.inf).astype(jnp.float32)

    return estimate


def make_hutchinson_estimator(key: jax.Array, K: jax.Array, num_probes: int = 8,
                              *, jitter: float = 1e-6, mesh=None):
    """Plug-in stopping rule: Hutchinson estimate of the relative trace
    residual tr(K − K̂)/tr̂(K) with Rademacher probes.  K Z is precomputed once
    (K is fixed while m grows), so each evaluation costs O(n·d·q + d³).  The
    Nyström residual of a PSD K is PSD, so the estimate is a true error.
    With a ``KernelOperator`` the one-time K Z is a streamed matvec —
    O(n²·p·q) kernel-eval compute but O(chunk·n) memory, never n²; with
    ``mesh`` the matvec rows and every CᵀZ contraction stay per-shard."""
    if mesh is not None:
        from repro.core import distributed as D

        return D.make_sharded_hutchinson_estimator(key, K, mesh, num_probes,
                                                   jitter=jitter)
    op = _operator(K)
    n = K.shape[0]
    Z = jax.random.rademacher(key, (n, num_probes), dtype=jnp.float32)
    if op is not None:
        KZ = op.matvec(Z)                              # streamed, O(chunk·n) mem
    else:
        KZ = K.astype(jnp.float32) @ Z                 # one-time O(n²·q)
    zKz = jnp.einsum("nq,nq->q", Z, KZ)
    denom = jnp.maximum(jnp.mean(zKz), 1e-30)

    def estimate(state: AccumState) -> jax.Array:
        CtZ = state.C.T @ Z                            # (d, q) — O(n·d·q)
        zKhatz = jnp.einsum("dq,dq->q", CtZ, _psd_apply_pinv(state.W, CtZ, jitter))
        est = jnp.maximum(jnp.mean(zKz - zKhatz), 0.0) / denom
        return jnp.where(jnp.isfinite(est), est, jnp.inf).astype(jnp.float32)

    return estimate


def doubling_ladder(state: AccumState, m_max: int, tol: float, apply_batch,
                    estimator, refine=None) -> tuple[AccumState, jax.Array]:
    """The shared doubling-schedule driver: static batch ladder, one
    ``lax.cond`` phase guard per batch (only the taken branch executes), the
    estimator once per batch.  ``apply_batch(state, B)`` is the backend —
    the dense/matfree ``accum_grow_batched`` or the sharded mapped sweep —
    so the stopping decisions cannot drift between engines.  Returns
    ``(state, passes)``.

    ``refine(state, phase) -> state`` (optional) runs after each executed
    batch — the leverage scheme's probability refresh + tail redraw
    (``schemes.refresh_tail``); it must preserve the state's pytree
    structure (pure masking, no shape changes) so it composes with the
    ``lax.cond`` phases.

    The schedule is laid out from the state's current m (assumed 0 under a
    tracer — the grow drivers always pass a fresh state); the per-phase
    guard ``m + B ≤ m_max`` makes overrunning the pre-drawn slabs impossible
    either way."""
    m0 = 0 if isinstance(state.m, jax.core.Tracer) else int(state.m)
    carry = (state, jnp.zeros((), jnp.int32))
    for i, B in enumerate(doubling_schedule(m0, m_max)):
        def do_batch(sp, B=B, i=i):
            s, p = sp
            s = apply_batch(s, B)
            s = dataclasses.replace(s, err=estimator(s))
            if refine is not None:
                s = refine(s, i)
            return s, p + 1

        s, _ = carry
        pred = jnp.logical_and(s.err > tol, s.m + B <= m_max)
        carry = jax.lax.cond(pred, do_batch, lambda sp: sp, carry)
    return carry


def make_leverage_refine(key: jax.Array, *, lam: float, mix: float = 0.1,
                         signed: bool = True):
    """Build the leverage scheme's per-phase refine callback for the grow
    drivers: estimate ridge-leverage probabilities from the state's own
    (C, SᵀC) via the Nyström lift and redraw the not-yet-accumulated slabs
    from them.

    SHARED by the single-device and sharded drivers (both construct it from
    the same key), so the refreshed draws cannot drift between them.

    Args:
        key: base PRNG key; phase ``i`` folds in ``0x11E7 + i``.
        lam: ridge level λ for the leverage scores.
        mix: uniform mixing weight for the probabilities.
        signed: draw Rademacher signs for redrawn slabs.

    Returns:
        ``refine(state, phase) -> state`` suitable for ``doubling_ladder``.
    """
    from repro.core import schemes as SCH

    def refine(state: AccumState, phase: int) -> AccumState:
        p_new = SCH.state_leverage_probs(state, lam, mix=mix)
        return SCH.refresh_tail(state,
                                jax.random.fold_in(key, _REFINE_STREAM + phase),
                                p_new, signed=signed)

    return refine


def accum_grow_doubling(K: jax.Array, state: AccumState, *, tol: float,
                        estimator, use_kernel: bool | None = None,
                        mesh=None, refine=None) -> tuple[AccumState, jax.Array]:
    """Adaptive growth on the DOUBLING schedule: draw B slabs, fold them in
    with ONE data pass (``accum_grow_batched``), check the estimator, B ← 2B
    — O(log m_final) passes over K (or X) instead of O(m_final).

    The batch sizes are static (1, 2, 4, …, clamped to m_max — the shared
    ``doubling_ladder``), so the whole driver stays jittable: each phase is
    a ``lax.cond`` that either applies the batch or passes the state through
    untouched once the tolerance is met — only the taken branch executes, so
    a converged state pays nothing for the remaining phases.  The estimator
    runs once per BATCH (its probe/holdout contractions read the C the same
    pass just produced), not once per slab.  Returns ``(state, passes)``
    with ``passes`` the number of batches actually applied.  ``refine`` is
    the optional per-phase probability refresh (``make_leverage_refine``),
    forwarded to the shared ladder."""
    if mesh is not None:
        from repro.core import distributed as D

        return D.sharded_accum_grow_doubling(
            K, state, mesh, tol=tol, estimator=estimator,
            use_kernel=use_kernel, refine=refine)
    if use_kernel is None:
        use_kernel = default_use_kernel()

    def apply_batch(s, B):
        return accum_grow_batched(K, s, B, use_kernel=use_kernel,
                                  donate=False)

    return doubling_ladder(state, state.m_max, tol, apply_batch, estimator,
                           refine=refine)


def accum_grow_adaptive(K: jax.Array, state: AccumState, *, tol: float,
                        estimator, check_every: int = 1,
                        use_kernel: bool | None = None,
                        mesh=None, schedule: str = "unit") -> AccumState:
    """Grow until ``estimator(state) ≤ tol`` or the pre-drawn ``m_max`` slabs
    are exhausted.  ``estimator`` maps AccumState → scalar error.

    ``schedule="unit"`` (default here; the ``grow_sketch_both`` driver
    defaults to doubling) folds one slab per pass in a ``lax.while_loop``;
    ``check_every > 1`` amortizes the estimator over several growth steps.
    ``schedule="doubling"`` delegates to ``accum_grow_doubling`` — batched
    rank-B passes, O(log m) sweeps over the data, estimator once per batch
    (``check_every`` does not apply there).  With ``mesh`` pass a shard-aware
    estimator (``make_*_estimator(mesh=…)``) — the loop states carry C padded
    up to the mesh."""
    if schedule not in ("unit", "doubling"):
        raise ValueError(f"unknown schedule {schedule!r}")
    if schedule == "doubling":
        state, _ = accum_grow_doubling(K, state, tol=tol, estimator=estimator,
                                       use_kernel=use_kernel, mesh=mesh)
        return state
    if mesh is not None:
        from repro.core import distributed as D

        return D.sharded_accum_grow_adaptive(
            K, state, mesh, tol=tol, estimator=estimator,
            check_every=check_every, use_kernel=use_kernel)
    if use_kernel is None:
        use_kernel = default_use_kernel()
    m_max = state.m_max

    def cond(s):
        return jnp.logical_and(s.m < m_max, s.err > tol)

    def body(s):
        s = accum_step(K, s, use_kernel=use_kernel)
        do_check = jnp.logical_or(s.m % check_every == 0, s.m >= m_max)
        err = jax.lax.cond(do_check, estimator, lambda st: st.err, s)
        return dataclasses.replace(s, err=err)

    return jax.lax.while_loop(cond, body, state)


def grow_sketch_both(
    key: jax.Array, K: jax.Array, d: int, *, m_max: int = 32,
    tol: float | None = None, probs: jax.Array | None = None,
    signed: bool = True, estimator=None, check_every: int = 1,
    use_kernel: bool | None = None, mesh=None, schedule: str = "doubling",
    scheme: str = "uniform", scheme_lam: float | None = None,
    scheme_mix: float = 0.1,
) -> tuple[AccumSketch, jax.Array, jax.Array, dict]:
    """One-call driver: grow a sketch on K — a precomputed matrix OR a
    matrix-free ``KernelOperator`` — until the error target is met (or to
    m_max when ``tol`` is None) and return ``(sketch, C, W, info)`` with
    C = K S, W = SᵀKS at the final m.

    Callers specify an error target instead of m — the paper's rescue of
    suboptimal (uniform / approximate-leverage) sampling schemes: grow m,
    keep the effective d×d size fixed.  ``estimator`` defaults to the holdout
    rule; pass ``make_hutchinson_estimator(...)`` (or any AccumState → scalar
    callable) to swap the plug-in rule.

    The whole driver is jittable: ``info``'s ``m``/``err`` are jax scalars
    (NOT host ints — converting here would force a device sync on every call
    and break tracing; examples/benchmarks convert at the printing edge), and
    under a trace the returned sketch is the state's ``masked_sketch()`` —
    static (m_max, d) shapes, zero-coefficient slabs beyond m, applies
    identically to the truncation eager callers get.

    Adaptive growth defaults to ``schedule="doubling"``: batched rank-B
    passes (draw B, one sweep, check the estimator, B ← 2B), O(log m) data
    passes instead of O(m) — ``info["passes"]`` reports the count.  Pass
    ``schedule="unit"`` for the one-slab-per-pass while_loop (there
    ``check_every`` amortizes the estimator).

    ``scheme`` selects the sampling scheme: ``"uniform"`` (default),
    ``"poisson"`` (fixed Horvitz–Thompson draws, π from ``probs`` or
    uniform), ``"leverage"`` — start from ``probs`` (or uniform), and after
    every executed batch re-estimate ridge-leverage probabilities FROM THE
    SKETCH ITSELF (``schemes.state_leverage_probs`` at ridge level
    ``scheme_lam``, uniform-mixed by ``scheme_mix``) and redraw the
    not-yet-accumulated slabs from them.  Leverage requires the doubling
    schedule (refinement happens between batches; a unit-step refresh would
    re-randomize every slab).  ``scheme_lam`` defaults to 1e-3; the KRR
    adaptive drivers forward their own λ.

    ``mesh`` (operator only) runs the whole growth data-parallel: identical
    index/holdout/probe draws (the RNG happens replicated, before anything is
    sharded), per-shard slab kernel evals, psum reductions."""
    from repro.core.schemes import validate_scheme

    validate_scheme(scheme)
    if scheme == "leverage" and schedule != "doubling":
        raise ValueError("scheme='leverage' refines between batches and "
                         "needs schedule='doubling'")
    if mesh is not None:
        from repro.core import distributed as D

        return D.sharded_grow_sketch_both(
            key, K, d, mesh, m_max=m_max, tol=tol, probs=probs, signed=signed,
            estimator=estimator, check_every=check_every,
            use_kernel=use_kernel, schedule=schedule, scheme=scheme,
            scheme_lam=scheme_lam, scheme_mix=scheme_mix)
    n = K.shape[0]
    state = accum_init(key, n, d, m_max, probs, signed=signed, scheme=scheme)
    refine = None
    if scheme == "leverage":
        refine = make_leverage_refine(
            key, lam=1e-3 if scheme_lam is None else scheme_lam,
            mix=scheme_mix, signed=signed)
    passes = None
    if tol is None:
        if refine is None:
            # fixed-size growth is ONE batch: t=0 makes the survivor rescale 0
            # and the m_max-slab block IS the one-shot sketch — a single data
            # pass where the unit loop paid m_max
            state = accum_grow_batched(K, state, m_max, use_kernel=use_kernel)
            passes = jnp.ones((), jnp.int32)
        else:
            # leverage at fixed size still walks the doubling ladder so the
            # probabilities refine between batches — O(log m) passes
            sched = doubling_schedule(0, m_max)
            for i, B in enumerate(sched):
                state = accum_grow_batched(K, state, B, use_kernel=use_kernel,
                                           donate=False)
                if i < len(sched) - 1:
                    state = refine(state, i)
            passes = jnp.full((), len(sched), jnp.int32)
    else:
        if estimator is None:
            estimator = make_holdout_estimator(
                jax.random.fold_in(key, _HOLDOUT_STREAM), K)
        if schedule == "doubling":
            state, passes = accum_grow_doubling(
                K, state, tol=tol, estimator=estimator, use_kernel=use_kernel,
                refine=refine)
        else:
            state = accum_grow_adaptive(K, state, tol=tol, estimator=estimator,
                                        check_every=check_every,
                                        use_kernel=use_kernel,
                                        schedule=schedule)
    return finish_grow(state, m_max, passes=passes)


def sketch_kernel_cols(
    X: jax.Array, sk: AccumSketch, kernel_fn, *, chunk: int | None = None
) -> jax.Array:
    """C = K S without ever forming K:  O(n·m·d) kernel evaluations.

    kernel_fn(A, B) -> (|A|, |B|) kernel matrix. Gathers the m·d landmark
    points, evaluates the (chunk, m·d) slab per row chunk, and contracts with
    the combination coefficients (``kernel_op.stream_cols`` — a ``lax.scan``
    streaming sweep).  Thin ad-hoc-callable wrapper; prefer a
    ``KernelOperator`` for named kernels (Pallas routing, engine support)."""
    from repro.core.kernel_op import stream_cols

    landmarks = jnp.take(X, sk.indices.reshape(-1), axis=0)      # (m*d, d_X)
    return stream_cols(X, landmarks, sk.coef, kernel_fn, chunk=chunk)
