"""Approximate matrix multiplication with accumulation sketches — the extension
the paper proposes in its conclusion ("applying the proposed sketching method to
approximate matrix multiplication").

For A (n, p), B (n, q):   Aᵀ B ≈ (Sᵀ A)ᵀ (Sᵀ B) = Aᵀ S Sᵀ B,
unbiased because E[S Sᵀ] = I_n for Algorithm-1 sketches (any P, any m).
Cost O(m·d·(p+q) + d·p·q) instead of O(n·p·q).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.apply import sketch_left
from repro.core.sketch import AccumSketch


def amm(A: jax.Array, B: jax.Array, sk: AccumSketch) -> jax.Array:
    """Sketched estimate of Aᵀ B."""
    SA = sketch_left(sk, A)       # (d, p)
    SB = sketch_left(sk, B)       # (d, q)
    return SA.T @ SB


def amm_error(A: jax.Array, B: jax.Array, sk: AccumSketch) -> jax.Array:
    """Relative Frobenius error vs the exact product (diagnostic)."""
    exact = A.T @ B
    err = amm(A, B, sk) - exact
    return jnp.linalg.norm(err) / (jnp.linalg.norm(exact) + 1e-30)
