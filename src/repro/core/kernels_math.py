"""Positive semi-definite kernel functions used by the KRR experiments.

All functions map (n, p), (m, p) -> (n, m) and are jit/vmap friendly.
"""
from __future__ import annotations

import math
from functools import lru_cache, partial

import jax
import jax.numpy as jnp


def _sqdist(A: jax.Array, B: jax.Array) -> jax.Array:
    # numerically-guarded pairwise squared distances
    a2 = jnp.sum(A * A, axis=-1)[:, None]
    b2 = jnp.sum(B * B, axis=-1)[None, :]
    d2 = a2 + b2 - 2.0 * (A @ B.T)
    return jnp.maximum(d2, 0.0)


def gaussian_kernel(A, B, bandwidth: float = 1.0):
    """exp(-||a-b||² / (2σ²))."""
    return jnp.exp(-_sqdist(A, B) / (2.0 * bandwidth**2))


def laplacian_kernel(A, B, bandwidth: float = 1.0):
    """k(a, b) = exp(−‖a − b‖ / bandwidth) — the L2 Laplacian (exponential)
    kernel, (a, p) × (b, p) → (a, b)."""
    d = jnp.sqrt(_sqdist(A, B) + 1e-30)
    return jnp.exp(-d / bandwidth)


def matern_kernel(A, B, bandwidth: float = 1.0, nu: float = 1.5):
    """Matérn with ν ∈ {0.5, 1.5, 2.5} (closed forms)."""
    r = jnp.sqrt(_sqdist(A, B) + 1e-30) / bandwidth
    if nu == 0.5:
        return jnp.exp(-r)
    if nu == 1.5:
        c = math.sqrt(3.0)
        return (1.0 + c * r) * jnp.exp(-c * r)
    if nu == 2.5:
        c = math.sqrt(5.0)
        return (1.0 + c * r + 5.0 * r * r / 3.0) * jnp.exp(-c * r)
    raise ValueError(f"unsupported nu={nu}")


@lru_cache(maxsize=None)
def _get_kernel_cached(name: str, bandwidth: float, nu: float):
    if name == "gaussian":
        return partial(gaussian_kernel, bandwidth=bandwidth)
    if name == "laplacian":
        return partial(laplacian_kernel, bandwidth=bandwidth)
    if name == "matern":
        return partial(matern_kernel, bandwidth=bandwidth, nu=nu)
    raise ValueError(f"unknown kernel {name}")


def get_kernel(name: str, bandwidth: float = 1.0, nu: float = 1.5):
    """Kernel callable for a (name, bandwidth, nu) config — CACHED, so equal
    configs return the IDENTICAL object.  ``functools.partial`` compares by
    identity, and the callable rides in pytree aux data (``SketchedKRR``), so
    a fresh partial per call would make two models fitted through equal
    operators carry unequal treedefs — un-stackable, un-vmappable, and a jit
    retrace per model."""
    return _get_kernel_cached(name, float(bandwidth), float(nu))
