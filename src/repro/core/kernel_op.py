"""Matrix-free kernel operator — the accumulation sketch applied to a DATASET.

Every earlier path in the repo took a materialized n×n kernel matrix K,
capping n at ~10⁴ on a single host and contradicting the paper's point:
accumulation controls the *effective* matrix size, so the n×n object should
never exist.  ``KernelOperator`` represents K = k(X, X) by the data ``X`` and
the kernel's name/bandwidth (``core/kernels_math.py``) and computes

    C = K S           (n, d)   — row-streamed kernel-eval → contraction
    W = Sᵀ K S = SᵀC  (d, d)   — row gathers of C, no extra kernel evals

directly from X in row tiles: per tile, the (tile, m·d) kernel block against
the sketch's landmark rows is evaluated and immediately contracted with the
combination coefficients, so peak memory is O(tile · m·d) — never O(n²).
Two backends share the arithmetic:

  * a fused Pallas kernel (``kernels/accum_apply/matfree_apply``) doing the
    sqdist → kernel → GEMM pipeline per grid tile (MXU path on TPU), and
  * a ``lax.scan`` streaming jnp path for CPU/AD, chunked so the jaxpr stays
    O(1) in n.

The progressive accumulation engine, KRR solvers, and spectral clustering all
accept a ``KernelOperator`` wherever they accept a dense K (``repro.core
.apply`` dispatches), including the engine's column-slab increments, the
plug-in stopping estimators, and the matrix-free predict path
K(X_test, landmarks)·θ.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import apply as A
from repro.core.kernels_math import get_kernel
from repro.core.sketch import AccumSketch

# dense() materializes the n×n kernel — refuse above this n unless forced
# (at n = 32768 the f32 matrix is already 4 GiB; the sqdist intermediates
# triple that)
DENSE_GUARD_N = 32768


def _scan_row_chunks(X: jax.Array, chunk: int | None, block_fn) -> jax.Array:
    """Row-streaming scaffold: ``block_fn`` maps a (b, p) row block to a
    (b, c) result; full chunks ride a ``lax.scan`` (jaxpr O(1) in the number
    of chunks) and the ragged tail gets one extra call.  ``chunk=None`` or
    small inputs take a single unstreamed block."""
    n, p = X.shape
    if chunk is None or n <= chunk:
        return block_fn(X)
    nfull = (n // chunk) * chunk

    def body(carry, xb):
        return carry, block_fn(xb)

    _, out = jax.lax.scan(body, None, X[:nfull].reshape(-1, chunk, p))
    out = out.reshape(nfull, -1)
    if nfull < n:
        out = jnp.concatenate([out, block_fn(X[nfull:])], axis=0)
    return out


def stream_cols(
    Xq: jax.Array, landmarks: jax.Array, coef: jax.Array, kernel_fn,
    *, chunk: int | None = None,
) -> jax.Array:
    """C = K(Xq, ·)·S from raw rows: the (b, m·d) kernel slab of each row
    chunk against the landmark rows, contracted with the combination
    coefficients.  ``chunk`` streams the rows through a ``lax.scan`` (jaxpr
    stays O(1) in the number of chunks) so peak memory is O(chunk · m·d)
    regardless of how large Xq is.  Returns (nq, d), f32-accumulated (f64
    inputs stay f64)."""
    m, d = coef.shape
    # accumulate in f32 at least; keep f64 when the caller runs in x64 mode
    acc_t = jnp.promote_types(jnp.float32, jnp.result_type(Xq.dtype, coef.dtype))
    coef_a = coef.astype(acc_t)

    def _block(xb):
        slab = kernel_fn(xb, landmarks).astype(acc_t)           # (b, m·d)
        return jnp.einsum("bmd,md->bd", slab.reshape(xb.shape[0], m, d), coef_a)

    return _scan_row_chunks(Xq, chunk, _block)


def stream_cols_slabs(
    Xq: jax.Array, landmarks: jax.Array, coef: jax.Array, kernel_fn,
    *, chunk: int | None = None,
) -> jax.Array:
    """Multi-slab C = K(Xq, ·)·S accumulated SLAB-BY-SLAB — the batched
    engine's streaming twin.

    A ``lax.scan`` over the m slabs evaluates each slab's (chunk, d) kernel
    blocks at the NARROW GEMM shape the row-streamed backends are fastest at
    and folds them into the (nq, d) accumulator: the (nq, m·d) wide slab of
    ``stream_cols`` never exists, and peak memory is O(nq·d + chunk·d).
    Measured on the CPU bench host, XLA's wide-output GEMM tiling degrades
    ~2× by m·d = 1024, so at batch sizes B ≥ 2 this formulation is the fast
    one (the Pallas matfree kernel keeps the wide block — the MXU wants it).
    Returns (nq, d), f32-accumulated (f64 inputs stay f64)."""
    m, d = coef.shape
    p = Xq.shape[-1]
    acc_t = jnp.promote_types(jnp.float32, jnp.result_type(Xq.dtype, coef.dtype))
    if chunk is None:
        # the (chunk, d) kernel block is the transient peak — same ~16 MiB
        # budget as everywhere else
        chunk = max(8, (4 * 1024 * 1024) // max(d, 1))
    lmr = landmarks.reshape(m, d, p)
    cf = coef.astype(acc_t)

    def body(acc, slab):
        lm_b, cf_b = slab

        def blk(xb):
            return kernel_fn(xb, lm_b).astype(acc_t)

        return acc + _scan_row_chunks(Xq, chunk, blk) * cf_b[None, :], None

    acc0 = jnp.zeros((Xq.shape[0], d), acc_t)
    acc, _ = jax.lax.scan(body, acc0, (lmr, cf))
    return acc


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class KernelOperator:
    """K = k(X, X) as an operator: data + kernel name, never the matrix.

    ``kernel``/``bandwidth``/``nu`` are static (pytree aux) so the operator
    jits like an array; ``X`` is the only leaf.  ``chunk=None`` lets each
    method pick a row-chunk bounding the kernel slab at ~16 MiB."""

    X: jax.Array                 # (n, p) dataset rows
    kernel: str = "gaussian"
    bandwidth: float = 1.0
    nu: float = 1.5              # matern only

    def tree_flatten(self):
        """Pytree leaf = X; kernel name/bandwidth/nu are static aux."""
        return (self.X,), (self.kernel, self.bandwidth, self.nu)

    @classmethod
    def tree_unflatten(cls, aux, children):
        """Inverse of ``tree_flatten`` (jax pytree protocol)."""
        return cls(X=children[0], kernel=aux[0], bandwidth=aux[1], nu=aux[2])

    # -- array-like surface (what apply/krr/spectral touch on a dense K) ------
    @property
    def n(self) -> int:
        """Number of dataset rows (= both dims of the represented K)."""
        return self.X.shape[0]

    @property
    def shape(self) -> tuple[int, int]:
        """(n, n) — the shape of the NEVER-materialized Gram matrix."""
        return (self.n, self.n)

    @property
    def dtype(self):
        """dtype of the represented K (= the dataset's dtype)."""
        return self.X.dtype

    @property
    def kernel_fn(self):
        """(a, p), (b, p) → (a, b) kernel matrix — ``core.kernels_math``."""
        return get_kernel(self.kernel, self.bandwidth, self.nu)

    def _auto_chunk(self, md: int) -> int:
        # f32 slab (chunk, md) ≤ ~16 MiB.  The floor is the same small
        # constant ``matvec`` uses — a 256-row floor would let the slab grow
        # past the budget whenever m·d is large (a (256, 65536) f32 slab is
        # 64 MiB), exactly the failure matvec's chunk comment warns about.
        return max(8, (4 * 1024 * 1024) // max(md, 1))

    # -- kernel-block primitives ----------------------------------------------
    def submatrix(self, rows: jax.Array, cols: jax.Array) -> jax.Array:
        """K[rows][:, cols] from |rows|·|cols| kernel evaluations."""
        return self.kernel_fn(jnp.take(self.X, rows, axis=0),
                              jnp.take(self.X, cols, axis=0))

    def weighted_cols(
        self, Xq: jax.Array, idx: jax.Array, coef: jax.Array, *,
        chunk: int | None = None, use_kernel: bool | None = None,
        mesh=None,
    ) -> jax.Array:
        """K(Xq, ·)·S for the sketch described by idx/coef (m, d) — the core
        primitive behind C, the engine's slab increments, and prediction.

        ``use_kernel`` (auto: True on TPU) routes through the fused Pallas
        kernel-eval→GEMM kernel; otherwise the ``lax.scan`` streaming path.
        ``mesh`` row-shards Xq over a ``("data",)`` device mesh: each device
        computes its tile through the same backend with the landmarks
        replicated (``repro.core.distributed``)."""
        if mesh is not None:
            from repro.core import distributed as D

            return D.sharded_weighted_cols(
                self, Xq, idx, coef, D.resolve_mesh(mesh), chunk=chunk,
                use_kernel=use_kernel)
        if use_kernel is None:
            use_kernel = A.default_use_kernel()
        lm = jnp.take(self.X, idx.reshape(-1), axis=0)
        if chunk is None:
            # always budget by SLAB size, not row count: an (nq, m·d) slab
            # blows the ~16 MiB budget at large m·d even when nq is small
            # (nq ≤ _auto_chunk(m·d) degrades to a single unstreamed block,
            # so small problems pay no scan overhead)
            chunk = self._auto_chunk(idx.size)

        def _stream():
            from repro.resilience import faults

            faults.fault_point("kernel.stream")
            return stream_cols(Xq, lm, coef, self.kernel_fn, chunk=chunk)

        if use_kernel:
            from repro.kernels.accum_apply.ops import matfree_cols_kernel
            from repro.resilience.degrade import ladder_call

            # three-rung ladder: fused Pallas kernel → XLA lax.scan streaming
            # → one dense unstreamed slab (only when it fits the dense guard).
            # Each rung drop is recorded in the global HealthReport.
            rungs = [
                ("pallas:matfree_cols",
                 lambda: matfree_cols_kernel(Xq, lm, coef, kernel=self.kernel,
                                             bandwidth=self.bandwidth,
                                             nu=self.nu)),
                ("xla:stream_cols", _stream),
            ]
            if Xq.shape[0] * idx.size <= DENSE_GUARD_N * 1024:
                rungs.append(
                    ("dense:one-slab",
                     lambda: stream_cols(Xq, lm, coef, self.kernel_fn,
                                         chunk=Xq.shape[0]))
                )
            return ladder_call("kernel.dispatch", rungs)
        return _stream()

    # -- sketched applications ------------------------------------------------
    def sketch_cols(self, sk: AccumSketch, *, chunk: int | None = None,
                    use_kernel: bool | None = None, mesh=None) -> jax.Array:
        """C = K S (n, d) — O(n·m·d) kernel evaluations, O(n·d) memory
        (O(n/D · d) per device under ``mesh``)."""
        return self.weighted_cols(self.X, sk.indices, sk.coef, chunk=chunk,
                                  use_kernel=use_kernel, mesh=mesh)

    def cross_cols(self, Xq: jax.Array, sk: AccumSketch, *,
                   chunk: int | None = None,
                   use_kernel: bool | None = None, mesh=None) -> jax.Array:
        """K(Xq, X)·S (nq, d) — the matrix-free predict path: test rows only
        ever meet the m·d landmark rows, never the training Gram matrix."""
        return self.weighted_cols(Xq, sk.indices, sk.coef, chunk=chunk,
                                  use_kernel=use_kernel, mesh=mesh)

    def sketch_both(
        self, sk: AccumSketch, *, chunk: int | None = None,
        use_kernel: bool | None = None, mesh=None,
    ) -> tuple[jax.Array, jax.Array]:
        """(C, W) = (K S, SᵀK S) without forming K.

        W = SᵀC is a row gather of the already-computed C (the sketch's
        non-zero rows are exactly the landmark rows), so it costs O(m·d²) on
        top of C — the same arithmetic as the dense path, which is what the
        golden dense ≡ matrix-free equivalence tests pin.  ``mesh`` computes
        both per data shard in one mapped launch (W psum-reduced)."""
        if mesh is not None:
            from repro.core import distributed as D

            return D.sharded_sketch_both(self, sk, D.resolve_mesh(mesh),
                                         chunk=chunk, use_kernel=use_kernel)
        C = self.sketch_cols(sk, chunk=chunk, use_kernel=use_kernel)
        return C, A.sketch_left(sk, C)

    def matvec(self, Z: jax.Array, *, chunk: int | None = None,
               mesh=None) -> jax.Array:
        """K @ Z streamed over row chunks — O(chunk·n) peak memory, O(n²·p)
        compute.  Only for estimators that genuinely need full matvecs
        (Hutchinson probes); sketched paths never call this.  ``mesh``
        splits the row streaming over the data shards."""
        if mesh is not None:
            from repro.core import distributed as D

            return D.sharded_matvec(self, Z, D.resolve_mesh(mesh),
                                    chunk=chunk)
        Zm = Z[:, None] if Z.ndim == 1 else Z
        n = self.n
        if chunk is None:
            # the (chunk, n) slab is the peak allocation — keep it ~16 MiB
            # even at n where a 256-row floor would let it grow to O(n)·256
            chunk = max(8, (4 * 1024 * 1024) // max(n, 1))
        kf = self.kernel_fn
        Z32 = Zm.astype(jnp.float32)

        def _block(xb):
            return kf(xb, self.X).astype(jnp.float32) @ Z32

        out = _scan_row_chunks(self.X, chunk, _block)
        return out[:, 0] if Z.ndim == 1 else out

    def dense(self, *, force: bool = False) -> jax.Array:
        """Materialize K (n, n) — tests and small problems ONLY.

        Refused above ``DENSE_GUARD_N`` rows unless ``force=True``: the whole
        point of this layer is that the n×n object never exists."""
        if self.n > DENSE_GUARD_N and not force:
            raise ValueError(
                f"refusing to materialize the {self.n}×{self.n} kernel matrix "
                f"(~{self.n * self.n * 4 / 2**30:.0f} GiB as f32); use the "
                "matrix-free sketched paths, or pass force=True if you really "
                "have the memory")
        return self.kernel_fn(self.X, self.X)
