"""Core library: the paper's accumulation-of-sub-sampling sketching framework."""
from repro.core.sketch import (
    AccumSketch,
    AccumState,
    append_subsample,
    make_accum_sketch,
    make_accum_sketch_jit,
    make_gaussian_sketch,
    make_nystrom_sketch,
    make_sparse_rp,
)
from repro.core.apply import (
    accum_grow,
    accum_grow_adaptive,
    accum_grow_batched,
    accum_grow_doubling,
    accum_init,
    accum_step,
    doubling_schedule,
    gram_sketch,
    grow_sketch_both,
    make_holdout_estimator,
    make_hutchinson_estimator,
    sketch_both,
    sketch_kernel_cols,
    sketch_left,
    sketch_right,
    sketch_vec,
    unsketch_mat,
    unsketch_vec,
)
from repro.core.kernel_op import KernelOperator, stream_cols, stream_cols_slabs
from repro.core.distributed import (
    make_data_mesh,
    shard_rows,
    sharded_gram,
    sharded_matvec,
    sharded_sketch_both,
    sharded_sketch_left,
    sharded_take_rows,
    sharded_weighted_cols,
)
from repro.core.krr import (
    SketchedKRR,
    insample_error,
    krr_exact_fit,
    krr_exact_fitted,
    krr_sketched_fit,
    krr_sketched_fit_adaptive,
    krr_sketched_fit_dense,
    krr_sketched_fit_matfree,
    krr_sketched_fit_pcg,
    krr_sketched_fit_pcg_adaptive,
)
from repro.core.spectral import (
    SpectralResult,
    kmeans,
    nystrom_eigh,
    sketched_spectral_embedding,
    spectral_cluster,
)
from repro.core.kernels_math import gaussian_kernel, get_kernel, laplacian_kernel, matern_kernel
from repro.core.leverage import (
    approx_leverage_probs,
    d_delta,
    incoherence,
    leverage_probs,
    leverage_scores,
    spectrum,
    statistical_dimension,
)
from repro.core.ksat import KSatResult, ksat_check
from repro.core.amm import amm, amm_error
from repro.core.schemes import (
    SCHEMES,
    poisson_inclusion,
    poisson_pieces,
    refresh_tail,
    sketch_leverage_probs,
    sketch_leverage_scores,
    state_leverage_probs,
)

__all__ = [n for n in dir() if not n.startswith("_")]
