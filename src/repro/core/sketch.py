"""Algorithm 1 of the paper: sketching matrices as accumulations of m rescaled,
randomly-signed sub-sampling matrices.

The sketch is *structural*: we never materialize the n-by-d matrix S. It is fully
described by

  indices : (m, d) int32   — n_ij, the sampled row index of the single non-zero in
                             column j of the i-th sub-sampling matrix S_(i)
  signs   : (m, d) float   — r_ij, i.i.d. Rademacher
  probs   : (n,)   float   — the sampling distribution P (p_k)

so that  S = sum_i S_(i),  with  (S_(i))[:, j] = r_ij / sqrt(d * m * p_{n_ij}) e_{n_ij}.

Special cases:
  m = 1, uniform P, signs ignored  → classical Nyström sub-sampling sketch
  m → ∞                            → sub-Gaussian (Gaussian) sketch by the CLT
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class AccumSketch:
    """Structural representation of an accumulation-of-sub-sampling sketch."""

    indices: jax.Array  # (m, d) int32
    signs: jax.Array    # (m, d) — ±1
    probs: jax.Array    # (n,) sampling distribution
    n: int              # ambient dimension (rows of S)

    # -- pytree plumbing ------------------------------------------------------
    def tree_flatten(self):
        return (self.indices, self.signs, self.probs), (self.n,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, n=aux[0])

    # -- derived quantities ---------------------------------------------------
    @property
    def m(self) -> int:
        return self.indices.shape[0]

    @property
    def d(self) -> int:
        return self.indices.shape[1]

    @property
    def coef(self) -> jax.Array:
        """(m, d) combination coefficients r_ij / sqrt(d m p_{n_ij})."""
        p = jnp.take(self.probs, self.indices, axis=0)  # (m, d)
        return self.signs / jnp.sqrt(self.d * self.m * p)

    def dense(self) -> jax.Array:
        """Materialize S (n, d) — O(n d), for tests/small problems only."""
        onehot = jax.nn.one_hot(self.indices, self.n, dtype=self.signs.dtype)  # (m,d,n)
        return jnp.einsum("mdn,md->nd", onehot, self.coef)

    def nnz_per_column(self) -> jax.Array:
        """Number of distinct non-zeros per column (≤ m); density diagnostic."""
        s = self.dense()
        return jnp.sum(s != 0, axis=0)


def make_accum_sketch(
    key: jax.Array,
    n: int,
    d: int,
    m: int = 1,
    probs: jax.Array | None = None,
    *,
    signed: bool = True,
    dtype=jnp.float32,
) -> AccumSketch:
    """Algorithm 1. Draw m*d indices from P with replacement + Rademacher signs.

    probs=None means the uniform distribution (classical Nyström when m=1).
    `signed=False` drops the Rademacher signs (pure Nyström; the paper notes the
    signs cancel in K S for m=1 anyway).
    """
    if probs is None:
        probs = jnp.full((n,), 1.0 / n, dtype=dtype)
    else:
        probs = jnp.asarray(probs, dtype=dtype)
        probs = probs / jnp.sum(probs)
    kidx, ksgn = jax.random.split(key)
    indices = jax.random.choice(kidx, n, shape=(m, d), replace=True, p=probs)
    if signed:
        signs = jax.random.rademacher(ksgn, (m, d), dtype=dtype)
    else:
        signs = jnp.ones((m, d), dtype=dtype)
    return AccumSketch(indices=indices.astype(jnp.int32), signs=signs, probs=probs, n=n)


def make_nystrom_sketch(key, n, d, probs=None, dtype=jnp.float32) -> AccumSketch:
    """m=1 special case — the classical (or leverage-weighted) Nyström sketch."""
    return make_accum_sketch(key, n, d, m=1, probs=probs, signed=False, dtype=dtype)


def make_gaussian_sketch(key, n, d, dtype=jnp.float32) -> jax.Array:
    """Dense sub-Gaussian sketch (the m→∞ limit): i.i.d. N(0, 1/d)."""
    return jax.random.normal(key, (n, d), dtype=dtype) / jnp.sqrt(d)


def make_sparse_rp(key, n, d, s: float | None = None, dtype=jnp.float32) -> jax.Array:
    """Very sparse random projection (Li, Hastie, Church 2006).

    Entries are sqrt(s/d)·{+1 w.p. 1/(2s), -1 w.p. 1/(2s), 0 otherwise}.
    Default s = sqrt(n) (their recommended density). Returned dense — it is a
    *baseline*, the paper's method never materializes its sketch.
    """
    if s is None:
        s = float(jnp.sqrt(n))
    ku, ks = jax.random.split(key)
    u = jax.random.uniform(ku, (n, d))
    sgn = jax.random.rademacher(ks, (n, d), dtype=dtype)
    mask = (u < 1.0 / s).astype(dtype)
    return sgn * mask * jnp.sqrt(s / d).astype(dtype)


@partial(jax.jit, static_argnames=("n", "d", "m", "signed"))
def _jit_make(key, n, d, m, probs, signed):
    return make_accum_sketch(key, n, d, m, probs, signed=signed)


def make_accum_sketch_jit(key, n, d, m=1, probs=None, signed=True) -> AccumSketch:
    """jit'd constructor (probs must be a concrete array or None)."""
    if probs is None:
        probs = jnp.full((n,), 1.0 / n, dtype=jnp.float32)
    return _jit_make(key, n, d, m, probs, signed)
