"""Algorithm 1 of the paper: sketching matrices as accumulations of m rescaled,
randomly-signed sub-sampling matrices.

The sketch is *structural*: we never materialize the n-by-d matrix S. It is fully
described by

  indices : (m, d) int32   — n_ij, the sampled row index of the single non-zero in
                             column j of the i-th sub-sampling matrix S_(i)
  signs   : (m, d) float   — r_ij, i.i.d. Rademacher
  probs   : (n,)   float   — the sampling distribution P (p_k)

so that  S = sum_i S_(i),  with  (S_(i))[:, j] = r_ij / sqrt(d * m * p_{n_ij}) e_{n_ij}.

Special cases:
  m = 1, uniform P, signs ignored  → classical Nyström sub-sampling sketch
  m → ∞                            → sub-Gaussian (Gaussian) sketch by the CLT

Grow API: ``append_subsample`` draws one more sub-sampling matrix (m → m+1,
survivors rescaled by sqrt(m/(m+1))), ``AccumSketch.truncated`` drops slabs
with the inverse renormalization, and ``AccumState`` is the pytree the
progressive accumulation engine (``repro.core.apply``) carries through
``lax.fori_loop``/``while_loop`` while growing (C, W) incrementally.

Sampling schemes: every constructor takes ``scheme=`` — ``"uniform"``
(default), ``"leverage"`` (caller-supplied or engine-refined ridge-leverage
probabilities), ``"poisson"`` (independent per-row inclusion, Horvitz–
Thompson normalized).  The draw mechanics live in ``repro.core.schemes``;
for Poisson sketches ``probs`` stores the EFFECTIVE per-row probability
π_i/d, which makes the universal coefficient r/√(d·m·p) equal the
Horvitz–Thompson r/√(m·π) with no special-casing anywhere downstream.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class AccumSketch:
    """Structural representation of an accumulation-of-sub-sampling sketch.

    ``coef_`` optionally carries the precomputed (m, d) combination
    coefficients.  The constructors populate it so hot loops (kernel entry
    points, PCG iterations, the progressive engine) never re-run the
    ``jnp.take(probs, indices)`` gather; ``coef`` falls back to computing it
    for hand-built sketches that leave it ``None``.
    """

    indices: jax.Array  # (m, d) int32
    signs: jax.Array    # (m, d) — ±1 (Poisson: {0, ±√(N/kept)})
    probs: jax.Array    # (n,) sampling distribution (Poisson: π/d)
    n: int              # ambient dimension (rows of S)
    coef_: jax.Array | None = None  # (m, d) cached r_ij / sqrt(d m p)
    scheme: str = "uniform"         # sampling scheme that drew this sketch

    # -- pytree plumbing ------------------------------------------------------
    def tree_flatten(self):
        """Flatten into (array leaves, static aux) for jax transformations."""
        return (self.indices, self.signs, self.probs, self.coef_), (
            self.n, self.scheme)

    @classmethod
    def tree_unflatten(cls, aux, children):
        """Rebuild from ``tree_flatten`` output (jax pytree protocol)."""
        indices, signs, probs, coef_ = children
        return cls(indices=indices, signs=signs, probs=probs, n=aux[0],
                   coef_=coef_, scheme=aux[1])

    # -- derived quantities ---------------------------------------------------
    @property
    def m(self) -> int:
        """Number of accumulated sub-sampling matrices (slabs)."""
        return self.indices.shape[0]

    @property
    def d(self) -> int:
        """Sketch dimension (columns of S)."""
        return self.indices.shape[1]

    @property
    def coef(self) -> jax.Array:
        """(m, d) combination coefficients r_ij / sqrt(d m p_{n_ij})."""
        if self.coef_ is not None:
            return self.coef_
        return _compute_coef(self.indices, self.signs, self.probs)

    def with_coef(self) -> "AccumSketch":
        """Copy with ``coef_`` populated (no-op if already cached)."""
        if self.coef_ is not None:
            return self
        return dataclasses.replace(self, coef_=self.coef)

    def truncated(self, m: int) -> "AccumSketch":
        """The sketch restricted to its first ``m`` sub-sampling matrices.

        The cached coefficients renormalize by sqrt(M/m) — each column's
        combination coefficient is r / sqrt(d·m·p), so dropping slabs *raises*
        the weight of the survivors (paper eq. after Alg. 1)."""
        if not 0 < m <= self.m:
            raise ValueError(f"cannot truncate m={self.m} sketch to m={m}")
        if m == self.m:
            return self
        coef_ = None
        if self.coef_ is not None:
            coef_ = self.coef_[:m] * jnp.sqrt(self.m / m).astype(self.coef_.dtype)
        return AccumSketch(indices=self.indices[:m], signs=self.signs[:m],
                           probs=self.probs, n=self.n, coef_=coef_,
                           scheme=self.scheme)

    def dense(self) -> jax.Array:
        """Materialize S (n, d) — O(n d), for tests/small problems only."""
        onehot = jax.nn.one_hot(self.indices, self.n, dtype=self.signs.dtype)  # (m,d,n)
        return jnp.einsum("mdn,md->nd", onehot, self.coef)

    def nnz_per_column(self) -> jax.Array:
        """Number of distinct non-zeros per column (≤ m); density diagnostic.

        Computed O(m²·d) from ``indices``/``coef`` directly — never the dense
        (n, d) S: for each column, group the m draws by sampled row (the m×m
        index-coincidence mask) and count the distinct rows whose summed
        coefficient is non-zero (colliding draws with cancelling signs are
        zeros in S, exactly as in the dense count)."""
        coef = self.coef
        eq = self.indices[:, None, :] == self.indices[None, :, :]   # (m, m, d)
        summed = jnp.sum(jnp.where(eq, coef[None, :, :], 0.0), axis=1)
        # entry i represents its row iff no earlier draw i' < i hit the same row
        earlier = jnp.tril(jnp.ones((self.m, self.m), bool), k=-1)
        seen = jnp.any(eq & earlier[:, :, None], axis=1)            # (m, d)
        return jnp.sum(~seen & (summed != 0), axis=0)


def _compute_coef(indices: jax.Array, signs: jax.Array, probs: jax.Array) -> jax.Array:
    m, d = indices.shape
    p = jnp.take(probs, indices, axis=0)  # (m, d)
    return signs / jnp.sqrt(d * m * p)


def _normalize_probs(probs: jax.Array | None, n: int,
                     dtype=jnp.float32) -> jax.Array:
    """The one shared probs-normalization path for EVERY sketch constructor.

    ``None`` → the uniform distribution; anything else is coerced to
    ``dtype`` and renormalized to sum 1, so unnormalized weight vectors are
    accepted identically everywhere (``make_accum_sketch``,
    ``make_accum_sketch_jit``, ``make_nystrom_sketch``, ``accum_init``, the
    Poisson inclusion map).

    Args:
        probs: (n,) nonnegative weights, or ``None`` for uniform.
        n: ambient dimension.
        dtype: dtype of the returned distribution.

    Returns:
        (n,) normalized sampling distribution.
    """
    if probs is None:
        return jnp.full((n,), 1.0 / n, dtype=dtype)
    probs = jnp.asarray(probs, dtype=dtype)
    return probs / jnp.sum(probs)


def make_accum_sketch(
    key: jax.Array,
    n: int,
    d: int,
    m: int = 1,
    probs: jax.Array | None = None,
    *,
    scheme: str = "uniform",
    signed: bool = True,
    dtype=jnp.float32,
) -> AccumSketch:
    """Algorithm 1. Draw m*d indices from P with replacement + Rademacher signs.

    probs=None means the uniform distribution (classical Nyström when m=1).
    `signed=False` drops the Rademacher signs (pure Nyström; the paper notes the
    signs cancel in K S for m=1 anyway).

    ``scheme`` selects the sampling scheme (``repro.core.schemes``):
    ``"uniform"`` ignores ``probs``-as-scheme semantics (a provided ``probs``
    is still honored, as before), ``"leverage"`` requires an explicit
    ``probs`` vector here (the adaptive drivers estimate one from the sketch
    itself; this one-shot constructor cannot), and ``"poisson"`` draws each
    row independently with probability π_i = min(1, d·p_i), storing π/d as
    the per-row probability so the cached coef is the Horvitz–Thompson
    r/√(m·π).
    """
    from repro.core.schemes import poisson_inclusion, poisson_pieces, validate_scheme

    validate_scheme(scheme)
    if scheme == "poisson":
        pi = poisson_inclusion(probs, n, d, dtype=dtype)
        indices, signs = poisson_pieces(key, pi, m, d, dtype=dtype,
                                        signed=signed)
        probs_eff = (pi / d).astype(dtype)
        return AccumSketch(indices=indices, signs=signs, probs=probs_eff, n=n,
                           coef_=_compute_coef(indices, signs, probs_eff),
                           scheme=scheme)
    if scheme == "leverage" and probs is None:
        raise ValueError(
            "scheme='leverage' needs an explicit probs vector in the one-shot "
            "constructor — compute one with schemes.sketch_leverage_probs / "
            "leverage.leverage_probs, or use the adaptive drivers "
            "(grow_sketch_both / krr_sketched_fit_adaptive), which estimate "
            "and refine it from the sketch itself")
    probs = _normalize_probs(probs, n, dtype)
    kidx, ksgn = jax.random.split(key)
    indices = jax.random.choice(kidx, n, shape=(m, d), replace=True, p=probs)
    if signed:
        signs = jax.random.rademacher(ksgn, (m, d), dtype=dtype)
    else:
        signs = jnp.ones((m, d), dtype=dtype)
    indices = indices.astype(jnp.int32)
    return AccumSketch(indices=indices, signs=signs, probs=probs, n=n,
                       coef_=_compute_coef(indices, signs, probs),
                       scheme=scheme)


def append_subsample(sk: AccumSketch, key: jax.Array, *, signed: bool = True) -> AccumSketch:
    """Grow a sketch m → m+1 by drawing ONE new sub-sampling matrix from the
    same distribution P — the paper's accumulation step.

    The survivors' cached coefficients rescale by sqrt(m/(m+1)) (each column's
    normalization is 1/sqrt(d·m·p)), so S_{m+1} = sqrt(m/(m+1))·S_m + T_{m+1}.
    The grown sketch is a fresh draw, not a prefix of any single-key
    ``make_accum_sketch`` — use ``AccumState``/``accum_grow`` when the
    step-by-step trajectory must replay a one-shot construction exactly.

    Scheme-aware: a ``"poisson"`` sketch appends one more Poisson slab drawn
    with the SAME inclusion probabilities π = d·probs (the stored effective
    probabilities reconstruct π exactly); other schemes redraw with
    replacement from ``sk.probs`` as before."""
    kidx, ksgn = jax.random.split(key)
    if sk.scheme == "poisson":
        from repro.core.schemes import poisson_pieces

        pi = jnp.clip(sk.d * sk.probs, 1e-9, 1.0)   # probs stores π/d
        idx_new, sgn_new = poisson_pieces(kidx, pi, 1, sk.d,
                                          dtype=sk.signs.dtype, signed=signed)
    else:
        idx_new = jax.random.choice(kidx, sk.n, shape=(1, sk.d), replace=True,
                                    p=sk.probs).astype(jnp.int32)
        if signed:
            sgn_new = jax.random.rademacher(ksgn, (1, sk.d),
                                            dtype=sk.signs.dtype)
        else:
            sgn_new = jnp.ones((1, sk.d), dtype=sk.signs.dtype)
    indices = jnp.concatenate([sk.indices, idx_new], axis=0)
    signs = jnp.concatenate([sk.signs, sgn_new], axis=0)
    return AccumSketch(indices=indices, signs=signs, probs=sk.probs, n=sk.n,
                       coef_=_compute_coef(indices, signs, sk.probs),
                       scheme=sk.scheme)


def make_nystrom_sketch(key, n, d, probs=None, dtype=jnp.float32,
                        *, scheme: str = "uniform") -> AccumSketch:
    """m=1 special case — the classical (or leverage-weighted) Nyström sketch.

    Delegates to ``make_accum_sketch`` (m=1, unsigned), so ``probs`` gets the
    SAME normalization/dtype coercion as every other constructor —
    unnormalized weight vectors are accepted identically everywhere — and
    ``scheme`` threads through unchanged.
    """
    return make_accum_sketch(key, n, d, m=1, probs=probs, scheme=scheme,
                             signed=False, dtype=dtype)


def make_gaussian_sketch(key, n, d, dtype=jnp.float32) -> jax.Array:
    """Dense sub-Gaussian sketch (the m→∞ limit): i.i.d. N(0, 1/d)."""
    return jax.random.normal(key, (n, d), dtype=dtype) / jnp.sqrt(d)


def make_sparse_rp(key, n, d, s: float | None = None, dtype=jnp.float32) -> jax.Array:
    """Very sparse random projection (Li, Hastie, Church 2006).

    Entries are sqrt(s/d)·{+1 w.p. 1/(2s), -1 w.p. 1/(2s), 0 otherwise}.
    Default s = sqrt(n) (their recommended density). Returned dense — it is a
    *baseline*, the paper's method never materializes its sketch.
    """
    if s is None:
        s = float(jnp.sqrt(n))
    ku, ks = jax.random.split(key)
    u = jax.random.uniform(ku, (n, d))
    sgn = jax.random.rademacher(ks, (n, d), dtype=dtype)
    mask = (u < 1.0 / s).astype(dtype)
    return sgn * mask * jnp.sqrt(s / d).astype(dtype)


@partial(jax.jit, static_argnames=("n", "d", "m", "signed", "dtype", "scheme"))
def _jit_make(key, n, d, m, probs, signed, dtype, scheme):
    return make_accum_sketch(key, n, d, m, probs, scheme=scheme,
                             signed=signed, dtype=dtype)


def make_accum_sketch_jit(key, n, d, m=1, probs=None, signed=True,
                          dtype=jnp.float32, *,
                          scheme: str = "uniform") -> AccumSketch:
    """jit'd constructor (probs must be a concrete array or None).

    ``dtype`` propagates to signs/probs/coef exactly as in the eager
    constructor (the seed version silently pinned float32), ``probs`` gets
    the same normalization (``_normalize_probs`` runs inside the traced
    constructor), and ``scheme`` rides as a static argument."""
    if probs is None:
        if scheme == "leverage":
            # same contract as the eager constructor (whose message explains
            # where leverage probs come from) — filling uniform here would
            # silently change the scheme
            make_accum_sketch(key, n, d, m, None, scheme=scheme)
        probs = jnp.full((n,), 1.0 / n, dtype=dtype)
    return _jit_make(key, n, d, m, probs, signed, jnp.dtype(dtype).name,
                     scheme)


# --------------------------------------------------------------------------- #
# Progressive accumulation state
# --------------------------------------------------------------------------- #

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class AccumState:
    """State of the progressive accumulation engine after ``m`` steps.

    Carried through ``lax.fori_loop``/``lax.while_loop`` by
    ``repro.core.apply.accum_grow``/``accum_grow_adaptive``: all m_max
    sub-sampling matrices are pre-drawn (same RNG scheme as
    ``make_accum_sketch``, so growing all the way to m_max replays
    ``make_accum_sketch(key, n, d, m_max)`` bit-for-bit; intermediate m are a
    prefix of THAT draw, not of a one-shot draw at m), and each step folds
    slab ``m`` into the running, *currently normalized* accumulators

        C = K S_m   (n, d)      W = S_mᵀ K S_m   (d, d)

    in O(n·d) — one column gather of K plus a rescale — instead of the
    O(n·m·d) from-scratch recompute per candidate m.  ``err`` holds the latest
    value of the plug-in stopping estimate (+inf until first evaluated).

    ``pdraw`` records the per-entry probability AT DRAW TIME — for fixed
    distributions it equals ``take(probs, indices)``, but the leverage scheme
    refines ``probs`` while m grows (``schemes.refresh_tail``), and the
    normalization of already-accumulated slabs must keep the probabilities
    they were actually drawn with.  The engine's coefficient gathers
    (``apply.slab_pieces``/``batch_pieces``, ``masked_sketch``) read
    ``pdraw``, never ``take(probs, indices)``.
    """

    indices: jax.Array   # (m_max, d) int32 — rows ≥ m not yet accumulated
    signs: jax.Array     # (m_max, d)
    probs: jax.Array     # (n,) current sampling distribution
    pdraw: jax.Array     # (m_max, d) per-entry probability at draw time
    C: jax.Array         # (n, d) float32 running K S_m
    W: jax.Array         # (d, d) float32 running Sᵀ K S_m
    m: jax.Array         # () int32 — number of slabs folded in so far
    err: jax.Array       # () float32 — latest stopping-rule estimate
    n: int               # static ambient dimension
    scheme: str = "uniform"  # sampling scheme driving the draws

    def tree_flatten(self):
        """Flatten into (array leaves, static aux) for jax transformations."""
        return (self.indices, self.signs, self.probs, self.pdraw, self.C,
                self.W, self.m, self.err), (self.n, self.scheme)

    @classmethod
    def tree_unflatten(cls, aux, children):
        """Rebuild from ``tree_flatten`` output (jax pytree protocol)."""
        return cls(*children, n=aux[0], scheme=aux[1])

    @property
    def m_max(self) -> int:
        """Number of pre-drawn slabs (static upper bound on m)."""
        return self.indices.shape[0]

    @property
    def d(self) -> int:
        """Sketch dimension (columns of S)."""
        return self.indices.shape[1]

    def grow_batched(self, K, B: int, *, use_kernel: bool | None = None,
                     mesh=None, donate: bool = True) -> "AccumState":
        """Fold the next ``B`` pre-drawn slabs into (C, W) in ONE pass over
        the data (``repro.core.apply.accum_grow_batched`` — lazy import, the
        engine lives there): bitwise-identical draws to B sequential steps,
        one read of K (or one kernel-eval sweep over X) instead of B."""
        from repro.core.apply import accum_grow_batched

        return accum_grow_batched(K, self, B, use_kernel=use_kernel,
                                  mesh=mesh, donate=donate)

    def sketch(self) -> AccumSketch:
        """The AccumSketch accumulated so far (host-side: m must be concrete).

        Coefficients come from ``pdraw`` — the probabilities each slab was
        actually drawn with — so leverage-refined growth (where ``probs``
        has since moved on) stays correctly normalized.  ``coef_`` is the
        authoritative normalization on the result."""
        m = int(self.m)
        if m == 0:
            raise ValueError("no sub-sampling matrices accumulated yet")
        coef = self.signs[:m] / jnp.sqrt(self.d * m * self.pdraw[:m])
        return AccumSketch(indices=self.indices[:m], signs=self.signs[:m],
                           probs=self.probs, n=self.n, coef_=coef,
                           scheme=self.scheme)

    def masked_sketch(self) -> AccumSketch:
        """Trace-safe equivalent of ``sketch()``: the FULL (m_max, d) sketch
        with slabs ≥ m zero-masked and the survivors renormalized for the
        accumulated size m (coef = r/sqrt(d·m·p)).

        Every structural application is bilinear in ``coef`` (K S, Sᵀ M,
        stream_cols, dense()), so zero-coefficient slabs contribute nothing
        and the masked sketch applies EXACTLY like ``sketch()``'s truncation —
        but with static shapes, so it works when ``m`` is a tracer (jitted
        ``grow_sketch_both`` drivers).  Note ``.m`` reads m_max on the result;
        the accumulated count lives in the caller's ``info["m"]``."""
        mf = jnp.maximum(self.m.astype(jnp.float32), 1.0)
        p = self.pdraw.astype(jnp.float32)   # at-draw probs (leverage refines)
        coef = self.signs.astype(jnp.float32) / jnp.sqrt(self.d * mf * p)
        mask = jnp.arange(self.m_max)[:, None] < self.m
        return AccumSketch(
            indices=self.indices,
            signs=jnp.where(mask, self.signs, 0.0),
            probs=self.probs, n=self.n,
            coef_=jnp.where(mask, coef, 0.0),
            scheme=self.scheme)
