"""Statistical leverage scores, statistical dimension, and the paper's
incoherence characteristic M (Theorem 8).

  ℓ_i   = (K (K + nλI)⁻¹)_ii
  d_stat = Σ ℓ_i = Σ σ_i/(σ_i + λ)        (σ_i = eigenvalues of K/n)
  Ψ_δ   = [Σ̃(Σ̃ + δ I)]^{-1/2} Uᵀ ... column ψ_i; ψ̃_i its first d_δ entries
  M     = max( max_i ‖ψ̃_i‖²/p_i ,  max_i (‖ψ_i‖² − ‖ψ̃_i‖²)/p_i )

These are O(n³) diagnostics used in experiments and tests (the production
sketch path never needs them — that is the paper's point: medium m substitutes
for leverage-exact sampling).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class KrrSpectrum(NamedTuple):
    """Eigendecomposition of K/n, shared by every oracle in this module."""

    eigvals: jax.Array   # σ_i of K/n, descending (n,)
    eigvecs: jax.Array   # U (n, n), columns matching eigvals


def spectrum(K: jax.Array) -> KrrSpectrum:
    """Full eigh of K/n (clipped to PSD, descending) — the O(n³) step every
    exact oracle below reuses via the ``spec=`` argument."""
    n = K.shape[0]
    w, U = jnp.linalg.eigh(K / n)
    order = jnp.argsort(-w)
    return KrrSpectrum(jnp.maximum(w[order], 0.0), U[:, order])


def leverage_scores(K: jax.Array, lam: float, spec: KrrSpectrum | None = None) -> jax.Array:
    """ℓ_i = (K(K+nλI)⁻¹)_ii = Σ_j U_ij² σ_j/(σ_j+λ)."""
    spec = spec or spectrum(K)
    ratio = spec.eigvals / (spec.eigvals + lam)
    return jnp.einsum("ij,j->i", spec.eigvecs**2, ratio)


def statistical_dimension(K: jax.Array, lam: float, spec: KrrSpectrum | None = None) -> jax.Array:
    """d_stat(λ) = Σ_i σ_i/(σ_i + λ) = Σ_i ℓ_i — the effective degrees of
    freedom of ridge regression at level λ (total leverage mass)."""
    spec = spec or spectrum(K)
    return jnp.sum(spec.eigvals / (spec.eigvals + lam))


def d_delta(spec: KrrSpectrum, delta: float) -> int:
    """d_δ = min{i : σ_i ≤ δ} − 1 (count of eigenvalues above δ)."""
    return int(jnp.sum(spec.eigvals > delta))


def incoherence(
    K: jax.Array, delta: float, probs: jax.Array | None = None,
    spec: KrrSpectrum | None = None,
) -> jax.Array:
    """The incoherence M of Theorem 8 under sampling distribution P (uniform default)."""
    spec = spec or spectrum(K)
    n = K.shape[0]
    if probs is None:
        probs = jnp.full((n,), 1.0 / n, dtype=K.dtype)
    dd = d_delta(spec, delta)
    scale = spec.eigvals / (spec.eigvals + delta)          # diag of Σ(Σ+δ)⁻¹ ... see note
    # Ψ_δ = [Σ(Σ+δI)]^{-1/2} ... the paper's Ψ has columns ψ_i with
    # ‖ψ_i‖² = Σ_j U_ij² σ_j/(σ_j+δ) (the ridge leverage form at level δ).
    psi_sq = spec.eigvecs**2 * scale[None, :]              # (n, n): ψ_i components²
    head = jnp.sum(psi_sq[:, :dd], axis=1)                 # ‖ψ̃_i‖²
    tail = jnp.sum(psi_sq[:, dd:], axis=1)                 # ‖ψ_i‖² − ‖ψ̃_i‖²
    return jnp.maximum(jnp.max(head / probs), jnp.max(tail / probs))


def leverage_probs(K: jax.Array, lam: float, spec: KrrSpectrum | None = None) -> jax.Array:
    """p_i ∝ ℓ_i — the leverage-based sampling distribution."""
    l = leverage_scores(K, lam, spec)
    l = jnp.maximum(l, 0.0)
    return l / jnp.sum(l)


def approx_leverage_probs(
    key: jax.Array, K: jax.Array, lam: float, sketch_dim: int
) -> jax.Array:
    """BLESS-flavoured approximate leverage scores from a Nyström pilot sketch
    (Alaoui & Mahoney 2015; Rudi et al. 2018):

        ℓ̂_i = (1/nλ) · (K_ii − k_{iS} (K_SS + nλ I_s)⁻¹ k_{Si})

    An over-estimate of ℓ_i(λ): a point far from every landmark keeps
    ℓ̂_i ≈ K_ii/(nλ) (high — it is poorly represented, exactly the points
    leverage sampling must catch), while a well-covered point's estimate is
    cancelled down by the Nyström projection. O(n·s²) instead of O(n³)."""
    n = K.shape[0]
    idx = jax.random.choice(key, n, shape=(sketch_dim,), replace=False)
    Knd = jnp.take(K, idx, axis=1)                          # (n, s)
    Kdd = jnp.take(Knd, idx, axis=0)                        # (s, s)
    reg = Kdd + n * lam * jnp.eye(sketch_dim, dtype=K.dtype)
    sol = jnp.linalg.solve(reg, Knd.T)                      # (s, n)
    proj = jnp.einsum("ns,sn->n", Knd, sol)                 # k_iᵀ(K_SS+nλ)⁻¹k_i
    l_hat = (jnp.diag(K) - proj) / (n * lam)
    l_hat = jnp.clip(l_hat, 1e-12, 1.0)
    return l_hat / jnp.sum(l_hat)
