"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch gemma3-12b --steps 200 \
      --reduced --ckpt-dir /tmp/ckpt

On a real slice this runs the full config on the production mesh; on CPU the
--reduced flag selects the same-family tiny config so the end-to-end path
(mesh → sharded jit → fault-tolerant loop → checkpoint/resume) is exercised
identically. The loop resumes from the latest checkpoint automatically —
re-running the same command after a kill is the restart drill.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import sharding as shlib
from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models.model import init_params
from repro.optim.adamw import AdamWConfig
from repro.optim.compress import CompressConfig
from repro.train.loop import LoopConfig, run
from repro.train.step import TrainConfig, init_train_state, train_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the same-family smoke config (CPU-feasible)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress", action="store_true",
                    help="sketched gradient all-reduce compression (paper technique)")
    ap.add_argument("--mesh", choices=["debug", "pod", "multipod"], default="debug")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)

    mesh = {
        "debug": lambda: make_debug_mesh(),
        "pod": lambda: make_production_mesh(multi_pod=False),
        "multipod": lambda: make_production_mesh(multi_pod=True),
    }[args.mesh]()

    tc = TrainConfig(
        optimizer=AdamWConfig(lr_peak=args.lr, total_steps=args.steps),
        n_micro=args.n_micro,
        compress=CompressConfig() if args.compress else None,
    )
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=args.batch)
    lc = LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                    ckpt_every=args.ckpt_every)

    with mesh:
        def init():
            params = init_params(jax.random.PRNGKey(0), cfg)
            state = init_train_state(params, tc)
            sh = shlib.params_shardings(mesh, state.params)
            return jax.device_put(
                state, type(state)(sh, shlib.opt_shardings(mesh, state.opt, sh),
                                   None if state.ef is None else jax.tree_util.tree_map(
                                       lambda _: shlib.replicated(mesh), state.ef)))

        step_fn = jax.jit(
            lambda s, t, l, i: train_step(s, t, l, i, cfg, tc),
            donate_argnums=(0,),
        )
        report = run(cfg, tc, dc, lc, init_params_fn=init, step_fn=step_fn)

    print(f"[train] ran {report.steps_run} steps "
          f"(resumed_from={report.resumed_from}) final_loss={report.final_loss:.4f}")
    n = len(report.losses)
    if n >= 20:
        first = float(np.mean(report.losses[: n // 5]))
        last = float(np.mean(report.losses[-n // 5:]))
        print(f"[train] loss first-20%={first:.4f} last-20%={last:.4f} "
              f"({'improved' if last < first else 'NOT improved'})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
