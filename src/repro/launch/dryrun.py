"""Multi-pod dry-run: .lower().compile() every (architecture × input-shape)
cell on the production meshes and extract roofline terms.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-110b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out results/

Success here proves the distribution config is coherent: sharding mismatches,
compile-time OOMs, and unsupported collectives all surface as hard failures.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# (no `from __future__` here — the env var lines above must be literally first)

import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import ARCHS
from repro.configs.base import SHAPES
from repro.launch.analysis import analyze, model_flops
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import make_cell
from repro.models.model import init_params
from repro.train.step import TrainConfig


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, tc: TrainConfig | None = None,
             verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    cell = make_cell(arch, shape_name, mesh, tc=tc)
    t0 = time.perf_counter()
    with mesh:
        lowered = jax.jit(cell.fn, in_shardings=cell.in_shardings).lower(*cell.args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    roof = analyze(compiled)
    shape = SHAPES[shape_name]
    n_active = _active_params(arch)
    n_tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    n_chips = 512 if multi_pod else 256
    mflops = model_flops(n_active, n_tokens, shape.kind) / n_chips  # per chip
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "ok": True,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "model_flops_per_chip": mflops,
        "useful_fraction": mflops / max(roof.flops, 1e-30),
        **roof.to_dict(),
    }
    if verbose:
        print(f"[dryrun] {cell.label} mesh={rec['mesh']}")
        print(f"  memory_analysis: {mem}")
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else ca
        print(f"  cost_analysis: flops={ca.get('flops', 0):.3e} "
              f"bytes={ca.get('bytes accessed', 0):.3e}")
        print(f"  roofline: t_comp={roof.t_compute*1e3:.2f}ms "
              f"t_mem={roof.t_memory*1e3:.2f}ms t_coll={roof.t_collective*1e3:.2f}ms "
              f"dominant={roof.dominant} frac={roof.compute_fraction():.3f} "
              f"useful={rec['useful_fraction']:.3f}")
        print(f"  collectives: {roof.coll_detail['count']}")
    return rec


_ACTIVE_CACHE: dict = {}


def _active_params(arch: str) -> int:
    if arch not in _ACTIVE_CACHE:
        cfg = ARCHS[arch]
        sds = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
        total = sum(x.size for x in jax.tree_util.tree_leaves(sds))
        _ACTIVE_CACHE[arch] = _moe_active(cfg, sds, total)
    return _ACTIVE_CACHE[arch]


def _moe_active(cfg, sds, total):
    if cfg.moe is None:
        return total
    inactive = 0
    for pos in sds["blocks"].values():
        ffn = pos.get("ffn", {})
        for n in ("wi_gate", "wi_up", "wo"):
            if n in ffn and ffn[n].ndim == 4:
                inactive += ffn[n].size * (1 - cfg.moe.top_k / cfg.moe.n_experts)
    return int(total - inactive)


def skip_reason(arch: str, shape_name: str) -> str | None:
    # every assigned cell runs: long_500k uses the paper's AccumSketch cache on
    # attention archs (see DESIGN.md §Arch-applicability) — nothing is skipped.
    return None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--out", default=None, help="append JSONL results here")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args(argv)

    if args.list:
        for a in ARCHS:
            for s in SHAPES:
                print(f"{a} {s}")
        return 0

    cells = []
    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    if not (args.all or args.arch):
        ap.error("pass --arch/--shape or --all")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    failures = 0
    for a, s, mp in cells:
        reason = skip_reason(a, s)
        if reason:
            print(f"[dryrun] SKIP {a}/{s}: {reason}")
            continue
        try:
            rec = run_cell(a, s, multi_pod=mp)
        except Exception as e:
            failures += 1
            rec = {
                "arch": a, "shape": s, "mesh": "2x16x16" if mp else "16x16",
                "ok": False, "error": f"{type(e).__name__}: {e}",
            }
            print(f"[dryrun] FAIL {a}/{s} mesh={rec['mesh']}: {rec['error']}")
            traceback.print_exc()
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    print(f"[dryrun] done; failures={failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
