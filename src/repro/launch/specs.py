"""ShapeDtypeStruct stand-ins + sharding assignments for every
(architecture × input-shape) dry-run cell. No device allocation happens here.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import sharding as shlib
from repro.configs import get_config
from repro.configs.base import SHAPES, ModelConfig, ShapeSpec
from repro.models.model import decode_step, init_cache, init_params, prefill
from repro.train.step import TrainConfig, init_train_state, train_step

PyTree = Any


class Cell(NamedTuple):
    """Everything dryrun needs: a step fn, abstract args, and in_shardings."""
    fn: Any
    args: tuple
    in_shardings: tuple
    label: str


def _sds_tree(f):
    return jax.eval_shape(f)


def params_abstract(cfg: ModelConfig) -> PyTree:
    return _sds_tree(lambda: init_params(jax.random.PRNGKey(0), cfg))


def uses_sketch_cache(cfg: ModelConfig, shape: ShapeSpec) -> bool:
    """long_500k uses the AccumSketch-compressed cache on attention blocks
    (the paper's technique is what makes 500k-context serving feasible for
    full-attention archs; SSM blocks are natively O(1))."""
    return shape.name == "long_500k" and cfg.has_attention


def train_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, tc: TrainConfig) -> Cell:
    B, S = shape.global_batch, shape.seq_len
    state_sds = _sds_tree(
        lambda: init_train_state(init_params(jax.random.PRNGKey(0), cfg), tc)
    )
    params_sh = shlib.params_shardings(mesh, state_sds.params, cfg.sharding_policy)
    opt_sh = shlib.opt_shardings(mesh, state_sds.opt, params_sh)
    ef_sh = None if state_sds.ef is None else jax.tree_util.tree_map(
        lambda _: shlib.replicated(mesh), state_sds.ef
    )
    state_sh = type(state_sds)(params_sh, opt_sh, ef_sh)
    tok_sh = NamedSharding(mesh, shlib.batch_spec(mesh, B, policy=cfg.sharding_policy))
    rep = shlib.replicated(mesh)

    tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
    labels = jax.ShapeDtypeStruct((B, S), jnp.int32)
    step = jax.ShapeDtypeStruct((), jnp.int32)
    args = [state_sds, tokens, labels, step]
    shardings = [state_sh, tok_sh, tok_sh, rep]

    if cfg.frontend:
        cond = jax.ShapeDtypeStruct((B, cfg.cond_len, cfg.d_model), jnp.bfloat16)
        cond_sh = NamedSharding(mesh, shlib.batch_spec(mesh, B, extra_dims=2, policy=cfg.sharding_policy))
        fn = lambda st, t, l, i, c: train_step(st, t, l, i, cfg, tc, cond=c)
        args.append(cond)
        shardings.append(cond_sh)
    else:
        fn = lambda st, t, l, i: train_step(st, t, l, i, cfg, tc)
    return Cell(fn, tuple(args), tuple(shardings), f"{cfg.name}/{shape.name}")


def prefill_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, q_chunk: int = 512) -> Cell:
    B, S = shape.global_batch, shape.seq_len
    p_sds = params_abstract(cfg)
    p_sh = shlib.params_shardings(mesh, p_sds, cfg.sharding_policy)
    tok_sh = NamedSharding(mesh, shlib.batch_spec(mesh, B, policy=cfg.sharding_policy))
    tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
    args = [p_sds, tokens]
    shardings = [p_sh, tok_sh]
    if cfg.frontend:
        cond = jax.ShapeDtypeStruct((B, cfg.cond_len, cfg.d_model), jnp.bfloat16)
        args.append(cond)
        shardings.append(NamedSharding(mesh, shlib.batch_spec(mesh, B, extra_dims=2, policy=cfg.sharding_policy)))
        fn = lambda p, t, c: prefill(p, t, cfg, cond=c, q_chunk=q_chunk)
    else:
        fn = lambda p, t: prefill(p, t, cfg, q_chunk=q_chunk)
    return Cell(fn, tuple(args), tuple(shardings), f"{cfg.name}/{shape.name}")


def decode_cell(cfg: ModelConfig, shape: ShapeSpec, mesh) -> Cell:
    B, S = shape.global_batch, shape.seq_len
    sketch = uses_sketch_cache(cfg, shape)
    cache_sds = _sds_tree(lambda: init_cache(cfg, B, S, use_sketch=sketch))
    p_sds = params_abstract(cfg)
    p_sh = shlib.params_shardings(mesh, p_sds, cfg.sharding_policy)
    cache_sh = type(cache_sds)(shlib.cache_shardings(mesh, cache_sds.blocks, B, cfg.sharding_policy))
    rep = shlib.replicated(mesh)
    tok_sh = NamedSharding(mesh, P(shlib.batch_spec(mesh, B, policy=cfg.sharding_policy)[0]))

    token = jax.ShapeDtypeStruct((B,), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    args = [p_sds, cache_sds, token, pos]
    shardings = [p_sh, cache_sh, tok_sh, rep]
    if sketch:
        slots = jax.ShapeDtypeStruct((cfg.sketch_attn.m_r,), jnp.int32)
        args.append(slots)
        shardings.append(rep)
        fn = lambda p, c, t, i, s: decode_step(p, c, t, i, cfg, slots=s, use_sketch=True)
    else:
        fn = lambda p, c, t, i: decode_step(p, c, t, i, cfg)
    return Cell(fn, tuple(args), tuple(shardings), f"{cfg.name}/{shape.name}")


def make_cell(arch: str, shape_name: str, mesh, *, tc: TrainConfig | None = None) -> Cell:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        # n_micro=4: the scan-over-layers carry stack (n_layers × B·S·D bf16)
        # is the dominant training temp; microbatching divides it by n_micro.
        # dp_only archs keep n_micro=1: their global batch exactly covers the
        # chips, and the models are small enough not to need the carry split.
        if tc is None:
            n_micro = 1 if cfg.sharding_policy == "dp_only" else 4
            tc = TrainConfig(n_micro=n_micro)
        return train_cell(cfg, shape, mesh, tc)
    if shape.kind == "prefill":
        return prefill_cell(cfg, shape, mesh)
    return decode_cell(cfg, shape, mesh)


def input_specs(arch: str, shape_name: str) -> tuple:
    """Public helper: the abstract inputs for a cell (mesh-independent)."""
    from repro.launch.mesh import make_debug_mesh

    return make_cell(arch, shape_name, make_debug_mesh()).args
