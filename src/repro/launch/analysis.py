"""Roofline-term extraction from a compiled dry-run artifact.

XLA's HloCostAnalysis visits every instruction ONCE — `while` (lax.scan) bodies
are NOT multiplied by their trip count, which undercounts a scanned-layers LM
by ~n_layers×. This module therefore re-derives the three roofline terms from
the compiled HLO text with trip-count multipliers:

  * parse the module into computations; build a symbol table of result shapes;
  * find `while` ops, read the trip count from the loop condition's compare
    constant, and propagate multipliers through called computations;
  * FLOPs: 2·|result|·|contraction| for every dot/convolution (elementwise
    FLOPs are ignored — dots dominate LM workloads; stated in EXPERIMENTS.md);
  * HBM bytes: Σ (operand + result bytes) over *top-level* instructions
    (fusion bodies are not descended into — a fusion reads its operands and
    writes its result once, which is exactly the post-fusion HBM traffic);
  * collective bytes: result-shape bytes × ring factor (all-reduce 2×).

Terms (per chip — the SPMD module is the per-partition program):
  compute    = FLOPs / hw.peak_flops     memory = bytes / hw.hbm_bw
  collective = coll_bytes / hw.ici_bw

The chip numbers live in `repro.analysis.hardware.HardwareModel` (default:
TPU v5e-class) — `Roofline` carries the model it was scored against, and
`set_default_hardware` swaps the target chip process-wide.
"""
from __future__ import annotations

import dataclasses
import re

from repro.analysis.hardware import (
    TPU_V5E,
    HardwareModel,
    get_default_hardware,
)
from repro.analysis.hlo import DTYPE_BYTES as _DTYPE_BYTES
from repro.analysis.hlo import shape_bytes as _shape_bytes
from repro.analysis.hlo import shape_dims as _shape_dims

# Backwards-compatible aliases for the historical module constants; the
# overridable source of truth is repro.analysis.hardware.
PEAK_FLOPS = TPU_V5E.peak_flops      # bf16 FLOP/s per chip (TPU v5e-class)
HBM_BW = TPU_V5E.hbm_bw              # B/s per chip
ICI_BW = TPU_V5E.ici_bw              # B/s per link

_COLLECTIVE_FACTOR = {
    "all-gather": 1.0, "all-gather-start": 1.0,
    "all-reduce": 2.0, "all-reduce-start": 2.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0, "collective-permute-start": 1.0,
}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(\([^)]*\))\s*->")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],{}]+))\s+([\w\-]+)\((.*)$"
)
_OPERAND = re.compile(r"%([\w.\-]+)")
_CALLREF_ONE = re.compile(r"(?:body|condition|to_apply|calls)=%?([\w.\-]+)")
_CALLREF_SET = re.compile(r"(?:calls|branch_computations)=\{([^}]*)\}")


def _call_targets(rest: str) -> list[str]:
    out = [m.group(1) for m in _CALLREF_ONE.finditer(rest)]
    for m in _CALLREF_SET.finditer(rest):
        out.extend(re.findall(r"[\w.\-]+", m.group(1)))
    return out
_CONST_INT = re.compile(r"constant\((\d+)\)")


@dataclasses.dataclass
class _Instr:
    name: str
    type_str: str
    op: str
    rest: str
    is_root: bool = False


class HloModule:
    def __init__(self, text: str):
        self.comps: dict[str, list[_Instr]] = {}
        self.defs: dict[str, str] = {}                # global instr name -> type
        self.entry: str | None = None
        cur = None
        for line in text.splitlines():
            s = line.strip()
            if s.endswith("{") and "->" in s and "=" not in s.split("->")[0].split("(")[0]:
                # computation header: "[ENTRY] %name (sig) -> type {"
                head = s.split("(")[0].strip()
                is_entry = head.startswith("ENTRY")
                name = head.replace("ENTRY", "").strip().lstrip("%")
                if name:
                    cur = name
                    self.comps[cur] = []
                    if is_entry:
                        self.entry = cur
                continue
            if cur is None:
                continue
            m = _INSTR.match(line)
            if m:
                name, type_str, op, rest = m.groups()
                self.comps[cur].append(_Instr(
                    name, type_str, op, rest,
                    is_root=line.lstrip().startswith("ROOT"),
                ))
                self.defs[name] = type_str

    # ------------------------------------------------------------------ #
    def _operand_names_types(self, comp: str, rest: str) -> list[tuple[str, str]]:
        """Resolve leading operand %names to (name, type) pairs (defs map —
        every instruction incl. `parameter` defines its type on its own line)."""
        ops = []
        depth = 0
        end = len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    end = i
                    break
                depth -= 1
        for m in _OPERAND.finditer(rest[:end]):
            t = self.defs.get(m.group(1))
            if t:
                ops.append((m.group(1), t))
        return ops

    def _operand_types(self, comp: str, rest: str) -> list[str]:
        return [t for _, t in self._operand_names_types(comp, rest)]

    def _trip_count(self, ins: _Instr) -> float:
        """Prefer XLA's known_trip_count backend_config; fall back to the
        largest constant in the condition computation."""
        m = re.search(r"known_trip_count[^0-9]*(\d+)", ins.rest)
        if m:
            return float(m.group(1))
        cond = re.search(r"condition=%?([\w.\-]+)", ins.rest)
        best = 1
        if cond:
            for ci in self.comps.get(cond.group(1), []):
                for mc in _CONST_INT.finditer(ci.op + "(" + ci.rest):
                    best = max(best, int(mc.group(1)))
        return float(best)

    def multipliers(self) -> dict[str, float]:
        """Execution multiplier per computation (entry = 1; while bodies ×trip)."""
        referenced = set()
        refs: dict[str, list[tuple[str, float]]] = {c: [] for c in self.comps}
        for comp, instrs in self.comps.items():
            for ins in instrs:
                factor = self._trip_count(ins) if ins.op == "while" else 1.0
                for target in _call_targets(ins.rest):
                    if target in self.comps:
                        referenced.add(target)
                        # while body AND condition both run ~trip times
                        refs[comp].append((target, factor))
        entries = [c for c in self.comps if c not in referenced]
        if self.entry and self.entry not in entries:
            entries.append(self.entry)
        mult: dict[str, float] = {}
        stack = [(e, 1.0) for e in entries]
        while stack:
            comp, m = stack.pop()
            if comp in mult and mult[comp] >= m:
                continue
            mult[comp] = m
            for tgt, f in refs.get(comp, []):
                stack.append((tgt, m * f))
        return mult

    # ------------------------------------------------------------------ #
    def dot_flops(self, comp: str, ins: _Instr) -> float:
        if ins.op not in ("dot", "convolution"):
            return 0.0
        out_elems = 1
        for _, dims in _shape_dims(ins.type_str):
            for d in dims:
                out_elems *= d
        # contraction size from lhs shape and contracting dims
        ops = self._operand_types(comp, ins.rest)
        contract = 1
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
        if m and ops:
            lhs_dims = _shape_dims(ops[0])
            if lhs_dims:
                dims = lhs_dims[0][1]
                for i in (int(x) for x in m.group(1).split(",") if x.strip()):
                    if i < len(dims):
                        contract *= dims[i]
        elif ins.op == "convolution" and len(ops) >= 2:
            # windowed contraction ≈ prod(kernel spatial × in features)
            k = _shape_dims(ops[1])
            if k:
                kern = 1
                for d in k[0][1]:
                    kern *= d
                out_last = _shape_dims(ins.type_str)[0][1][-1] if _shape_dims(ins.type_str) else 1
                contract = max(kern // max(out_last, 1), 1)
        return 2.0 * out_elems * contract

    # ------------------------------------------------------------------ #
    def _invariant_names(self) -> dict[str, set[str]]:
        """Per while-body computation: names of loop-INVARIANT carried values
        (get-tuple-element(param, i) returned unchanged at root-tuple slot i).

        These are parameters the loop re-reads every iteration — weights used
        inside a time scan. On TPU, XLA keeps them VMEM-resident across the
        loop when they fit (they are written to HBM once, not per step), so
        the HBM model charges them zero inside the body. Without this, a
        recurrent matrix re-counts per timestep and dominates every RNN-style
        roofline with traffic a real chip never issues."""
        if hasattr(self, "_inv_cache"):
            return self._inv_cache
        bodies = set()
        for instrs in self.comps.values():
            for ins in instrs:
                if ins.op == "while":
                    m = re.search(r"body=%?([\w.\-]+)", ins.rest)
                    if m:
                        bodies.add(m.group(1))
        out: dict[str, set[str]] = {}
        for bname in bodies:
            body = self.comps.get(bname, [])
            gte_idx: dict[str, int] = {}
            root = None
            for bi in body:
                if bi.op == "get-tuple-element":
                    mi = re.search(r"index=(\d+)", bi.rest)
                    if mi:
                        gte_idx[bi.name] = int(mi.group(1))
                if bi.is_root:
                    root = bi
            inv: set[str] = set()
            if root is not None and root.op == "tuple":
                operands = [m.group(1) for m in _OPERAND.finditer(root.rest)]
                for slot, name in enumerate(operands):
                    if gte_idx.get(name) == slot:
                        inv.add(name)
            out[bname] = inv
        self._inv_cache = out
        return out

    def _param_index(self, ins: _Instr) -> int | None:
        m = re.match(r"\s*(\d+)", ins.rest)
        return int(m.group(1)) if m else None

    def _is_pure_convert(self, comp_name: str) -> bool:
        """True if the computation is only parameter/convert/copy/bitcast ops.

        The CPU backend legalizes bf16 dots by materializing explicit f32
        copies of the weights (`wrapped_convert` kLoop fusions). A TPU backend
        consumes bf16 in the MXU directly and fuses dtype converts into the
        consumer — these instructions are measurement artifacts of running the
        dry-run on CPU, not traffic the target chip would issue, so they are
        charged zero. (The f32-sized operand reads at the consumers are still
        counted, which keeps the model conservative.)"""
        body = self.comps.get(comp_name)
        if not body:
            return False
        return all(bi.op in ("parameter", "convert", "copy", "bitcast")
                   for bi in body)

    def _fusion_bytes(self, comp: str, ins: _Instr) -> float:
        """HBM traffic of one fusion call.

        A fusion reads its operands and writes its result once — EXCEPT that a
        parameter consumed only by dynamic-slice/gather ops inside the body
        only reads the slices (XLA keeps the big operand in place; this is how
        scan bodies address their stacked inputs), and a root
        dynamic-update-slice writes only the updated window (in-place carry
        update). Counting full operands here overstates scan-body traffic by
        the trip count × (L/1) — the dominant error for scanned LMs."""
        targets = [t for t in _call_targets(ins.rest) if t in self.comps]
        body = None
        for t in targets:
            if self._is_pure_convert(t):
                return 0.0      # CPU bf16-legalization artifact (see above)
            if t.startswith("fused"):
                body = self.comps[t]
                break
        inv = self._invariant_names().get(comp, set())
        named_ops = self._operand_names_types(comp, ins.rest)
        if body is None:
            b = _shape_bytes(ins.type_str)
            return b + sum(_shape_bytes(t) for nm, t in named_ops if nm not in inv)

        # map parameter index -> instr name; collect per-name uses
        param_name = {}
        uses: dict[str, list[_Instr]] = {}
        for bi in body:
            if bi.op == "parameter":
                idx = self._param_index(bi)
                if idx is not None:
                    param_name[idx] = bi.name
            for m in _OPERAND.finditer(bi.rest):
                uses.setdefault(m.group(1), []).append(bi)

        total = 0.0
        for idx, (nm, t) in enumerate(named_ops):
            if nm in inv:
                continue        # loop-invariant: VMEM-resident across the loop
            name = param_name.get(idx)
            us = uses.get(name, []) if name else []
            if us and all(u.op in ("dynamic-slice", "gather") for u in us):
                total += sum(_shape_bytes(u.type_str) for u in us)
            elif us and all(u.op == "dynamic-update-slice" for u in us):
                # aliased carry being updated in place: reads nothing extra
                continue
            else:
                total += _shape_bytes(t)

        root = body[-1] if body else None
        for bi in body:
            if bi.is_root:
                root = bi
        if root is not None and root.op == "dynamic-update-slice":
            # write = the updated window (operand 1), not the whole buffer
            upd_ops = [m.group(1) for m in _OPERAND.finditer(root.rest)]
            if len(upd_ops) >= 2 and upd_ops[1] in self.defs:
                total += _shape_bytes(self.defs[upd_ops[1]])
            else:
                total += _shape_bytes(root.type_str)
        else:
            total += _shape_bytes(ins.type_str)
        return total

    def analyze(self) -> tuple[float, float, float, dict]:
        mult = self.multipliers()
        flops = 0.0
        hbm = 0.0
        coll = 0.0
        coll_detail: dict = {"bytes": {}, "count": {}}
        for comp, instrs in self.comps.items():
            m = mult.get(comp, 0.0)
            if m == 0.0:
                continue
            for ins in instrs:
                f = self.dot_flops(comp, ins)
                flops += f * m
                if ins.op in _COLLECTIVE_FACTOR and not ins.op.endswith("-done"):
                    b = _shape_bytes(ins.type_str)
                    coll += b * _COLLECTIVE_FACTOR[ins.op] * m
                    coll_detail["bytes"][ins.op] = coll_detail["bytes"].get(ins.op, 0) + b * m
                    coll_detail["count"][ins.op] = coll_detail["count"].get(ins.op, 0) + m
                # HBM: count ops at "executable" level — entry/loop bodies and
                # fusion CALLS (their operands+result), not inside fusion bodies
            if not comp.startswith(("fused_",)):
                for ins in instrs:
                    if ins.op in ("parameter", "constant", "tuple", "get-tuple-element",
                                  "bitcast", "while", "call", "conditional",
                                  "convert"):  # convert: CPU bf16-legalization artifact
                        continue
                    if ins.op in ("dynamic-slice", "gather", "dynamic-update-slice"):
                        # reads/writes only the slice, not the full operand
                        hbm += 2 * _shape_bytes(ins.type_str) * m
                        continue
                    if ins.op == "fusion":
                        hbm += self._fusion_bytes(comp, ins) * m
                        continue
                    inv = self._invariant_names().get(comp, set())
                    b = _shape_bytes(ins.type_str)
                    for nm, t in self._operand_names_types(comp, ins.rest):
                        if nm not in inv:
                            b += _shape_bytes(t)
                    hbm += b * m
        return flops, hbm, coll, coll_detail


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-chip FLOPs (trip-count corrected)
    hbm_bytes: float             # per-chip HBM traffic estimate
    coll_bytes: float            # per-chip weighted collective bytes
    coll_detail: dict
    peak_mem_bytes: float        # per-chip peak allocation (memory_analysis)
    xla_flops: float = 0.0       # raw cost_analysis (uncorrected, for reference)
    xla_bytes: float = 0.0
    hardware: HardwareModel | None = None   # None → process default

    @property
    def hw(self) -> HardwareModel:
        """The chip model this roofline is scored against."""
        if self.hardware is not None:
            return self.hardware
        return get_default_hardware()

    @property
    def t_compute(self) -> float:
        return self.flops / self.hw.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / self.hw.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / self.hw.ici_bw

    @property
    def dominant(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def compute_fraction(self) -> float:
        return self.t_compute / max(self.bound_time, 1e-30)

    def to_dict(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes, "coll_detail": self.coll_detail,
            "peak_mem_bytes": self.peak_mem_bytes,
            "xla_flops": self.xla_flops, "xla_bytes": self.xla_bytes,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "dominant": self.dominant,
            "roofline_fraction": self.compute_fraction(),
            "hardware": self.hw.name,
        }


def analyze(compiled, hardware: HardwareModel | None = None) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    mem = compiled.memory_analysis()
    peak = float(
        getattr(mem, "temp_size_in_bytes", 0)
        + getattr(mem, "argument_size_in_bytes", 0)
        + getattr(mem, "output_size_in_bytes", 0)
        - getattr(mem, "alias_size_in_bytes", 0)
    )
    mod = HloModule(compiled.as_text())
    flops, hbm, coll, detail = mod.analyze()
    return Roofline(
        flops=flops, hbm_bytes=hbm, coll_bytes=coll, coll_detail=detail,
        peak_mem_bytes=peak,
        xla_flops=float(cost.get("flops", 0.0)),
        xla_bytes=float(cost.get("bytes accessed", 0.0)),
        hardware=hardware,
    )


def model_flops(n_params_active: int, n_tokens: int, kind: str) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference fwd)."""
    return (6.0 if kind == "train" else 2.0) * n_params_active * n_tokens
