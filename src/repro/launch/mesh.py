"""Production meshes. A FUNCTION (not module-level constant) so importing this
module never touches jax device state."""
from __future__ import annotations

import math


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; multi_pod prepends a pod axis (2×16×16 = 512).

    Uses the first prod(shape) available devices, so it works both on real
    slices and under --xla_force_host_platform_device_count placeholders."""
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devs)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import (launch/dryrun.py does this)."
        )
    return jax.make_mesh(shape, axes, devices=devs[:need])


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh over whatever devices exist (tests)."""
    import jax

    return jax.make_mesh((n_data, n_model), ("data", "model"),
                         devices=jax.devices()[: n_data * n_model])
