"""Production serving launcher: batched requests against exact or sketched
(AccumSketch, the paper's technique) KV caches.

  PYTHONPATH=src python -m repro.launch.serve --arch zamba2-7b --reduced \
      --batch 4 --prompt-len 32 --new-tokens 32 --sketch
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models.model import init_params
from repro.serve.engine import Engine, ServeConfig


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--sketch", action="store_true",
                    help="AccumSketch-compressed cache (O(d_slots) memory, "
                    "context-length independent)")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)

    params = init_params(jax.random.PRNGKey(0), cfg)
    sc = ServeConfig(max_len=args.prompt_len + args.new_tokens,
                     use_sketch=args.sketch, temperature=args.temperature)
    eng = Engine(cfg, params, sc)

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len),
                           dtype=np.int32)
    t0 = time.perf_counter()
    out, cache = eng.generate(prompts, args.new_tokens)
    dt = time.perf_counter() - t0
    total = args.batch * args.new_tokens
    print(f"[serve] arch={cfg.name} sketch={args.sketch} "
          f"generated {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s incl. compile)")
    print(f"[serve] sample continuation: {out[0][:16].tolist()}")
    cache_bytes = sum(
        np.prod(x.shape) * x.dtype.itemsize
        for x in jax.tree_util.tree_leaves(cache)
    )
    print(f"[serve] cache bytes: {cache_bytes/1e6:.2f} MB")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
