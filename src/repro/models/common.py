"""Shared model utilities: init, RMSNorm, RoPE, chunked cross-entropy."""
from __future__ import annotations

import jax
import jax.numpy as jnp

PARAM_DTYPE = jnp.bfloat16


def dense_init(key, shape, in_axis: int = 0, dtype=PARAM_DTYPE):
    """Truncated-normal fan-in init."""
    fan_in = shape[in_axis]
    std = 1.0 / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))
    w = jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32) * std
    return w.astype(dtype)


def embed_init(key, shape, dtype=PARAM_DTYPE):
    w = jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32) * 0.02
    return w.astype(dtype)


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def rope_table(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """(P,) int positions → (P, head_dim/2) sin/cos tables (f32)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: (..., P, H, Dh); sin/cos: (P, Dh/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    s = sin[..., :, None, :]   # broadcast over head axis
    c = cos[..., :, None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * c - xf2 * s, xf2 * c + xf1 * s], axis=-1
    ).astype(x.dtype)


def chunked_xent(
    h: jax.Array,            # (B, S, D) final hidden states
    emb_out: jax.Array,      # (V, D) output embedding (logits = h @ emb_out.T)
    labels: jax.Array,       # (B, S) int32
    mask: jax.Array | None,  # (B, S) 1.0 where the loss counts
    chunk: int = 512,
) -> tuple[jax.Array, jax.Array]:
    """Mean cross-entropy without materializing full (B,S,V) logits.

    UNROLLED Python loop over sequence chunks (nchunk is small and static),
    NOT lax.scan: with a scan, the closed-over output embedding becomes a
    loop-carried weight — SPMD must all-gather W and all-reduce the replicated
    dW accumulator EVERY chunk (measured: 83% of xlstm-125m/train_4k's
    collective bytes). Straight-line chunks let XLA hoist one W gather and sum
    the per-chunk partial dW locally, emitting a single all-reduce.
    jax.checkpoint per chunk keeps the (B,c,V) logits out of the residuals."""
    B, S, D = h.shape
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    nchunk = max(S // chunk, 1)
    chunk = S // nchunk
    hc = h.reshape(B, nchunk, chunk, D).swapaxes(0, 1)          # (nc, B, c, D)
    lc = labels.reshape(B, nchunk, chunk).swapaxes(0, 1)
    mc = mask.reshape(B, nchunk, chunk).swapaxes(0, 1)

    # gather the (possibly FSDP-sharded) output embedding ONCE, outside the
    # checkpointed chunks — otherwise every chunk (and its backward recompute)
    # re-issues the all-gather
    from repro.sharding import constrain  # late import: avoids models↔sharding cycle
    emb_f = constrain(emb_out.astype(jnp.float32), None, None)

    @jax.checkpoint  # recompute the (B,c,V) logits in backward
    def body(hcb, lcb, mcb, W):
        logits = hcb.astype(jnp.float32) @ W.T
        lse = jax.nn.logsumexp(logits, axis=-1)
        lab = jnp.take_along_axis(logits, lcb[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - lab) * mcb), jnp.sum(mcb)

    loss_sum = jnp.zeros((), jnp.float32)
    count = jnp.zeros((), jnp.float32)
    for i in range(nchunk):
        ls, ct = body(hc[i], lc[i], mc[i], emb_f)
        loss_sum = loss_sum + ls
        count = count + ct
    return loss_sum / jnp.maximum(count, 1.0), count
