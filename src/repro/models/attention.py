"""Attention blocks: GQA + RoPE, chunked-causal (memory-safe prefill), sliding
window, KV-cache decode, and AccumSketch (paper technique) compressed decode."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.sketched_attention import (
    SketchCache,
    init_sketch_cache,
    sketch_decode_attend,
    sketch_prefill_attend,
    update_sketch_cache,
)
from repro.models.common import apply_rope, dense_init

NEG_INF = -1e30


def init_attn(key, cfg: ModelConfig):
    H, Hkv, Dh, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, H * Dh)),
        "wk": dense_init(ks[1], (D, Hkv * Dh)),
        "wv": dense_init(ks[2], (D, Hkv * Dh)),
        "wo": dense_init(ks[3], (H * Dh, D)),
        "norm": jnp.zeros((D,), jnp.float32),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * Dh,), jnp.float32)
        p["bk"] = jnp.zeros((Hkv * Dh,), jnp.float32)
        p["bv"] = jnp.zeros((Hkv * Dh,), jnp.float32)
    return p


def _qkv(p, h, cfg: ModelConfig, sin, cos):
    B, S, D = h.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = h @ p["wq"]
    k = h @ p["wk"]
    v = h @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = q.reshape(B, S, H, Dh)
    k = k.reshape(B, S, Hkv, Dh)
    v = v.reshape(B, S, Hkv, Dh)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    return q, k, v


def _chunked_causal(
    q, k, v, cfg: ModelConfig, *, window: int | None, q_chunk: int, out_dtype
) -> jax.Array:
    """Chunked-causal attention core shared by `attn_forward` / `attn_prefill`:
    q (B, S, H, Dh), k/v (B, S, Hkv, Dh) → (B, S, H·Dh) pre-output-projection,
    scanned over query chunks so peak memory is O(B·H·q_chunk·S) not O(B·H·S²)."""
    B, S = q.shape[:2]
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // Hkv
    # head-aligned TP: shard the KV-head axis (padded if it doesn't divide)
    # so the QKᵀ/AV contractions stay shard-local — see sharding.constrain
    from repro.sharding import constrain
    pol = cfg.sharding_policy
    head_tp = "tp!" if cfg.attn_head_tp else None
    k = constrain(k, "dp", None, head_tp, None, policy=pol)
    v = constrain(v, "dp", None, head_tp, None, policy=pol)
    scale = 1.0 / jnp.sqrt(jnp.asarray(Dh, jnp.float32))
    kpos = jnp.arange(S)

    nq = max(S // q_chunk, 1)
    qc = S // nq
    qs = q.reshape(B, nq, qc, Hkv, G, Dh).transpose(1, 0, 2, 3, 4, 5)  # (nq,B,qc,Hkv,G,Dh)
    qs = constrain(qs, None, "dp", None, head_tp, None, None, policy=pol)

    @jax.checkpoint  # backward recomputes the (·,qc,S) logits: the chunk scan
    def body(i, qblk):  # must not stack per-chunk score residuals (O(S²))
        qpos = i * qc + jnp.arange(qc)
        logits = jnp.einsum(
            "bqhgd,bshd->bhgqs", qblk.astype(jnp.float32), k.astype(jnp.float32)
        ) * scale
        mask = kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > (qpos[:, None] - window)
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        o = jnp.einsum(
            "bhgqs,bshd->bqhgd", jax.nn.softmax(logits, axis=-1), v.astype(jnp.float32)
        )
        return o.astype(out_dtype)

    out = jax.lax.map(lambda args: body(*args), (jnp.arange(nq), qs))
    return out.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H * Dh)


def attn_forward(
    p, h: jax.Array, cfg: ModelConfig, sin, cos, *,
    window: int | None = None, q_chunk: int = 512,
) -> jax.Array:
    """Causal (optionally sliding-window) attention, scanned over query chunks
    so peak memory is O(B·H·q_chunk·S) instead of O(B·H·S²)."""
    q, k, v = _qkv(p, h, cfg, sin, cos)
    out = _chunked_causal(q, k, v, cfg, window=window, q_chunk=q_chunk,
                          out_dtype=h.dtype)
    return out @ p["wo"]


# --------------------------------------------------------------------------- #
# Decode: exact KV cache
# --------------------------------------------------------------------------- #

class KVCache(NamedTuple):
    k: jax.Array  # (B, S_max, Hkv, Dh)
    v: jax.Array  # (B, S_max, Hkv, Dh)


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> KVCache:
    shp = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(jnp.zeros(shp, dtype), jnp.zeros(shp, dtype))


def attn_prefill(
    p, h: jax.Array, cache: KVCache, cfg: ModelConfig, sin, cos, *,
    window: int | None = None, q_chunk: int = 512,
) -> tuple[jax.Array, KVCache]:
    """Batched exact-cache prefill: chunked-causal attention for all L prompt
    tokens (positions 0..L-1) plus ONE bulk KV-cache write — replaces L
    sequential `attn_decode` dispatches. Sliding-window (ring-buffer) caches
    keep exactly the last S_cache tokens at slot t % S_cache, matching what L
    sequential ring writes would leave behind. Returns (out (B, L, D), cache)."""
    B, L, _ = h.shape
    q, k, v = _qkv(p, h, cfg, sin, cos)
    out = _chunked_causal(q, k, v, cfg, window=window, q_chunk=q_chunk,
                          out_dtype=h.dtype) @ p["wo"]
    S_cache = cache.k.shape[1]
    kc, vc = k.astype(cache.k.dtype), v.astype(cache.v.dtype)
    if L <= S_cache:
        cache = KVCache(
            jax.lax.dynamic_update_slice(cache.k, kc, (0, 0, 0, 0)),
            jax.lax.dynamic_update_slice(cache.v, vc, (0, 0, 0, 0)),
        )
    else:
        ring = (jnp.arange(L - S_cache, L)) % S_cache
        cache = KVCache(
            cache.k.at[:, ring].set(kc[:, L - S_cache:]),
            cache.v.at[:, ring].set(vc[:, L - S_cache:]),
        )
    return out, cache


def attn_decode(
    p, h_t: jax.Array, cache: KVCache, pos: jax.Array, cfg: ModelConfig,
    sin_t, cos_t, *, write_pos: jax.Array | None = None,
) -> tuple[jax.Array, KVCache]:
    """One-token decode. h_t: (B, 1, D); pos: scalar current absolute index.

    `write_pos` defaults to pos; a ring-buffer (sliding-window) cache passes
    pos % window. Validity mask: slot s is valid iff s <= pos (for a full
    cache) — for a ring buffer once pos >= S_cache-1 every slot is valid,
    which the same comparison yields since pos keeps growing."""
    B = h_t.shape[0]
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // Hkv
    if write_pos is None:
        write_pos = pos
    q, k, v = _qkv(p, h_t, cfg, sin_t, cos_t)                       # (B,1,·,Dh)
    cache = KVCache(
        jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype), (0, write_pos, 0, 0)),
        jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype), (0, write_pos, 0, 0)),
    )
    S = cache.k.shape[1]
    kpos = jnp.arange(S)
    scale = 1.0 / jnp.sqrt(jnp.asarray(Dh, jnp.float32))
    qg = q.reshape(B, Hkv, G, Dh)
    logits = jnp.einsum(
        "bhgd,bshd->bhgs", qg.astype(jnp.float32), cache.k.astype(jnp.float32)
    ) * scale
    mask = kpos <= pos
    logits = jnp.where(mask[None, None, None, :], logits, NEG_INF)
    o = jnp.einsum(
        "bhgs,bshd->bhgd", jax.nn.softmax(logits, axis=-1), cache.v.astype(jnp.float32)
    )
    out = o.reshape(B, 1, H * Dh).astype(h_t.dtype) @ p["wo"]
    return out, cache


# --------------------------------------------------------------------------- #
# Decode: sketched (compressed) cache — the paper's technique in serving
# --------------------------------------------------------------------------- #

def init_attn_sketch_cache(cfg: ModelConfig, batch: int, dtype) -> SketchCache:
    """Sketched attention cache sized from cfg (`dtype` for k/v sums; mass f32)."""
    return init_sketch_cache(
        batch, cfg.n_kv_heads, cfg.sketch_attn.d_slots, cfg.head_dim, dtype
    )


def attn_prefill_sketched(
    p, h: jax.Array, cache: SketchCache, cfg: ModelConfig, sin, cos,
    slot_table: jax.Array, *, chunk: int = 128,
) -> tuple[jax.Array, SketchCache]:
    """Batched sketched-cache prefill: one vectorized segment-sum scatter for
    all L tokens' (k, v) plus evolving-cache attention (position t sees the
    cache state after its own scatter — identical semantics to L sequential
    `attn_decode_sketched` dispatches, see `sketch_prefill_attend`).
    slot_table: (L, m_r) from `decode_slot_table`. Returns (out (B, L, D), cache)."""
    B, L, _ = h.shape
    H, Dh = cfg.n_heads, cfg.head_dim
    q, k, v = _qkv(p, h, cfg, sin, cos)
    o, cache = sketch_prefill_attend(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        cache, slot_table, chunk=chunk,
    )
    out = o.transpose(0, 2, 1, 3).reshape(B, L, H * Dh).astype(h.dtype) @ p["wo"]
    return out, cache


def attn_decode_sketched(
    p, h_t: jax.Array, cache: SketchCache, cfg: ModelConfig,
    sin_t, cos_t, slots: jax.Array,
) -> tuple[jax.Array, SketchCache]:
    """One-token decode over the AccumSketch-compressed cache: O(d_slots) per
    token and O(d_slots·Dh) memory regardless of context length."""
    B = h_t.shape[0]
    H, Dh = cfg.n_heads, cfg.head_dim
    q, k, v = _qkv(p, h_t, cfg, sin_t, cos_t)
    cache = update_sketch_cache(cache, k[:, 0], v[:, 0], slots)
    o = sketch_decode_attend(q[:, 0].reshape(B, H, Dh), cache)
    out = o.reshape(B, 1, H * Dh).astype(h_t.dtype) @ p["wo"]
    return out, cache
