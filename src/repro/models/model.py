"""The composable LM: `pattern` × `n_superblocks` scanned with jax.lax.scan.

Scanning keeps the HLO size O(pattern) instead of O(n_layers) — this is what
makes 512-way multi-pod SPMD compiles tractable, and it is also where remat
(activation checkpointing) attaches.

Params pytree:
  embed      (V, D)            — input embedding (tied output head if cfg.tie)
  lm_head    (V, D) | absent   — untied output head
  final_norm (D,)
  blocks     {pos{i}: subtree stacked over n_superblocks}
  shared     {...}             — parameters for `attn_shared` kinds (Zamba2)
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.sketched_attention import SketchCache
from repro.models import attention as att
from repro.models import ffn as ffn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.common import chunked_xent, embed_init, rmsnorm, rope_table
from repro.sharding import constrain

Params = dict
PyTree = Any


# --------------------------------------------------------------------------- #
# Init
# --------------------------------------------------------------------------- #

def _init_block(key, kind: str, cfg: ModelConfig) -> Params:
    if kind in ("attn", "attn_local"):
        ka, kf = jax.random.split(key)
        p = {"attn": att.init_attn(ka, cfg)}
        if cfg.ffn == "dense":
            p["ffn"] = ffn_mod.init_ffn(kf, cfg.d_model, cfg.d_ff)
        elif cfg.ffn == "moe":
            p["ffn"] = moe_mod.init_moe(kf, cfg.d_model, cfg.moe)
        return p
    if kind == "mamba2":
        return {"mixer": ssm_mod.init_mamba2(key, cfg)}
    if kind == "mlstm":
        return {"mixer": xlstm_mod.init_mlstm(key, cfg)}
    if kind == "slstm":
        return {"mixer": xlstm_mod.init_slstm(key, cfg)}
    raise ValueError(f"unknown block kind {kind}")


def init_params(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, 4)
    params: Params = {
        "embed": embed_init(keys[0], (cfg.vocab_size, cfg.d_model)),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(keys[1], (cfg.vocab_size, cfg.d_model))

    # stacked per-superblock params (vmap over superblock index)
    blocks = {}
    for i, kind in enumerate(cfg.pattern):
        if kind == "attn_shared":
            continue
        kinit = jax.random.fold_in(keys[2], i)  # rng-stream: init-block
        sb_keys = jax.random.split(kinit, cfg.n_superblocks)
        blocks[f"pos{i}"] = jax.vmap(lambda k: _init_block(k, kind, cfg))(sb_keys)
    params["blocks"] = blocks

    shared = {}
    if "attn_shared" in cfg.pattern:
        ka, kf = jax.random.split(keys[3])
        shared["attn"] = att.init_attn(ka, cfg)
        shared["ffn"] = ffn_mod.init_ffn(kf, cfg.d_model, cfg.d_ff)
    params["shared"] = shared
    return params


def param_count(params: Params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


def active_param_count(cfg: ModelConfig, params: Params) -> int:
    """Active-per-token parameters (MoE: top_k of n_experts)."""
    total = param_count(params)
    if cfg.moe is None:
        return total
    expert_names = ("wi_gate", "wi_up", "wo")
    inactive = 0
    for pos in params["blocks"].values():
        ffn = pos.get("ffn", {})
        for n in expert_names:
            if n in ffn and ffn[n].ndim == 4:      # (n_sb, E, ·, ·)
                inactive += ffn[n].size * (1 - cfg.moe.top_k / cfg.moe.n_experts)
    return int(total - inactive)


# --------------------------------------------------------------------------- #
# Forward (training / prefill)
# --------------------------------------------------------------------------- #

def _block_forward(kind, bp, shared, h, cfg: ModelConfig, sin, cos, aux, q_chunk):
    eps = cfg.norm_eps
    if kind in ("attn", "attn_local", "attn_shared"):
        p = shared if kind == "attn_shared" else bp
        window = cfg.window if kind == "attn_local" else None
        h = h + att.attn_forward(
            p["attn"], rmsnorm(h, p["attn"]["norm"], eps), cfg, sin, cos,
            window=window, q_chunk=q_chunk,
        )
        if "ffn" in p:
            x = rmsnorm(h, p["ffn"]["norm"], eps)
            if cfg.ffn == "moe" and kind != "attn_shared":
                y, metrics = moe_mod.moe_forward(p["ffn"], x, cfg.moe)
                aux = aux + metrics.aux_loss
            else:
                y = ffn_mod.ffn_forward(p["ffn"], x)
            h = h + y
        return h, aux
    p = bp["mixer"]
    x = rmsnorm(h, p["norm"], eps)
    if kind == "mamba2":
        y = ssm_mod.mamba2_forward(p, x, cfg)
    elif kind == "mlstm":
        y = xlstm_mod.mlstm_forward(p, x, cfg)
    elif kind == "slstm":
        y = xlstm_mod.slstm_forward(p, x, cfg)
    else:
        raise ValueError(kind)
    return h + y, aux


def _remat_wrap(fn, policy: str):
    if policy == "none":
        return fn
    pol = {
        "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        "full": jax.checkpoint_policies.nothing_saveable,
    }[policy]
    return jax.checkpoint(fn, policy=pol, prevent_cse=False)


def forward(
    params: Params, tokens: jax.Array, cfg: ModelConfig, *,
    cond: jax.Array | None = None, q_chunk: int = 512, remat: str = "dots",
) -> tuple[jax.Array, jax.Array]:
    """tokens (B, S) [+ cond (B, Sc, D)] → (h_final (B, S_tot, D), aux_loss)."""
    B, S = tokens.shape
    h = jnp.take(params["embed"], tokens, axis=0)
    h = h * jnp.sqrt(jnp.asarray(cfg.d_model, h.dtype))
    if cond is not None:
        h = jnp.concatenate([cond.astype(h.dtype), h], axis=1)
    h = constrain(h, "dp", None, None, policy=cfg.sharding_policy)  # batch on DP axes
    S_tot = h.shape[1]
    sin, cos = rope_table(jnp.arange(S_tot), cfg.head_dim, cfg.rope_theta)
    shared = params["shared"]

    def superblock(carry, sb_params):
        h, aux = carry
        h = constrain(h, "dp", None, None, policy=cfg.sharding_policy)  # pin scan carry
        for i, kind in enumerate(cfg.pattern):
            bp = sb_params.get(f"pos{i}")
            h, aux = _block_forward(kind, bp, shared, h, cfg, sin, cos, aux, q_chunk)
        return (h, aux), None

    body = _remat_wrap(superblock, remat)
    (h, aux), _ = jax.lax.scan(
        body, (h, jnp.zeros((), jnp.float32)), params["blocks"]
    )
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return h, aux


def output_embedding(params: Params) -> jax.Array:
    return params.get("lm_head", params["embed"])


def loss_fn(
    params: Params, tokens: jax.Array, labels: jax.Array, cfg: ModelConfig, *,
    cond: jax.Array | None = None, q_chunk: int = 512, remat: str = "dots",
) -> tuple[jax.Array, dict]:
    h, aux = forward(params, tokens, cfg, cond=cond, q_chunk=q_chunk, remat=remat)
    B, S = tokens.shape
    if cond is not None:
        # loss only on the token (non-conditioning) positions
        Sc = cond.shape[1]
        mask = jnp.concatenate(
            [jnp.zeros((B, Sc), jnp.float32), jnp.ones((B, S), jnp.float32)], axis=1
        )
        labels_full = jnp.concatenate([jnp.zeros((B, Sc), labels.dtype), labels], axis=1)
    else:
        mask, labels_full = jnp.ones((B, S), jnp.float32), labels
    xent, count = chunked_xent(h, output_embedding(params), labels_full, mask)
    aux_w = cfg.moe.aux_loss_weight if cfg.moe else 0.0
    loss = xent + aux_w * aux
    return loss, {"xent": xent, "aux": aux, "tokens": count}


def _head_logits(h_last: jax.Array, emb: jax.Array) -> jax.Array:
    """(B, D) @ (V, D)ᵀ → (B, V) f32. bf16 operands with f32 accumulation:
    `emb.T.astype(f32)` would materialize a full-vocab f32 weight copy (2.5 GB
    for qwen1.5-110b) on every decode step."""
    return jnp.einsum("bd,vd->bv", h_last, emb,
                      preferred_element_type=jnp.float32)


def prefill(
    params: Params, tokens: jax.Array, cfg: ModelConfig, *,
    cond: jax.Array | None = None, q_chunk: int = 512,
) -> jax.Array:
    """Prefill pass → last-position logits (B, V)."""
    h, _ = forward(params, tokens, cfg, cond=cond, q_chunk=q_chunk, remat="none")
    return _head_logits(h[:, -1], output_embedding(params))


# --------------------------------------------------------------------------- #
# Decode with per-block caches
# --------------------------------------------------------------------------- #

class DecodeCache(NamedTuple):
    blocks: PyTree        # {pos{i}: state stacked over superblocks}


def _init_block_cache(kind, cfg: ModelConfig, batch, max_len, dtype, use_sketch):
    if kind in ("attn", "attn_shared"):
        if use_sketch:
            # AccumSketch-compressed cache (paper technique): O(d_slots) memory.
            # Honors the caller's dtype for k_sum/v_sum (the seed hardcoded
            # f32 — 2× the memory the config asked for); mass stays f32 inside
            # init_sketch_cache regardless.
            return att.init_attn_sketch_cache(cfg, batch, dtype)
        return att.init_kv_cache(cfg, batch, max_len, dtype)
    if kind == "attn_local":
        return att.init_kv_cache(cfg, batch, min(max_len, cfg.window), dtype)
    if kind == "mamba2":
        return ssm_mod.init_ssm_state(cfg, batch, dtype)
    if kind == "mlstm":
        return xlstm_mod.init_mlstm_state(cfg, batch)
    if kind == "slstm":
        return xlstm_mod.init_slstm_state(cfg, batch)
    raise ValueError(kind)


def init_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16, *,
    use_sketch: bool = False,
) -> DecodeCache:
    """use_sketch=True → attention caches are AccumSketch-compressed (paper
    technique): O(d_slots) memory per layer instead of O(max_len)."""
    blocks = {}
    for i, kind in enumerate(cfg.pattern):
        one = _init_block_cache(kind, cfg, batch, max_len, dtype, use_sketch)
        blocks[f"pos{i}"] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_superblocks,) + x.shape), one
        )
    return DecodeCache(blocks)


def _block_decode(kind, bp, shared, h, state, cfg, sin_t, cos_t, pos, slots, use_sketch):
    eps = cfg.norm_eps
    if kind in ("attn", "attn_local", "attn_shared"):
        p = shared if kind == "attn_shared" else bp
        x = rmsnorm(h, p["attn"]["norm"], eps)
        if isinstance(state, SketchCache):
            y, state = att.attn_decode_sketched(p["attn"], x, state, cfg, sin_t, cos_t, slots)
        elif kind == "attn_local":
            # ring-buffer sliding-window cache: write at pos % window
            y, state = att.attn_decode(
                p["attn"], x, state, pos, cfg, sin_t, cos_t,
                write_pos=pos % state.k.shape[1],
            )
        else:
            y, state = att.attn_decode(p["attn"], x, state, pos, cfg, sin_t, cos_t)
        h = h + y
        if "ffn" in p:
            x = rmsnorm(h, p["ffn"]["norm"], eps)
            if cfg.ffn == "moe" and kind != "attn_shared":
                y, _ = moe_mod.moe_forward(p["ffn"], x, cfg.moe)
            else:
                y = ffn_mod.ffn_forward(p["ffn"], x)
            h = h + y
        return h, state
    p = bp["mixer"]
    x = rmsnorm(h, p["norm"], eps)
    if kind == "mamba2":
        y, state = ssm_mod.mamba2_decode(p, x, state, cfg)
    elif kind == "mlstm":
        y, state = xlstm_mod.mlstm_decode(p, x, state, cfg)
    elif kind == "slstm":
        y, state = xlstm_mod.slstm_decode(p, x, state, cfg)
    else:
        raise ValueError(kind)
    return h + y, state


def _block_prefill(kind, bp, shared, h, state, cfg, sin, cos, slot_table, q_chunk):
    """One block's batched prefill: full-sequence forward + cache state as if
    the L tokens had been decoded one by one (see `prefill_with_cache`)."""
    eps = cfg.norm_eps
    if kind in ("attn", "attn_local", "attn_shared"):
        p = shared if kind == "attn_shared" else bp
        x = rmsnorm(h, p["attn"]["norm"], eps)
        if isinstance(state, SketchCache):
            y, state = att.attn_prefill_sketched(
                p["attn"], x, state, cfg, sin, cos, slot_table
            )
        else:
            window = cfg.window if kind == "attn_local" else None
            y, state = att.attn_prefill(
                p["attn"], x, state, cfg, sin, cos, window=window, q_chunk=q_chunk
            )
        h = h + y
        if "ffn" in p:
            x = rmsnorm(h, p["ffn"]["norm"], eps)
            if cfg.ffn == "moe" and kind != "attn_shared":
                y, _ = moe_mod.moe_forward(p["ffn"], x, cfg.moe)
            else:
                y = ffn_mod.ffn_forward(p["ffn"], x)
            h = h + y
        return h, state
    # recurrent mixers have per-token decode transitions only — run them as an
    # inner scan over tokens (still ONE dispatch; the sequential dependence is
    # inherent to the state recurrence, not a Python-loop artifact)
    p = bp["mixer"]
    x = rmsnorm(h, p["norm"], eps)
    decode_fn = {
        "mamba2": ssm_mod.mamba2_decode,
        "mlstm": xlstm_mod.mlstm_decode,
        "slstm": xlstm_mod.slstm_decode,
    }[kind]

    def tok(st, x_t):
        y, st = decode_fn(p, x_t[:, None], st, cfg)
        return st, y[:, 0]

    state, ys = jax.lax.scan(tok, state, x.swapaxes(0, 1))
    return h + ys.swapaxes(0, 1), state


def prefill_with_cache(
    params: Params, tokens: jax.Array, cfg: ModelConfig, cache: DecodeCache, *,
    slot_table: jax.Array | None = None, q_chunk: int = 512,
) -> tuple[jax.Array, DecodeCache]:
    """Batched prefill: consume all L prompt tokens in ONE dispatch and return
    (last-position logits (B, V), updated DecodeCache) — the state the
    sequential decode loop would reach after positions 0..L-1, at chunked
    `forward` cost instead of L jitted dispatches.

    Exact caches get a bulk KV write, sketched caches one vectorized
    segment-sum scatter (bitwise-identical to the token loop's cache);
    `slot_table` (L, m_r) from `decode_slot_table` is required when the cache
    contains SketchCache states."""
    B, L = tokens.shape
    h = jnp.take(params["embed"], tokens, axis=0)
    h = h * jnp.sqrt(jnp.asarray(cfg.d_model, h.dtype))
    h = constrain(h, "dp", None, None, policy=cfg.sharding_policy)
    sin, cos = rope_table(jnp.arange(L), cfg.head_dim, cfg.rope_theta)
    shared = params["shared"]

    def superblock(h, xs):
        sb_params, sb_cache = xs
        h = constrain(h, "dp", None, None, policy=cfg.sharding_policy)
        new_states = {}
        for i, kind in enumerate(cfg.pattern):
            bp = sb_params.get(f"pos{i}")
            h, st = _block_prefill(
                kind, bp, shared, h, sb_cache[f"pos{i}"], cfg, sin, cos,
                slot_table, q_chunk,
            )
            new_states[f"pos{i}"] = st
        return h, new_states

    h, new_blocks = jax.lax.scan(superblock, h, (params["blocks"], cache.blocks))
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = _head_logits(h[:, -1], output_embedding(params))
    return logits, DecodeCache(new_blocks)


def decode_step(
    params: Params, cache: DecodeCache, token_t: jax.Array, pos: jax.Array,
    cfg: ModelConfig, *, slots: jax.Array | None = None, use_sketch: bool = False,
) -> tuple[jax.Array, DecodeCache]:
    """One decoding step. token_t: (B,) int32; pos: scalar int32 (current index).

    Returns (logits (B, V), updated cache). The scan mirrors forward()."""
    h = jnp.take(params["embed"], token_t[:, None], axis=0)
    h = h * jnp.sqrt(jnp.asarray(cfg.d_model, h.dtype))
    h = constrain(h, "dp", None, None, policy=cfg.sharding_policy)
    sin_t, cos_t = rope_table(pos[None], cfg.head_dim, cfg.rope_theta)
    shared = params["shared"]

    def superblock(h, xs):
        sb_params, sb_cache = xs
        new_states = {}
        for i, kind in enumerate(cfg.pattern):
            bp = sb_params.get(f"pos{i}")
            h, st = _block_decode(
                kind, bp, shared, h, sb_cache[f"pos{i}"], cfg, sin_t, cos_t,
                pos, slots, use_sketch,
            )
            new_states[f"pos{i}"] = st
        return h, new_states

    h, new_blocks = jax.lax.scan(superblock, h, (params["blocks"], cache.blocks))
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = _head_logits(h[:, 0], output_embedding(params))
    return logits, DecodeCache(new_blocks)
