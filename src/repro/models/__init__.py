from repro.models.model import (
    DecodeCache,
    active_param_count,
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    output_embedding,
    param_count,
    prefill,
)

__all__ = [n for n in dir() if not n.startswith("_")]
