"""Mixture-of-Experts FFN: top-k routing with sort-based capacity dispatch.

Sort-based (MegaBlocks-flavoured) dispatch keeps memory at O(T·k + E·C·D)
instead of the O(T·E·C) one-hot combine tensor, which matters at the 65k
tokens/device of the production shapes. Expert compute is a single batched
einsum over the (E, C, D) buffer → EP-shards cleanly over the `model` axis.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro.configs.base import MoECfg
from repro.models.common import dense_init
from repro.models.ffn import ffn_forward, init_ffn


class MoEMetrics(NamedTuple):
    aux_loss: jax.Array
    dropped_fraction: jax.Array


def init_moe(key, d_model: int, moe: MoECfg):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    E, F = moe.n_experts, moe.d_ff_expert
    p = {
        "router": dense_init(k1, (d_model, E), dtype=jnp.float32),
        "wi_gate": dense_init(k2, (E, d_model, F)),
        "wi_up": dense_init(k3, (E, d_model, F)),
        "wo": dense_init(k4, (E, F, d_model)),
        "norm": jnp.zeros((d_model,), jnp.float32),
    }
    if moe.dense_residual:
        p["dense"] = init_ffn(k5, d_model, moe.d_ff_dense)
    return p


def capacity(n_tokens: int, moe: MoECfg) -> int:
    c = int(n_tokens * moe.top_k * moe.capacity_factor / moe.n_experts)
    return max(c, 4)


def _dispatch(x, router, moe: MoECfg, C: int):
    """Sort-based dispatch of local tokens into an (E, C, D) buffer.
    Returns (xe, combine info). No cross-device communication."""
    T, D = x.shape
    E, K = moe.n_experts, moe.top_k
    logits = (x.astype(jnp.float32) @ router)                          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, tope = jax.lax.top_k(probs, K)                               # (T, K)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

    e_f = tope.reshape(-1)                                             # (T·K,)
    w_f = topw.reshape(-1)
    tok_f = jnp.repeat(jnp.arange(T), K)
    order = jnp.argsort(e_f, stable=True)
    e_s, w_s, tok_s = e_f[order], w_f[order], tok_f[order]
    counts = jnp.bincount(e_f, length=E)                               # (E,)
    starts = jnp.cumsum(counts) - counts
    pos_s = jnp.arange(T * K) - starts[e_s]                            # rank within expert
    keep = (pos_s < C).astype(jnp.float32)
    slot = e_s * C + jnp.minimum(pos_s, C - 1)                         # (T·K,)

    buf = jnp.zeros((E * C, D), x.dtype)
    buf = buf.at[slot].add(x[tok_s] * keep[:, None].astype(x.dtype))
    xe = buf.reshape(E, C, D)
    return xe, (slot, tok_s, keep, w_s, tope, probs)


def _combine(ye_flat, info, T, dtype):
    slot, tok_s, keep, w_s, _, _ = info
    y_s = ye_flat[slot] * (keep * w_s)[:, None].astype(dtype)
    return jnp.zeros((T, ye_flat.shape[-1]), dtype).at[tok_s].add(y_s)


def _experts(xe, wig, wiu, wo, dtype):
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wig).astype(jnp.float32))
    u = jnp.einsum("ecd,edf->ecf", xe, wiu)
    return jnp.einsum("ecf,efd->ecd", g.astype(dtype) * u, wo)


def _metrics(info, E, T, K):
    _, _, keep, _, tope, probs = info
    frac_tokens = jnp.mean(jax.nn.one_hot(tope[:, 0], E, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs)
    dropped = 1.0 - jnp.sum(keep) / (T * K)
    return aux, dropped


def moe_forward_local(p, h: jax.Array, moe: MoECfg) -> tuple[jax.Array, MoEMetrics]:
    """Single-device (or fully replicated) path: dispatch over all T tokens."""
    B, S, D = h.shape
    T = B * S
    x = h.reshape(T, D)
    xe, info = _dispatch(x, p["router"], moe, capacity(T, moe))
    ye = _experts(xe, p["wi_gate"], p["wi_up"], p["wo"], h.dtype)
    out = _combine(ye.reshape(-1, D), info, T, h.dtype)
    if moe.dense_residual:
        out = out + ffn_forward(p["dense"], x)
    aux, dropped = _metrics(info, moe.n_experts, T, moe.top_k)
    return out.reshape(B, S, D), MoEMetrics(aux, dropped)


def _moe_forward_a2a(p, h: jax.Array, moe: MoECfg, mesh, dp, ep: str):
    """Production EP path (GShard/DeepSpeed-MoE pattern), shard_mapped:

      local dispatch → all_to_all over the expert axis → expert GEMMs →
      all_to_all back → local combine.

    Why not plain pjit: the sort-based dispatch scatters with data-dependent
    indices over the dp-sharded token axis, which SPMD can only realize by
    replicating the operands — measured 70%+ of arctic-480b/train_4k's
    collective bytes as per-layer all-reduces of (T·K, D) and dispatch-mask
    tensors. Tokens never need to leave their data shard: only the (E, C, D)
    expert buffer crosses chips, and only over the `model` (EP) axis.

    FSDP composition: expert weights arrive (E_loc, D/|dp|, F)-sharded; they
    are all-gathered over dp here (ZeRO-3 gather, transposed by autodiff into
    a reduce-scatter of the grads) so each data shard contracts its own
    tokens against full-D weights."""
    # jax.shard_map only exists on newer jax; 0.4.x ships it in experimental
    shard_map = getattr(jax, "shard_map", None)
    if shard_map is None:
        from jax.experimental.shard_map import shard_map

    B, S, D = h.shape
    E, K = moe.n_experts, moe.top_k
    M = mesh.shape[ep]
    E_loc = E // M
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    T_loc = (B // dp_size) * S
    # h is REPLICATED over the model axis: each model shard must dispatch a
    # DISJOINT 1/M slice of the local tokens, or every expert receives M
    # identical copies and the expert GEMMs run M× redundantly (measured: 8×
    # per-chip FLOPs before this slice). This also spreads router+dispatch
    # work over the model axis (sequence-parallel dispatch).
    T_chunk = T_loc // M
    C_loc = capacity(T_chunk, moe)
    P_ = PartitionSpec

    def body(x, router, wig, wiu, wo):
        x = x.reshape(T_loc, D)
        j = jax.lax.axis_index(ep)
        x = jax.lax.dynamic_slice_in_dim(x, j * T_chunk, T_chunk)
        xe, info = _dispatch(x, router, moe, C_loc)          # (E, C_loc, D)
        # dispatch a2a: (M·E_loc, C_loc, D) → (E_loc, M·C_loc, D)
        # (symmetric split/concat axes — the transpose of a2a(0,0) is itself,
        # which keeps the VJP shapes aligned)
        xe = xe.reshape(M, E_loc, C_loc, D)
        xe = jax.lax.all_to_all(xe, ep, split_axis=0, concat_axis=0)
        xe = xe.transpose(1, 0, 2, 3).reshape(E_loc, M * C_loc, D)
        # ZeRO-3 weight gather over dp (grads reduce-scatter automatically);
        # explicitly bf16 on the wire — gathering in f32 doubles the bytes
        if dp:
            bf = jnp.bfloat16
            wig = jax.lax.all_gather(wig.astype(bf), dp, axis=1, tiled=True)
            wiu = jax.lax.all_gather(wiu.astype(bf), dp, axis=1, tiled=True)
            wo = jax.lax.all_gather(wo.astype(bf), dp, axis=2, tiled=True)
        ye = _experts(xe, wig, wiu, wo, x.dtype)             # (E_loc, M·C_loc, D)
        # combine a2a: back to (E, C_loc, D) on the source shard
        ye = ye.reshape(E_loc, M, C_loc, D).transpose(1, 0, 2, 3)
        ye = jax.lax.all_to_all(ye, ep, split_axis=0, concat_axis=0)
        out = _combine(ye.reshape(E * C_loc, D), info, T_chunk, x.dtype)
        # restore the replicated-over-model activation layout
        out = jax.lax.all_gather(out, ep, axis=0, tiled=True)   # (T_loc, D)
        aux, dropped = _metrics(info, E, T_chunk, K)
        aux = jax.lax.pmean(aux, tuple(dp) + (ep,))
        dropped = jax.lax.pmean(dropped, tuple(dp) + (ep,))
        return out.reshape(B // dp_size, S, D), aux, dropped

    dp_spec = dp if len(dp) != 1 else dp[0]
    # newer jax renamed check_rep → check_vma; support both
    import inspect
    _chk = ("check_vma" if "check_vma" in inspect.signature(shard_map).parameters
            else "check_rep")
    out, aux, dropped = shard_map(
        body, mesh=mesh,
        in_specs=(
            P_(dp_spec, None, None),              # h: batch over dp
            P_(None, None),                       # router: replicated
            P_(ep, dp_spec, None),                # wi_gate (E, D, F)
            P_(ep, dp_spec, None),                # wi_up
            P_(ep, None, dp_spec),                # wo (E, F, D)
        ),
        out_specs=(P_(dp_spec, None, None), P_(), P_()),
        **{_chk: False},
    )(h, p["router"].astype(jnp.float32), p["wi_gate"], p["wi_up"], p["wo"])
    return out, aux, dropped


def moe_forward(p, h: jax.Array, moe: MoECfg) -> tuple[jax.Array, MoEMetrics]:
    """h: (B, S, D) → (B, S, D). Capacity-dropped tokens pass through (residual).

    Uses the a2a expert-parallel path when running under a mesh with a
    non-trivial `model` axis and divisible shapes; otherwise the local path."""
    from repro.sharding import _current_mesh, data_axes

    B, S, D = h.shape
    mesh = _current_mesh()
    use_a2a = False
    if mesh is not None and "model" in mesh.shape and mesh.shape["model"] > 1:
        M = mesh.shape["model"]
        dp = tuple(a for a in data_axes(mesh) if mesh.shape[a] > 1)
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]
        use_a2a = (moe.n_experts % M == 0 and B % max(dp_size, 1) == 0
                   and D % max(dp_size, 1) == 0
                   and ((B // dp_size) * S) % M == 0)
    if use_a2a:
        out, aux, dropped = _moe_forward_a2a(p, h, moe, mesh, dp, "model")
        if moe.dense_residual:
            out = out + ffn_forward(p["dense"], h.reshape(B * S, D)).reshape(B, S, D)
        return out, MoEMetrics(aux, dropped)
    return moe_forward_local(p, h, moe)
