"""Mamba-2 block (SSD — state space dual), chunked-parallel training form and
single-step recurrent decode form. Follows the minimal-SSD formulation:

  h_t = exp(dt_t·A) h_{t-1} + dt_t · B_t ⊗ x_t ,   y_t = C_t · h_t + D ⊙ x_t

Training scans over length-Q chunks (intra-chunk parallel, inter-chunk scan),
so compute is O(L·Q) with O(L/Q) sequential steps.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, rmsnorm


class SSMState(NamedTuple):
    conv: jax.Array   # (B, W-1, d_conv_ch) rolling conv inputs
    ssm: jax.Array    # (B, H, P, N) recurrent state


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    return d_inner, H, s.head_dim, s.d_state


def init_mamba2(key, cfg: ModelConfig):
    s = cfg.ssm
    d_inner, H, P, N = _dims(cfg)
    d_conv_ch = d_inner + 2 * N
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (cfg.d_model, 2 * d_inner + 2 * N + H)),
        "conv_w": dense_init(ks[1], (s.conv_width, d_conv_ch)),
        "conv_b": jnp.zeros((d_conv_ch,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),          # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "gate_norm": jnp.zeros((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[2], (d_inner, cfg.d_model)),
        "norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }


def _split_proj(p, h, cfg: ModelConfig):
    d_inner, H, P, N = _dims(cfg)
    zxbcdt = h @ p["in_proj"]
    z, xBC, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * N], axis=-1)
    return z, xBC, dt


def _causal_conv(p, xBC, cfg: ModelConfig):
    """Depthwise causal conv along L. xBC: (B, L, Cch)."""
    W = cfg.ssm.conv_width
    pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xBC.shape[1], :] * p["conv_w"][i].astype(xBC.dtype)
        for i in range(W)
    )
    return jax.nn.silu((out + p["conv_b"].astype(xBC.dtype)).astype(jnp.float32)).astype(xBC.dtype)


def mamba2_forward(p, h: jax.Array, cfg: ModelConfig) -> jax.Array:
    """(B, L, D) → (B, L, D); L must be divisible by the chunk length."""
    B, L, D = h.shape
    d_inner, H, P, N = _dims(cfg)
    Q = min(cfg.ssm.chunk, L)
    nc = L // Q
    f32 = jnp.float32

    z, xBC, dt = _split_proj(p, h, cfg)
    xBC = _causal_conv(p, xBC, cfg)
    x, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + N], axis=-1)
    x = x.reshape(B, L, H, P)
    dt = jax.nn.softplus(dt.astype(f32) + p["dt_bias"])             # (B, L, H)
    A = -jnp.exp(p["A_log"])                                        # (H,)

    # chunked SSD
    dA = (dt * A).reshape(B, nc, Q, H)                              # (B,c,q,H) f32
    dA_cs = jnp.cumsum(dA, axis=2)                                  # within-chunk cumsum
    dA_sum = dA_cs[:, :, -1, :]                                     # (B,c,H)
    xdt = (x.astype(f32) * dt[..., None]).reshape(B, nc, Q, H, P)
    Bc = Bm.astype(f32).reshape(B, nc, Q, N)
    Cc = Cm.astype(f32).reshape(B, nc, Q, N)

    # intra-chunk (diagonal blocks): Y_ii
    seg = dA_cs[:, :, :, None, :] - dA_cs[:, :, None, :, :]         # (B,c,i,j,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)                  # (B,c,i,j)
    y_diag = jnp.einsum("bcij,bcijh,bcjhp->bcihp", scores, decay, xdt)

    # chunk states and inter-chunk scan
    decay_out = jnp.exp(dA_sum[:, :, None, :] - dA_cs)              # (B,c,j,H)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", Bc, decay_out, xdt)  # (B,c,H,P,N)

    def scan_body(s_prev, inp):
        st, dec = inp                                               # (B,H,P,N), (B,H)
        s_new = s_prev * jnp.exp(dec)[:, :, None, None] + st
        return s_new, s_prev

    init = jnp.zeros((B, H, P, N), f32)
    _, s_prevs = jax.lax.scan(
        scan_body, init,
        (states.transpose(1, 0, 2, 3, 4), dA_sum.transpose(1, 0, 2)),
    )
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)                      # (B,c,H,P,N)

    y_off = jnp.einsum("bcin,bchpn,bcih->bcihp", Cc, s_prevs, jnp.exp(dA_cs))
    y = (y_diag + y_off).reshape(B, L, H, P) + x.astype(f32) * p["D"][None, None, :, None]

    y = y.reshape(B, L, d_inner)
    gated = y * jax.nn.silu(z.astype(f32))
    y = rmsnorm(gated.astype(h.dtype), p["gate_norm"], cfg.norm_eps)
    return y @ p["out_proj"]


# --------------------------------------------------------------------------- #
# Decode
# --------------------------------------------------------------------------- #

def init_ssm_state(cfg: ModelConfig, batch: int, dtype) -> SSMState:
    d_inner, H, P, N = _dims(cfg)
    W = cfg.ssm.conv_width
    return SSMState(
        conv=jnp.zeros((batch, W - 1, d_inner + 2 * N), dtype),
        ssm=jnp.zeros((batch, H, P, N), jnp.float32),
    )


def mamba2_decode(p, h_t: jax.Array, state: SSMState, cfg: ModelConfig):
    """One-token recurrent step. h_t: (B, 1, D)."""
    B = h_t.shape[0]
    d_inner, H, P, N = _dims(cfg)
    f32 = jnp.float32

    z, xBC, dt = _split_proj(p, h_t, cfg)                           # (B,1,·)
    window = jnp.concatenate([state.conv, xBC.astype(state.conv.dtype)], axis=1)  # (B,W,Cch)
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(f32), p["conv_w"].astype(f32))
    xBC_t = jax.nn.silu(conv_out + p["conv_b"])                     # (B,Cch) f32
    new_conv = window[:, 1:, :]

    x, Bv, Cv = jnp.split(xBC_t, [d_inner, d_inner + N], axis=-1)
    x = x.reshape(B, H, P)
    dtv = jax.nn.softplus(dt[:, 0].astype(f32) + p["dt_bias"])      # (B,H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dtv * A)                                           # (B,H)
    s = state.ssm * dA[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dtv, Bv, x
    )
    y = jnp.einsum("bn,bhpn->bhp", Cv, s) + x * p["D"][None, :, None]
    y = y.reshape(B, 1, d_inner)
    gated = y * jax.nn.silu(z.astype(f32))
    y = rmsnorm(gated.astype(h_t.dtype), p["gate_norm"], cfg.norm_eps)
    return y @ p["out_proj"], SSMState(new_conv, s)
