"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory with recurrent
gate connections), both with exponential gating + max-stabilizer.

Training uses a time scan (these are the smallest assigned configs); decode is
the same recurrence at length 1 — O(1) state per token, so long_500k is native.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init


# --------------------------------------------------------------------------- #
# mLSTM
# --------------------------------------------------------------------------- #

class MLSTMState(NamedTuple):
    C: jax.Array   # (B, H, Dv, Dk) matrix memory
    n: jax.Array   # (B, H, Dk)
    m: jax.Array   # (B, H) stabilizer


def init_mlstm(key, cfg: ModelConfig):
    D, H, Dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    ks = jax.random.split(key, 7)
    return {
        "wq": dense_init(ks[0], (D, H * Dh)),
        "wk": dense_init(ks[1], (D, H * Dh)),
        "wv": dense_init(ks[2], (D, H * Dh)),
        "wi": dense_init(ks[3], (D, H), dtype=jnp.float32),
        "wf": dense_init(ks[4], (D, H), dtype=jnp.float32),
        "wog": dense_init(ks[5], (D, H * Dh)),
        "wo": dense_init(ks[6], (H * Dh, D)),
        "f_bias": jnp.full((H,), 3.0, jnp.float32),   # open forget gates at init
        "norm": jnp.zeros((D,), jnp.float32),
    }


def _mlstm_gates(p, h):
    B, L, D = h.shape
    H = p["wi"].shape[1]
    Dh = p["wq"].shape[1] // H
    q = (h @ p["wq"]).reshape(B, L, H, Dh)
    k = (h @ p["wk"]).reshape(B, L, H, Dh) / jnp.sqrt(jnp.asarray(Dh, h.dtype))
    v = (h @ p["wv"]).reshape(B, L, H, Dh)
    log_i = (h.astype(jnp.float32) @ p["wi"])                       # (B,L,H)
    log_f = jax.nn.log_sigmoid(h.astype(jnp.float32) @ p["wf"] + p["f_bias"])
    og = jax.nn.sigmoid((h @ p["wog"]).astype(jnp.float32)).reshape(B, L, H, Dh)
    return q, k, v, log_i, log_f, og


def _mlstm_step(state: MLSTMState, q, k, v, log_i, log_f, og):
    """One recurrence step; all inputs (B, H, ...) f32."""
    m_new = jnp.maximum(log_f + state.m, log_i)
    i_p = jnp.exp(log_i - m_new)
    f_p = jnp.exp(log_f + state.m - m_new)
    C = state.C * f_p[..., None, None] + i_p[..., None, None] * (
        v[..., :, None] * k[..., None, :]
    )
    n = state.n * f_p[..., None] + i_p[..., None] * k
    num = jnp.einsum("bhvk,bhk->bhv", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), jnp.exp(-m_new))
    y = og * num / den[..., None]
    return MLSTMState(C, n, m_new), y


def mlstm_forward(p, h: jax.Array, cfg: ModelConfig, *, chunk: int = 64) -> jax.Array:
    """Chunkwise-parallel mLSTM (exact, stabilized).

    The per-timestep recurrence costs O(L) scan steps each carrying the
    (B,H,Dv,Dk) matrix memory through HBM; the chunkwise form (the SSD/GLA
    construction adapted to mLSTM's exp-gating + max-stabilizer) scans L/Q
    chunks and handles the Q intra-chunk positions with masked GEMMs — MXU
    work instead of carry traffic, a Q× cut of the dominant memory term
    (EXPERIMENTS.md §Perf A4).

    Stabilizer algebra: with b_τ = Σ_{s≤τ} lf_s, a_s = li_s − b_s and
    w_τ = max(m_prev, cummax_τ(a)), every within-chunk weight collapses to
      intra: exp(a_s − w_τ)·(q_τ·k_s)   inter: exp(m_prev − w_τ)·(q_τ·C_prev)
    (the b_τ cancel), and the per-position stabilizer is M_τ = b_τ + w_τ —
    bit-for-bit the running max of the sequential rule."""
    B, L, D = h.shape
    H, Dh = cfg.n_heads, cfg.head_dim
    q, k, v, log_i, log_f, og = _mlstm_gates(p, h)
    f32 = jnp.float32
    Q = min(chunk, L)
    if L % Q != 0:   # fall back to the sequential scan on ragged lengths
        return _mlstm_forward_seq(p, h, cfg)
    G = L // Q

    def to_chunks(x, feat):  # (B, L, H[, Dh]) → (G, B, Q, H[, Dh])
        shp = (B, G, Q, H) + ((Dh,) if feat else ())
        return x.astype(f32).reshape(shp).transpose(1, 0, 2, 3, *range(4, 4 + feat))

    qc, kc, vc = to_chunks(q, 1), to_chunks(k, 1), to_chunks(v, 1)
    lic, lfc = to_chunks(log_i, 0), to_chunks(log_f, 0)
    init = MLSTMState(
        jnp.zeros((B, H, Dh, Dh), f32), jnp.zeros((B, H, Dh), f32),
        jnp.full((B, H), -1e30, f32),
    )
    mask = jnp.tril(jnp.ones((Q, Q), bool))             # s ≤ τ

    def body(st, x):
        qt, kt, vt, li, lf = x                          # (B,Q,H,·)
        b = jnp.cumsum(lf, axis=1)                      # (B,Q,H) inclusive
        a = li - b
        w = jnp.maximum(st.m[:, None, :], jax.lax.cummax(a, axis=1))  # (B,Q,H)
        inter = jnp.exp(st.m[:, None, :] - w)           # (B,Q,H)
        src = jnp.exp(a[:, None, :, :] - w[:, :, None, :])            # (B,τ,s,H)
        src = jnp.where(mask[None, :, :, None], src, 0.0)
        scores = jnp.einsum("bqhd,bshd->bqsh", qt, kt) * src
        num = (jnp.einsum("bqsh,bshd->bqhd", scores, vt)
               + inter[..., None] * jnp.einsum("bqhd,bhvd->bqhv", qt, st.C))
        den = (jnp.sum(scores, axis=2)
               + inter * jnp.einsum("bqhd,bhd->bqh", qt, st.n))
        guard = jnp.exp(-(b + w))                       # exp(−M_τ)
        y = num / jnp.maximum(jnp.abs(den), guard)[..., None]
        # chunk-end state update (τ = Q)
        wQ = w[:, -1]                                   # (B,H)
        dec = jnp.exp(st.m - wQ)
        upd = jnp.exp(a - wQ[:, None, :])               # (B,Q,H)
        C = st.C * dec[..., None, None] + jnp.einsum("bqhv,bqhd,bqh->bhvd", vt, kt, upd)
        n = st.n * dec[..., None] + jnp.einsum("bqhd,bqh->bhd", kt, upd)
        m_new = b[:, -1] + wQ
        return MLSTMState(C, n, m_new), y

    _, ys = jax.lax.scan(body, init, (qc, kc, vc, lic, lfc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, L, H * Dh)
    y = (og.reshape(B, L, H, Dh) * y.reshape(B, L, H, Dh)).reshape(B, L, H * Dh)
    return y.astype(h.dtype) @ p["wo"]


def _mlstm_forward_seq(p, h: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Sequential reference recurrence (oracle for the chunkwise path)."""
    B, L, D = h.shape
    H, Dh = cfg.n_heads, cfg.head_dim
    q, k, v, log_i, log_f, og = _mlstm_gates(p, h)
    f32 = jnp.float32
    xs = (
        q.astype(f32).transpose(1, 0, 2, 3), k.astype(f32).transpose(1, 0, 2, 3),
        v.astype(f32).transpose(1, 0, 2, 3), log_i.transpose(1, 0, 2),
        log_f.transpose(1, 0, 2), og.transpose(1, 0, 2, 3),
    )
    init = MLSTMState(
        jnp.zeros((B, H, Dh, Dh), f32), jnp.zeros((B, H, Dh), f32),
        jnp.full((B, H), -1e30, f32),
    )

    def body(st, x):
        st, y = _mlstm_step(st, *x)
        return st, y

    _, ys = jax.lax.scan(body, init, xs)
    y = ys.transpose(1, 0, 2, 3).reshape(B, L, H * Dh)
    return y.astype(h.dtype) @ p["wo"]


def init_mlstm_state(cfg: ModelConfig, batch: int) -> MLSTMState:
    H, Dh = cfg.n_heads, cfg.head_dim
    f32 = jnp.float32
    return MLSTMState(
        jnp.zeros((batch, H, Dh, Dh), f32), jnp.zeros((batch, H, Dh), f32),
        jnp.full((batch, H), -1e30, f32),
    )


def mlstm_decode(p, h_t: jax.Array, state: MLSTMState, cfg: ModelConfig):
    q, k, v, log_i, log_f, og = _mlstm_gates(p, h_t)                # L = 1
    f32 = jnp.float32
    state, y = _mlstm_step(
        state, q[:, 0].astype(f32), k[:, 0].astype(f32), v[:, 0].astype(f32),
        log_i[:, 0], log_f[:, 0], og[:, 0],
    )
    B = h_t.shape[0]
    y = y.reshape(B, 1, cfg.n_heads * cfg.head_dim)
    return y.astype(h_t.dtype) @ p["wo"], state


# --------------------------------------------------------------------------- #
# sLSTM
# --------------------------------------------------------------------------- #

class SLSTMState(NamedTuple):
    c: jax.Array   # (B, H, Dh)
    n: jax.Array
    hst: jax.Array
    m: jax.Array


def init_slstm(key, cfg: ModelConfig):
    D, H, Dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    ks = jax.random.split(key, 9)
    p = {
        "wz": dense_init(ks[0], (D, H * Dh)),
        "wi": dense_init(ks[1], (D, H * Dh), dtype=jnp.float32),
        "wf": dense_init(ks[2], (D, H * Dh), dtype=jnp.float32),
        "wog": dense_init(ks[3], (D, H * Dh)),
        "rz": dense_init(ks[4], (H, Dh, Dh), in_axis=1, dtype=jnp.float32),
        "ri": dense_init(ks[5], (H, Dh, Dh), in_axis=1, dtype=jnp.float32),
        "rf": dense_init(ks[6], (H, Dh, Dh), in_axis=1, dtype=jnp.float32),
        "rog": dense_init(ks[7], (H, Dh, Dh), in_axis=1, dtype=jnp.float32),
        "wo": dense_init(ks[8], (H * Dh, D)),
        "f_bias": jnp.full((H * Dh,), 3.0, jnp.float32),
        "norm": jnp.zeros((D,), jnp.float32),
    }
    return p


def _slstm_step(p, state: SLSTMState, xz, xi, xf, xog, H, Dh):
    """xz/xi/xf/xog: (B, H·Dh) pre-activations from the input; recurrence adds
    per-head R h_{t-1}."""
    B = xz.shape[0]
    hprev = state.hst                                               # (B,H,Dh)
    rec = lambda R: jnp.einsum("bhd,hde->bhe", hprev, R).reshape(B, H * Dh)
    z = jnp.tanh(xz + rec(p["rz"]))
    log_i = xi + rec(p["ri"])
    log_f = jax.nn.log_sigmoid(xf + rec(p["rf"]) + p["f_bias"])
    o = jax.nn.sigmoid(xog + rec(p["rog"]))
    z = z.reshape(B, H, Dh)
    log_i = log_i.reshape(B, H, Dh)
    log_f = log_f.reshape(B, H, Dh)
    o = o.reshape(B, H, Dh)
    m_new = jnp.maximum(log_f + state.m, log_i)
    i_p = jnp.exp(log_i - m_new)
    f_p = jnp.exp(log_f + state.m - m_new)
    c = f_p * state.c + i_p * z
    n = jnp.maximum(f_p * state.n + i_p, jnp.exp(-m_new))
    hnew = o * c / n
    return SLSTMState(c, n, hnew, m_new), hnew


def slstm_forward(p, h: jax.Array, cfg: ModelConfig) -> jax.Array:
    B, L, D = h.shape
    H, Dh = cfg.n_heads, cfg.head_dim
    f32 = jnp.float32
    xz = (h @ p["wz"]).astype(f32).transpose(1, 0, 2)
    xi = (h.astype(f32) @ p["wi"]).transpose(1, 0, 2)
    xf = (h.astype(f32) @ p["wf"]).transpose(1, 0, 2)
    xog = (h @ p["wog"]).astype(f32).transpose(1, 0, 2)
    R = (p["rz"], p["ri"], p["rf"], p["rog"])
    ys = _slstm_scan(R, p["f_bias"], xz, xi, xf, xog)       # (L, B, H, Dh)
    y = ys.transpose(1, 0, 2, 3).reshape(B, L, H * Dh)
    return y.astype(h.dtype) @ p["wo"]


# --------------------------------------------------------------------------- #
# sLSTM time scan with a one-GEMM weight-gradient backward.
#
# Why custom_vjp: under data parallelism the naive autodiff of the scan
# accumulates dL/dR (R replicated, batch sharded) in the backward carry — SPMD
# must materialize the replicated accumulator every step, i.e. one tuple
# all-reduce of (H,Dh,Dh)×4 PER TIMESTEP (measured: 96% of all collective
# bytes on the 16×16 mesh for xlstm-125m/train_4k). Here the backward scan
# instead EMITS the per-step pre-activation gradients as stacked outputs and
# computes dR_g = Σ_t h_{t−1} ⊗ dpre_g,t as one einsum over the (L,B) axes
# after the scan — a single large GEMM and a single all-reduce.
#
# The stabilizer m is stop-gradient (h is invariant to m in exact arithmetic —
# the exp(−m) factors cancel between c and n — so its gradient paths sum to
# zero; stopping them is the standard xLSTM treatment and removes the kink at
# the max switch).
# --------------------------------------------------------------------------- #

def _slstm_gates(R, f_bias, xz, xi, xf, xog, hprev, H, Dh):
    """Vectorized gate math for one step (or a whole stacked batch of steps).
    hprev: (..., H, Dh); x*: (..., H·Dh). Returns f32 gate tensors (..., H, Dh)."""
    Rz, Ri, Rf, Rog = R
    rec = lambda Rm: jnp.einsum("...hd,hde->...he", hprev, Rm)
    shp = hprev.shape
    pre_z = xz.reshape(shp) + rec(Rz)
    li = xi.reshape(shp) + rec(Ri)
    pf = xf.reshape(shp) + rec(Rf) + f_bias.reshape(H, Dh)
    pre_o = xog.reshape(shp) + rec(Rog)
    return pre_z, li, pf, pre_o


def _slstm_scan_fwd_core(R, f_bias, xz, xi, xf, xog):
    """Returns ys plus the (h, c, n, m) stacks needed for the backward pass."""
    L, B = xz.shape[0], xz.shape[1]
    H, Dh = R[0].shape[0], R[0].shape[1]
    f32 = jnp.float32
    init = SLSTMState(
        jnp.zeros((B, H, Dh), f32), jnp.zeros((B, H, Dh), f32),
        jnp.zeros((B, H, Dh), f32), jnp.full((B, H, Dh), -1e30, f32),
    )

    def body(st, x):
        xz_t, xi_t, xf_t, xog_t = x
        pre_z, li, pf, pre_o = _slstm_gates(
            R, f_bias, xz_t, xi_t, xf_t, xog_t, st.hst, H, Dh)
        z = jnp.tanh(pre_z)
        lf = jax.nn.log_sigmoid(pf)
        m_new = jax.lax.stop_gradient(jnp.maximum(lf + st.m, li))
        i_p = jnp.exp(li - m_new)
        f_p = jnp.exp(lf + st.m - m_new)
        c = f_p * st.c + i_p * z
        n = jnp.maximum(f_p * st.n + i_p, jnp.exp(-m_new))
        o = jax.nn.sigmoid(pre_o)
        hnew = o * c / n
        new = SLSTMState(c, n, hnew, m_new)
        return new, (hnew, c, n, m_new)

    _, (hs, cs, ns, ms) = jax.lax.scan(body, init, (xz, xi, xf, xog))
    return hs, cs, ns, ms


@jax.custom_vjp
def _slstm_scan(R, f_bias, xz, xi, xf, xog):
    hs, _, _, _ = _slstm_scan_fwd_core(R, f_bias, xz, xi, xf, xog)
    return hs


def _slstm_scan_fwd(R, f_bias, xz, xi, xf, xog):
    hs, cs, ns, ms = _slstm_scan_fwd_core(R, f_bias, xz, xi, xf, xog)
    return hs, (R, f_bias, xz, xi, xf, xog, hs, cs, ns, ms)


def _slstm_scan_bwd(res, g_hs):
    R, f_bias, xz, xi, xf, xog, hs, cs, ns, ms = res
    Rz, Ri, Rf, Rog = R
    L, B = xz.shape[0], xz.shape[1]
    H, Dh = Rz.shape[0], Rz.shape[1]
    f32 = jnp.float32

    shift = lambda s, fill: jnp.concatenate(
        [jnp.full_like(s[:1], fill), s[:-1]], axis=0)
    h_prev = shift(hs, 0.0)
    c_prev = shift(cs, 0.0)
    n_prev = shift(ns, 0.0)
    m_prev = shift(ms, -1e30)

    # recompute the gates for every step at once (vectorized — no recurrence:
    # everything depends only on the saved h/m stacks)
    pre_z, li, pf, pre_o = _slstm_gates(R, f_bias, xz, xi, xf, xog, h_prev, H, Dh)
    z = jnp.tanh(pre_z)
    lf = jax.nn.log_sigmoid(pf)
    i_p = jnp.exp(li - ms)
    f_p = jnp.exp(lf + m_prev - ms)
    o = jax.nn.sigmoid(pre_o)
    sw = (f_p * n_prev + i_p >= jnp.exp(-ms)).astype(f32)   # n max switch

    def body(carry, x):
        gh_in, gc_in, gn_in = carry
        (gy, z_t, ip_t, fp_t, lf_t, o_t, c_t, n_t, cprev_t, nprev_t, sw_t) = x
        gh = gy + gh_in
        go = gh * c_t / n_t
        dpre_o = go * o_t * (1.0 - o_t)
        gc = gh * o_t / n_t + gc_in
        gn = -gh * o_t * c_t / (n_t * n_t) + gn_in
        dz = gc * ip_t
        dpre_z = dz * (1.0 - z_t * z_t)
        dip = gc * z_t + gn * sw_t
        dfp = gc * cprev_t + gn * sw_t * nprev_t
        dli = dip * ip_t                       # ∂ip/∂li = ip (m stop-grad)
        dlf = dfp * fp_t
        dpf = dlf * (1.0 - jnp.exp(lf_t))      # ∂log_sigmoid = σ(−pf) = 1−e^{lf}
        # flow into h_{t−1} through the four recurrent matrices
        recT = lambda d, Rm: jnp.einsum("bhe,hde->bhd", d, Rm)
        gh_prev = (recT(dpre_z, Rz) + recT(dli, Ri)
                   + recT(dpf, Rf) + recT(dpre_o, Rog))
        gc_prev = gc * fp_t
        gn_prev = gn * sw_t * fp_t
        return (gh_prev, gc_prev, gn_prev), (dpre_z, dli, dpf, dpre_o)

    zeros = jnp.zeros((B, H, Dh), f32)
    xs = (g_hs, z, i_p, f_p, lf, o, cs, ns, c_prev, n_prev, sw)
    xs_rev = jax.tree_util.tree_map(lambda a: a[::-1], xs)
    _, d_rev = jax.lax.scan(body, (zeros, zeros, zeros), xs_rev)
    dpre_z, dli, dpf, dpre_o = jax.tree_util.tree_map(lambda a: a[::-1], d_rev)

    # the whole point: dR as ONE einsum over (L, B) — a single all-reduce
    # under data parallelism instead of one per timestep
    dR = tuple(
        jnp.einsum("lbhd,lbhe->hde", h_prev, d)
        for d in (dpre_z, dli, dpf, dpre_o)
    )
    d_fbias = jnp.sum(dpf, axis=(0, 1)).reshape(H * Dh)
    flat = lambda d: d.reshape(L, B, H * Dh)
    return dR, d_fbias, flat(dpre_z), flat(dli), flat(dpf), flat(dpre_o)


_slstm_scan.defvjp(_slstm_scan_fwd, _slstm_scan_bwd)


def init_slstm_state(cfg: ModelConfig, batch: int) -> SLSTMState:
    H, Dh = cfg.n_heads, cfg.head_dim
    f32 = jnp.float32
    z = jnp.zeros((batch, H, Dh), f32)
    return SLSTMState(z, z, z, jnp.full((batch, H, Dh), -1e30, f32))


def slstm_decode(p, h_t: jax.Array, state: SLSTMState, cfg: ModelConfig):
    B = h_t.shape[0]
    H, Dh = cfg.n_heads, cfg.head_dim
    f32 = jnp.float32
    x = h_t[:, 0]
    state, y = _slstm_step(
        p, state, (x @ p["wz"]).astype(f32), x.astype(f32) @ p["wi"],
        x.astype(f32) @ p["wf"], (x @ p["wog"]).astype(f32), H, Dh,
    )
    y = y.reshape(B, 1, H * Dh)
    return y.astype(h_t.dtype) @ p["wo"], state
