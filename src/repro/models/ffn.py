"""Dense SwiGLU FFN."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init


def init_ffn(key, d_model: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(k1, (d_model, d_ff)),
        "wi_up": dense_init(k2, (d_model, d_ff)),
        "wo": dense_init(k3, (d_ff, d_model)),
        "norm": jnp.zeros((d_model,), jnp.float32),
    }


def ffn_forward(p, h: jax.Array) -> jax.Array:
    g = jax.nn.silu((h @ p["wi_gate"]).astype(jnp.float32)).astype(h.dtype)
    u = h @ p["wi_up"]
    return (g * u) @ p["wo"]
