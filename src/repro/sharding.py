"""Sharding rules: map (param path, shape) → PartitionSpec on the production mesh.

Policy (DP/FSDP/TP/EP/SP composed):
  * batch axes            → ("pod","data")  (DP; pod composes with data)
  * parameter "fsdp" dim  → ("pod","data")  (ZeRO-3-style weight sharding; XLA
                            all-gathers per scan step, overlapped by the
                            latency-hiding scheduler)
  * parameter "tensor" dim→ "model"         (TP: heads / FFN inner / vocab)
  * MoE expert dim        → "model"         (EP)
  * long-context KV cache → sequence dim on "model" when head dims don't
                            divide (SP fallback)

Every assignment is divisibility-checked against the mesh; non-divisible dims
fall back to replication (never a compile error on exotic head counts).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def data_axes(mesh: Mesh, policy: str = "default"):
    """The combined DP/FSDP axes: ("pod","data") on multi-pod, ("data",) else.
    Under "dp_only" the model axis joins them (no TP anywhere)."""
    names = ("pod", "data", "model") if policy == "dp_only" else ("pod", "data")
    return tuple(a for a in names if a in mesh.shape)


def _fit(mesh: Mesh, dim: int, axes):
    """Return the contiguous sub-tuple of `axes` with the LARGEST device count
    whose size divides `dim`, else None (replicate). Size-1 results are
    dropped (sharding over them is replication anyway — keeping specs None on
    debug meshes keeps the HLO and tests clean). Largest-first keeps the most
    parallelism — e.g. batch=256 on the 2×16×16 multi-pod mesh under dp_only
    picks ("data","model")=256-way, not ("pod","data")=32-way; earlier
    sub-tuples win ties so the leading (outermost) axes are preferred."""
    if axes is None:
        return None
    if isinstance(axes, str):
        axes = (axes,)
    n = len(axes)
    best, best_sz = None, 1
    for k in range(n, 0, -1):
        for start in range(n - k + 1):
            sub = axes[start:start + k]
            sz = _axis_size(mesh, sub)
            if sz > best_sz and dim % sz == 0:
                best, best_sz = sub, sz
    if best is None:
        return None
    return best if len(best) > 1 else best[0]


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def param_spec(mesh: Mesh, path_str: str, shape: tuple[int, ...],
               policy: str = "default") -> P:
    fsdp = data_axes(mesh, policy)
    tp = "model" if "model" in mesh.shape and policy != "dp_only" else None
    stacked = "/blocks/" in f"/{path_str}/"  # leading superblock axis
    dims = list(shape[1:]) if stacked else list(shape)
    lead = [None] if stacked else []

    def spec(*entries):
        return P(*lead, *entries)

    name = path_str.rsplit("/", 1)[-1]
    nd = len(dims)

    if nd <= 1:
        return spec(*([None] * nd))

    # --- MoE experts: (E, D, F) / (E, F, D) — EP on E, FSDP on D ---------- #
    if nd == 3 and name in ("wi_gate", "wi_up", "wo") and "ffn" in path_str:
        e = _fit(mesh, dims[0], tp)
        if name == "wo":   # (E, F, D)
            return spec(e, None, _fit(mesh, dims[2], fsdp))
        return spec(e, _fit(mesh, dims[1], fsdp), None)

    # --- xLSTM per-head recurrent mats (H, Dh, Dh) ------------------------- #
    if nd == 3 and name.startswith("r"):
        return spec(_fit(mesh, dims[0], tp), None, None)

    # --- embeddings: (V, D) — vocab on TP, D on FSDP ----------------------- #
    if name in ("embed", "lm_head"):
        return spec(_fit(mesh, dims[0], tp), _fit(mesh, dims[1], fsdp))

    # --- 2-D projections ---------------------------------------------------- #
    if nd == 2:
        # output projections: contract dim is TP-sharded
        if name in ("wo", "out_proj"):
            return spec(_fit(mesh, dims[0], tp), _fit(mesh, dims[1], fsdp))
        if name == "conv_w":
            return spec(None, _fit(mesh, dims[1], tp))
        if name == "router":
            return spec(_fit(mesh, dims[0], fsdp), None)
        # input projections (wq/wk/wv/wi_*/in_proj/wz/wi/wf/wog/...):
        return spec(_fit(mesh, dims[0], fsdp), _fit(mesh, dims[1], tp))

    return spec(*([None] * nd))


def params_shardings(mesh: Mesh, params: PyTree, policy: str = "default") -> PyTree:
    def one(path, x):
        return NamedSharding(
            mesh, param_spec(mesh, _path_str(path), x.shape, policy))

    return jax.tree_util.tree_map_with_path(one, params)


def opt_shardings(mesh: Mesh, opt_state: PyTree, params_sh: PyTree) -> PyTree:
    """ZeRO-1: m/v/master inherit the param shardings; step is replicated."""
    from repro.optim.adamw import AdamWState

    rep = NamedSharding(mesh, P())
    return AdamWState(
        step=rep,
        m=params_sh, v=params_sh, master=params_sh,
    )


def batch_spec(mesh: Mesh, batch: int, *, extra_dims: int = 1,
               policy: str = "default") -> P:
    b = _fit(mesh, batch, data_axes(mesh, policy))
    return P(b, *([None] * extra_dims))


def cache_shardings(mesh: Mesh, cache: PyTree, batch: int,
                    policy: str = "default") -> PyTree:
    """Decode caches: batch → DP axes; if batch doesn't divide, shard the
    sequence/slot axis (SP) or heads on "model"."""
    fsdp = data_axes(mesh, policy)
    tp = "model" if "model" in mesh.shape and policy != "dp_only" else None

    def one(x):
        # leading superblock axis then (B, ...) — cache leaves are stacked
        dims = x.shape[1:]
        b = _fit(mesh, dims[0], fsdp)
        rest = [None] * (len(dims) - 1)
        # shard the largest remaining dim on model (seq for KV, slots for
        # sketch caches, heads for states) if divisible
        if len(rest) > 0 and tp is not None:
            sizes = list(dims[1:])
            order = sorted(range(len(sizes)), key=lambda i: -sizes[i])
            for i in order:
                if sizes[i] % _axis_size(mesh, tp) == 0:
                    rest[i] = tp
                    break
        return NamedSharding(mesh, P(None, b, *rest))

    return jax.tree_util.tree_map(one, cache)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# --------------------------------------------------------------------------- #
# Activation constraints (used inside model code; no-ops without a mesh)
# --------------------------------------------------------------------------- #

def _current_mesh():
    from jax._src import mesh as mesh_lib

    m = mesh_lib.thread_resources.env.physical_mesh
    return None if m.empty else m


def constrain(x: jax.Array, *entries, policy: str = "default") -> jax.Array:
    """with_sharding_constraint that (a) is a no-op outside a mesh context and
    (b) drops axes absent from the mesh / non-divisible dims. Entries use the
    logical names "dp" (pod+data; +model under dp_only) and "tp" (model), or
    None.

    This pins the scan carry: without it, SPMD propagation lets the embedding's
    FSDP sharding leak into activations (batch-replicated loop carries)."""
    m = _current_mesh()
    if m is None:
        return x
    fsdp = data_axes(m, policy)
    tp = "model" if "model" in m.shape and policy != "dp_only" else None
    spec = []
    for dim, e in zip(x.shape, entries):
        if e == "dp":
            spec.append(_fit(m, dim, fsdp))
        elif e == "tp":
            spec.append(_fit(m, dim, tp))
        elif e == "sp":
            # sequence parallelism: shard the sequence dim over the model
            # axis so per-block TP output all-reduces become reduce-scatter +
            # all-gather pairs (half the bytes) and norms/elementwise run on
            # 1/|model| of the tokens (Megatron-SP). Dropped under dp_only or
            # when the dim doesn't divide (decode: S=1 → replicated).
            spec.append(_fit(m, dim, tp))
        elif e == "tp!":
            # force model-axis sharding even when the dim doesn't divide —
            # XLA pads the trailing shards. Used to pin HEAD-ALIGNED q/k/v
            # sharding: without it, SPMD inherits the flat (H·Dh)/|model|
            # column sharding from the projection GEMM, splits head_dim, and
            # the QKᵀ contraction goes partial → a (B,Hkv,G,q,S)-sized
            # all-reduce per query chunk per layer.
            spec.append(tp if tp is not None and m.shape.get("model", 1) > 1 else None)
        else:
            spec.append(None)
    spec += [None] * (x.ndim - len(spec))
    return jax.lax.with_sharding_constraint(x, NamedSharding(m, P(*spec)))
