"""Tiny shared helpers (no jax imports — safe to import from anywhere)."""
from __future__ import annotations

import os

_FALSY = ("0", "false", "False", "FALSE", "off", "no")


def env_flag(name: str, default: bool) -> bool:
    """Tri-state boolean env override: unset → default, else truthiness."""
    env = os.environ.get(name)
    if env is None:
        return default
    return env not in _FALSY
