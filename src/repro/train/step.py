"""train_step: loss → grads → (optional sketched compression) → AdamW.

Microbatch gradient accumulation via lax.scan keeps per-step activation peak
at 1/n_micro; remat policy is a config knob. Inside pjit the DP reduction is
implicit in the sharded mean loss — no explicit psum needed.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import loss_fn
from repro.optim.adamw import AdamWConfig, AdamWState, adamw_update, init_adamw
from repro.optim.compress import CompressConfig, compress_grads, init_error_feedback

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    n_micro: int = 1
    remat: str = "full"               # none|dots|full — "full" keeps the scan
                                      # carry as the only cross-layer residual
    q_chunk: int = 512
    compress: CompressConfig | None = None
    seed: int = 0


class TrainState(NamedTuple):
    params: PyTree
    opt: AdamWState
    ef: PyTree | None                 # error-feedback buffers (compression)


def init_train_state(params: PyTree, tc: TrainConfig) -> TrainState:
    ef = None
    if tc.compress is not None:
        ef = init_error_feedback(params, tc.compress)
    return TrainState(params, init_adamw(params), ef)


def _grads(params, tokens, labels, cond, cfg: ModelConfig, tc: TrainConfig):
    def lf(p, t, l, c):
        loss, mets = loss_fn(p, t, l, cfg, cond=c, q_chunk=tc.q_chunk, remat=tc.remat)
        return loss, mets

    if tc.n_micro == 1:
        (loss, mets), grads = jax.value_and_grad(lf, has_aux=True)(
            params, tokens, labels, cond
        )
        return loss, mets, grads

    B = tokens.shape[0]
    mb = B // tc.n_micro
    tk = tokens.reshape(tc.n_micro, mb, *tokens.shape[1:])
    lb = labels.reshape(tc.n_micro, mb, *labels.shape[1:])
    cd = (
        cond.reshape(tc.n_micro, mb, *cond.shape[1:]) if cond is not None else None
    )

    def body(carry, xs):
        acc, loss_acc = carry
        t, l = xs[0], xs[1]
        c = xs[2] if cond is not None else None
        (loss, mets), g = jax.value_and_grad(lf, has_aux=True)(params, t, l, c)
        acc = jax.tree_util.tree_map(
            lambda a, b: a + b.astype(jnp.float32) / tc.n_micro, acc, g
        )
        return (acc, loss_acc + loss / tc.n_micro), mets

    zero = jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), params
    )
    xs = (tk, lb, cd) if cond is not None else (tk, lb)
    (grads, loss), mets = jax.lax.scan(body, (zero, jnp.zeros((), jnp.float32)), xs)
    mets = jax.tree_util.tree_map(lambda x: x[-1], mets)
    return loss, mets, grads


def train_step(
    state: TrainState, tokens: jax.Array, labels: jax.Array, step: jax.Array,
    cfg: ModelConfig, tc: TrainConfig, *, cond: jax.Array | None = None,
) -> tuple[TrainState, dict]:
    loss, mets, grads = _grads(state.params, tokens, labels, cond, cfg, tc)

    ef = state.ef
    if tc.compress is not None:
        grads, ef, cmets = compress_grads(
            grads, ef, step, jax.random.PRNGKey(tc.seed), tc.compress
        )
        mets = {**mets, **cmets}

    new_params, new_opt, omets = adamw_update(grads, state.opt, tc.optimizer)
    metrics = {"loss": loss, **mets, **omets}
    return TrainState(new_params, new_opt, ef), metrics
