"""Fault-tolerant training loop: checkpoint/restart, straggler tracking,
deterministic data resume.

Restart contract: the loop derives everything from (config, latest checkpoint);
the data pipeline is stateless in `step`, so a preempted job resumes with the
exact token stream it would have seen. Straggler mitigation: per-step wall time
EWMA; steps slower than `straggler_factor`× the EWMA are logged — on a real
cluster this feeds the controller that re-slices `n_micro` (gradient
accumulation is the elastic knob that changes per-step work without
resharding) or evicts the slow host.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import numpy as np

from repro.checkpoint.ckpt import AsyncCheckpointer, latest_step, restore
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, global_batch
from repro.train.step import TrainConfig, TrainState, train_step


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_dir: str = ""
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    straggler_factor: float = 2.0


@dataclasses.dataclass
class LoopReport:
    steps_run: int
    resumed_from: int | None
    final_loss: float
    losses: list
    straggler_steps: list


def run(
    cfg: ModelConfig, tc: TrainConfig, dc: DataConfig, lc: LoopConfig,
    *, init_params_fn: Callable[[], TrainState] | None = None,
    step_fn=None, log=print,
) -> LoopReport:
    state = init_params_fn() if init_params_fn else None
    assert state is not None, "provide init_params_fn"

    resumed_from = None
    start = 0
    ckpt = None
    if lc.ckpt_dir:
        ckpt = AsyncCheckpointer(lc.ckpt_dir, keep=lc.keep)
        last = latest_step(lc.ckpt_dir)
        if last is not None:
            tree, start = restore(lc.ckpt_dir, state)
            state = jax.tree_util.tree_map(jax.numpy.asarray, tree)
            resumed_from = start
            log(f"[loop] resumed from step {start}")

    if step_fn is None:
        step_fn = jax.jit(
            lambda s, t, l, i: train_step(s, t, l, i, cfg, tc),
            donate_argnums=(0,),
        )

    losses, stragglers = [], []
    ewma = None
    for step in range(start, lc.total_steps):
        toks, labs = global_batch(dc, step)
        t0 = time.perf_counter()
        state, mets = step_fn(state, toks, labs, np.int32(step))
        loss = float(mets["loss"])
        dt = time.perf_counter() - t0
        ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
        if dt > lc.straggler_factor * ewma and step > start + 2:
            stragglers.append(step)
            log(f"[loop] straggler at step {step}: {dt:.3f}s vs ewma {ewma:.3f}s")
        losses.append(loss)
        if step % lc.log_every == 0:
            log(f"[loop] step {step} loss {loss:.4f} ({dt*1e3:.0f} ms)")
        if ckpt and (step + 1) % lc.ckpt_every == 0:
            ckpt.save(state, step=step + 1)
    if ckpt:
        ckpt.save(state, step=lc.total_steps)
        ckpt.wait()
    return LoopReport(
        steps_run=lc.total_steps - start, resumed_from=resumed_from,
        final_loss=losses[-1] if losses else float("nan"),
        losses=losses, straggler_steps=stragglers,
    )
