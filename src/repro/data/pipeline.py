"""Deterministic, stateless synthetic LM data pipeline.

Design for fault tolerance: batch t is a pure function of (seed, step) — a
restarted job at step t reproduces exactly the stream a non-restarted job
would have seen, with no iterator state to checkpoint. Host-sharding: each
data-parallel host materializes only its slice (process_index-based offsets),
matching how a multi-pod deployment feeds jax.make_array_from_process_data.

The synthetic distribution is a order-2 Markov chain over the vocab with a
power-law unigram marginal, so cross-entropy has meaningful structure
(a model can actually learn; loss decreasing is asserted in tests).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


def _token_block(key, cfg: DataConfig, shape) -> jax.Array:
    """Markov-ish synthetic tokens: next = f(prev) + noise, power-law marginal."""
    k1, k2 = jax.random.split(key)
    # power-law unigram draw
    u = jax.random.uniform(k1, shape, minval=1e-6, maxval=1.0)
    base = (cfg.vocab_size * (u ** 2.5)).astype(jnp.int32) % cfg.vocab_size
    # deterministic mixing: makes position t predictable from t-1 half the time
    mix = jax.random.bernoulli(k2, 0.5, shape)
    rolled = (jnp.roll(base, 1, axis=-1) * 31 + 7) % cfg.vocab_size
    return jnp.where(mix, rolled, base)


def host_batch(cfg: DataConfig, step: int) -> tuple[np.ndarray, np.ndarray]:
    """This host's (tokens, labels) slice for `step`: shapes
    (global_batch / n_hosts, seq_len)."""
    assert cfg.global_batch % cfg.n_hosts == 0
    per_host = cfg.global_batch // cfg.n_hosts
    key = jax.random.fold_in(  # rng-stream: data-step-host
        jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step), cfg.host_id
    )
    block = _token_block(key, cfg, (per_host, cfg.seq_len + 1))
    block = np.asarray(block)
    return block[:, :-1].astype(np.int32), block[:, 1:].astype(np.int32)


def global_batch(cfg: DataConfig, step: int) -> tuple[np.ndarray, np.ndarray]:
    """All-hosts batch (single-host testing convenience)."""
    toks, labs = [], []
    for h in range(cfg.n_hosts):
        t, l = host_batch(dataclasses.replace(cfg, host_id=h), step)
        toks.append(t)
        labs.append(l)
    return np.concatenate(toks), np.concatenate(labs)
