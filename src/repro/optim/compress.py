"""Sketched gradient compression with error feedback — the paper's technique
applied to distributed optimization.

Each 2-D gradient block G (p, q) with p ≥ threshold is compressed before the
data-parallel all-reduce:   Ĝ = Sᵀ G   (d, q),  S an AccumSketch over rows.
Workers all-reduce Ĝ (d/p of the bytes), then unsketch  G̃ = S Ĝ, which equals
P_S G in expectation (E[SSᵀ]=I ⇒ unbiased). The residual G − S SᵀG stays in a
local error-feedback buffer and is added to the next step's gradient, giving
the usual EF-SGD convergence guarantee.

The sketch is resampled every step from a counter-based key (fold_in(step)),
identical on every worker — no index communication is needed, which is the
practical advantage of sub-sampling-structured sketches over dense Gaussian
compression (whose projection matrix would itself need syncing or seeding +
O(n·d) flops; here it is O(m·d·q) gathers).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.apply import sketch_left, unsketch_mat
from repro.core.sketch import make_accum_sketch

PyTree = Any


@dataclasses.dataclass(frozen=True)
class CompressConfig:
    ratio: float = 0.125        # d = ratio · p
    m: int = 4                  # accumulations
    min_rows: int = 1024        # only compress blocks with p ≥ this


def _eligible(x: jax.Array, cfg: CompressConfig) -> bool:
    return x.ndim >= 2 and x.shape[0] >= cfg.min_rows


def init_error_feedback(grads: PyTree, cfg: CompressConfig) -> PyTree:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32) if _eligible(g, cfg) else None,
        grads, is_leaf=lambda x: x is None,
    )


def compress_grads(
    grads: PyTree, ef: PyTree, step: jax.Array, key: jax.Array, cfg: CompressConfig,
    *, axis_name: str | None = None,
) -> tuple[PyTree, PyTree, dict]:
    """Returns (projected grads [all-reduced over axis_name if given],
    new error-feedback buffers, metrics).

    Inside pjit, pass axis_name=None and let the caller's psum/sharding do the
    reduction — the compression itself is what shrinks the all-reduce bytes.
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    ef_leaves = jax.tree_util.tree_leaves(
        ef, is_leaf=lambda x: x is None
    )
    out, new_ef = [], []
    bytes_full = bytes_comp = 0
    for i, (g, e) in enumerate(zip(leaves, ef_leaves)):
        if e is None or not _eligible(g, cfg):
            out.append(g)
            new_ef.append(None)
            bytes_full += g.size * 4
            bytes_comp += g.size * 4
            continue
        p = g.shape[0]
        d = max(int(p * cfg.ratio), 1)
        sk = make_accum_sketch(  # rng-stream: compress-step-leaf
            jax.random.fold_in(jax.random.fold_in(key, step), i), p, d, cfg.m
        )
        gf = g.astype(jnp.float32).reshape(p, -1) + e.reshape(p, -1)
        sketched = sketch_left(sk, gf)                      # (d, cols)
        if axis_name is not None:
            sketched = jax.lax.pmean(sketched, axis_name)
        recon = unsketch_mat(sk, sketched)                  # (p, cols) = S Sᵀ (g+e)
        new_ef.append((gf - recon).reshape(g.shape))
        out.append(recon.reshape(g.shape).astype(g.dtype))
        bytes_full += g.size * 4
        bytes_comp += sketched.size * 4
    metrics = {
        "compress_ratio": jnp.asarray(bytes_comp / max(bytes_full, 1), jnp.float32)
    }
    return (
        jax.tree_util.tree_unflatten(treedef, out),
        jax.tree_util.tree_unflatten(treedef, new_ef),
        metrics,
    )
