"""AdamW with f32 master copies, global-norm clipping, and cosine schedule.

Pure-pytree implementation (no optax in this environment). Optimizer state is
optionally ZeRO-1 partitioned: the sharding rules in `repro/sharding.py` place
`m`, `v`, and `master` on the combined (pod, data, model) axes so per-chip
optimizer bytes scale 1/N_chips.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class AdamWState(NamedTuple):
    step: jax.Array      # scalar int32
    m: PyTree            # f32, like params
    v: PyTree            # f32
    master: PyTree       # f32 master weights


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to lr_min."""
    step_f = step.astype(jnp.float32)
    warm = cfg.lr_peak * step_f / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step_f - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step_f < cfg.warmup_steps, warm, cos)


def init_adamw(params: PyTree) -> AdamWState:
    f32 = lambda t: jax.tree_util.tree_map(lambda x: jnp.zeros(x.shape, jnp.float32), t)
    # copy=True: f32 params (norm scales) must not alias the master buffer
    # (aliasing breaks buffer donation in the jitted train step)
    master = jax.tree_util.tree_map(lambda x: jnp.array(x, jnp.float32, copy=True), params)
    return AdamWState(jnp.zeros((), jnp.int32), f32(params), f32(params), master)


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree))
        + 1e-30
    )


def _decay_mask(path: tuple, x: jax.Array) -> bool:
    """No weight decay on norms/biases/scalars."""
    name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
    return x.ndim >= 2 and "norm" not in name and "bias" not in name.lower()


def adamw_update(
    grads: PyTree, state: AdamWState, cfg: AdamWConfig, param_dtype=jnp.bfloat16
) -> tuple[PyTree, AdamWState, dict]:
    """Returns (new params cast to param_dtype, new state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-12))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(path, g, m, v, w):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if _decay_mask(path, w):
            u = u + cfg.weight_decay * w
        return m, v, w - lr * u

    flat = jax.tree_util.tree_flatten_with_path(grads)[0]
    treedef = jax.tree_util.tree_structure(grads)
    ms = jax.tree_util.tree_leaves(state.m)
    vs = jax.tree_util.tree_leaves(state.v)
    ws = jax.tree_util.tree_leaves(state.master)
    out = [upd(p, g, m, v, w) for (p, g), m, v, w in zip(flat, ms, vs, ws)]
    new_m = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_w = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    new_params = jax.tree_util.tree_map(lambda x: x.astype(param_dtype), new_w)
    # norm params stay f32 (they are stored f32 in the model)
    new_params = jax.tree_util.tree_map(
        lambda p, w: w if p.dtype == jnp.float32 else p, new_params, new_w
    )
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step, new_m, new_v, new_w), metrics
