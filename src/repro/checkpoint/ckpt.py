"""Fault-tolerant checkpointing: msgpack + atomic rename + retained history +
async writer thread.

Layout: <dir>/step_<n>/state.msgpack (+ .meta.json), written to a tmp path and
os.rename'd (atomic on POSIX) so a preemption mid-write never corrupts the
latest checkpoint. `latest_step()` only trusts directories with the COMMIT
marker. Arrays are stored host-unsharded (fetched with jax.device_get), so a
restarted job with a *different mesh shape* can reshard on load — elastic
scaling across restarts.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

PyTree = Any
_COMMIT = "COMMITTED"


def _flatten(tree: PyTree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _encode_leaf(x) -> dict:
    a = np.asarray(jax.device_get(x))
    # bf16 has no numpy dtype wire format — ship as uint16 view + tag
    if a.dtype == jnp.bfloat16:
        return {
            "dtype": "bfloat16", "shape": list(a.shape),
            "data": a.view(np.uint16).tobytes(),
        }
    return {"dtype": a.dtype.str, "shape": list(a.shape), "data": a.tobytes()}


def _decode_leaf(d: dict) -> np.ndarray:
    if d["dtype"] == "bfloat16":
        a = np.frombuffer(d["data"], np.uint16).reshape(d["shape"])
        return a.view(jnp.bfloat16)
    return np.frombuffer(d["data"], np.dtype(d["dtype"])).reshape(d["shape"])


def save(path: str, tree: PyTree, *, step: int, extra: dict | None = None) -> str:
    """Synchronous atomic save. Returns the committed directory."""
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten(tree)
    payload = msgpack.packb(
        {"leaves": [_encode_leaf(x) for x in leaves]}, use_bin_type=True
    )
    with open(os.path.join(tmp, "state.msgpack"), "wb") as f:
        f.write(payload)
    meta = {"step": step, "treedef": str(treedef), "extra": extra or {}}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    with open(os.path.join(tmp, _COMMIT), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def restore(path: str, like: PyTree, *, step: int | None = None) -> tuple[PyTree, int]:
    """Restore into the structure of `like` (resharding happens when the caller
    device_puts with its own shardings). Returns (tree, step)."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {path}")
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "state.msgpack"), "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    leaves = [_decode_leaf(x) for x in payload["leaves"]]
    _, treedef = _flatten(like)
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = []
    for name in os.listdir(path):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(path, name, _COMMIT)):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def retain(path: str, keep: int = 3) -> None:
    """Garbage-collect all but the newest `keep` committed checkpoints."""
    if not os.path.isdir(path):
        return
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(path)
        if n.startswith("step_") and not n.endswith(".tmp")
        and os.path.exists(os.path.join(path, n, _COMMIT))
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(path, f"step_{s:08d}"), ignore_errors=True)


class AsyncCheckpointer:
    """Overlaps checkpoint serialization with training: save() snapshots to
    host memory (device_get) then writes on a daemon thread. wait() joins."""

    def __init__(self, path: str, keep: int = 3):
        self.path = path
        self.keep = keep
        self._thread: threading.Thread | None = None

    def save(self, tree: PyTree, *, step: int, extra: dict | None = None) -> None:
        self.wait()
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            save(self.path, host_tree, step=step, extra=extra)
            retain(self.path, self.keep)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
