"""Fault-tolerant checkpointing: msgpack + atomic rename + retained history +
async writer thread.

Layout: <dir>/step_<n>/state.msgpack (+ meta.json), written to a tmp path and
os.rename'd (atomic on POSIX) so a preemption mid-write never corrupts the
latest checkpoint. `latest_step()` only trusts directories with the COMMIT
marker, and by default sweeps stale `.tmp` / uncommitted directories left by
mid-write kills. Arrays are stored host-unsharded (fetched with
jax.device_get), so a restarted job with a *different mesh shape* can reshard
on load — elastic scaling across restarts.

Resilience behavior (see docs/resilience.md):

* `save()` retries the tmp-write + rename with exponential backoff (transient
  I/O errors), EXCEPT on a (simulated) device loss, which propagates
  untouched — a killed process neither retries nor cleans up; the stale tmp
  dir it leaves is removed by the next `sweep_stale()`.
* `restore(step=None)` walks committed steps newest-first and falls back past
  a corrupt payload to step N−1, recording the skip in the global
  HealthReport.
* `AsyncCheckpointer` captures writer-thread exceptions and re-raises them on
  the next `save()` / `wait()` / `close()` instead of dying silently.

The `ckpt.write` fault site (REPRO_FAULT_PLAN) can corrupt/truncate the
payload of one write attempt or kill it mid-stream, so all of the above is
exercised by tests/test_resilience.py rather than only in prose.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

from repro.resilience import faults

PyTree = Any
_COMMIT = "COMMITTED"


def _flatten(tree: PyTree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _encode_leaf(x) -> dict:
    a = np.asarray(jax.device_get(x))
    # bf16 has no numpy dtype wire format — ship as uint16 view + tag
    if a.dtype == jnp.bfloat16:
        return {
            "dtype": "bfloat16", "shape": list(a.shape),
            "data": a.view(np.uint16).tobytes(),
        }
    return {"dtype": a.dtype.str, "shape": list(a.shape), "data": a.tobytes()}


def _decode_leaf(d: dict) -> np.ndarray:
    if d["dtype"] == "bfloat16":
        a = np.frombuffer(d["data"], np.uint16).reshape(d["shape"])
        return a.view(jnp.bfloat16)
    return np.frombuffer(d["data"], np.dtype(d["dtype"])).reshape(d["shape"])


def _step_dir(path: str, step: int) -> str:
    return os.path.join(path, f"step_{step:08d}")


def _write_attempt(tmp: str, final: str, payload: bytes, meta: dict) -> None:
    os.makedirs(tmp, exist_ok=True)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    # Fault site: a "kill" here dies after meta but before state — exactly the
    # partial tmp dir a preemption leaves; "corrupt"/"truncate" mangle the
    # committed payload (the corrupt-latest fallback's target).
    payload = faults.corrupt("ckpt.write", payload)
    with open(os.path.join(tmp, "state.msgpack"), "wb") as f:
        f.write(payload)
    with open(os.path.join(tmp, _COMMIT), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)


def save(
    path: str,
    tree: PyTree,
    *,
    step: int,
    extra: dict | None = None,
    keep_last: int | None = None,
    retries: int = 3,
    backoff: float = 0.05,
) -> str:
    """Atomic save with retry-with-backoff. Returns the committed directory.

    Transient write errors are retried up to `retries` times (backoff
    doubling from `backoff` seconds); a DeviceLost propagates immediately.
    When `keep_last` is given, older committed steps are garbage-collected
    after the commit."""
    final = _step_dir(path, step)
    tmp = final + ".tmp"
    leaves, treedef = _flatten(tree)
    payload = msgpack.packb(
        {"leaves": [_encode_leaf(x) for x in leaves]}, use_bin_type=True
    )
    meta = {"step": step, "treedef": str(treedef), "extra": extra or {}}
    for attempt in range(max(1, retries)):
        try:
            _write_attempt(tmp, final, payload, meta)
            break
        except faults.DeviceLost:
            raise  # simulated preemption: no cleanup, no retry
        except Exception:
            if attempt >= max(1, retries) - 1:
                raise
            time.sleep(backoff * (2**attempt))
    if keep_last is not None:
        retain(path, keep_last)
    return final


def _restore_step(path: str, like: PyTree, step: int) -> PyTree:
    d = _step_dir(path, step)
    with open(os.path.join(d, "state.msgpack"), "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    leaves = [_decode_leaf(x) for x in payload["leaves"]]
    _, treedef = _flatten(like)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore(path: str, like: PyTree, *, step: int | None = None) -> tuple[PyTree, int]:
    """Restore into the structure of `like` (resharding happens when the caller
    device_puts with its own shardings). Returns (tree, step).

    With `step=None` the newest committed checkpoint is loaded, falling back
    step-by-step past corrupt/undecodable payloads; each skip is recorded in
    the global HealthReport (site "ckpt.restore" is informational — the data
    loss already happened at write time)."""
    if step is not None:
        return _restore_step(path, like, step), step
    steps = committed_steps(path)
    if not steps:
        raise FileNotFoundError(f"no committed checkpoint under {path}")
    last_err: Exception | None = None
    for i, s in enumerate(steps):
        try:
            return _restore_step(path, like, s), s
        except Exception as e:  # noqa: BLE001 — any undecodable payload falls back
            last_err = e
            from repro.resilience.degrade import global_health

            nxt = f"step_{steps[i + 1]}" if i + 1 < len(steps) else "none"
            global_health().record(
                "ckpt.restore", rung_from=f"step_{s}", rung_to=nxt, detail=repr(e)
            )
    raise last_err


def committed_steps(path: str) -> list[int]:
    """All committed step numbers under `path`, newest first."""
    if not os.path.isdir(path):
        return []
    steps = [
        int(n.split("_")[1])
        for n in os.listdir(path)
        if n.startswith("step_") and not n.endswith(".tmp")
        and os.path.exists(os.path.join(path, n, _COMMIT))
    ]
    return sorted(steps, reverse=True)


def sweep_stale(path: str) -> list[str]:
    """Remove step entries lacking the COMMIT marker (incl. `.tmp` leftovers
    from mid-write kills). Returns the removed names. Not safe to run
    concurrently with a live writer on the same directory."""
    if not os.path.isdir(path):
        return []
    removed = []
    for name in sorted(os.listdir(path)):
        p = os.path.join(path, name)
        if not name.startswith("step_") or not os.path.isdir(p):
            continue
        if not os.path.exists(os.path.join(p, _COMMIT)):
            shutil.rmtree(p, ignore_errors=True)
            removed.append(name)
    return removed


def latest_step(path: str, *, sweep: bool = True) -> int | None:
    """Newest committed step, or None. By default also sweeps stale
    uncommitted directories (see `sweep_stale` for the concurrency caveat)."""
    if sweep:
        sweep_stale(path)
    steps = committed_steps(path)
    return steps[0] if steps else None


def read_meta(path: str, step: int) -> dict:
    """The meta.json of a committed step ({"step", "treedef", "extra"})."""
    with open(os.path.join(_step_dir(path, step), "meta.json")) as f:
        return json.load(f)


def retain(path: str, keep: int = 3) -> None:
    """Garbage-collect all but the newest `keep` committed checkpoints."""
    for s in committed_steps(path)[keep:]:
        shutil.rmtree(_step_dir(path, s), ignore_errors=True)


class AsyncCheckpointer:
    """Overlaps checkpoint serialization with training: save() snapshots to
    host memory (device_get) then writes on a daemon thread. wait() joins.

    A writer-thread failure is captured and re-raised by the next save() /
    wait() / close() — never swallowed silently."""

    def __init__(self, path: str, keep: int = 3):
        self.path = path
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._exc: BaseException | None = None

    def save(self, tree: PyTree, *, step: int, extra: dict | None = None) -> None:
        self.wait()
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            try:
                save(self.path, host_tree, step=step, extra=extra, keep_last=self.keep)
            except BaseException as e:  # noqa: BLE001 — surfaced on next save()/wait()
                self._exc = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise exc

    def close(self) -> None:
        """Drain the writer and surface any captured failure."""
        self.wait()
