"""xlstm-125m [ssm]: 12L d_model=768 4H d_ff=0 vocab=50304 — alternating
sLSTM + mLSTM blocks (attention-free). [arXiv:2405.04517; unverified]

The paper's sketching technique is inapplicable to the mixer (no kernel matrix);
long_500k runs natively (recurrent state). See DESIGN.md §Arch-applicability."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    pattern=("mlstm", "slstm"),
    n_superblocks=6,
    ffn="none",
    tie_embeddings=True,
    native_long_context=True,
    # 125M params replicate trivially; TP would put per-timestep all-reduces
    # inside the sLSTM/mLSTM time scan (measured: 1.4M collectives/step on the
    # 16×16 mesh). See EXPERIMENTS.md §Perf iteration A1.
    sharding_policy="dp_only",
)
