"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (MHA kv=16) expert d_ff=1408
vocab=163840, MoE 64 experts top-6 (kimi/moonlight).
[hf:moonshotai/Moonlight-16B-A3B; hf]"""
from repro.configs.base import ModelConfig, MoECfg, SketchAttnCfg

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    pattern=("attn",),
    n_superblocks=48,
    ffn="moe",
    moe=MoECfg(n_experts=64, top_k=6, d_ff_expert=1408),
    rope_theta=50000.0,
    sketch_attn=SketchAttnCfg(d_slots=1024, m=8, m_r=2),
    native_long_context=False,
)
