"""stablelm-3b [dense]: 32L d_model=2560 32H (MHA kv=32) d_ff=6912 vocab=50304.
[hf:stabilityai/stablelm-2-1_6b family; unverified]"""
from repro.configs.base import ModelConfig, SketchAttnCfg

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
    pattern=("attn",),
    n_superblocks=32,
    rope_theta=10000.0,
    sketch_attn=SketchAttnCfg(d_slots=1024, m=8, m_r=2),
    native_long_context=False,
)
