"""qwen1.5-110b [dense]: 80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064,
QKV bias. [hf:Qwen/Qwen1.5-0.5B family; hf]"""
from repro.configs.base import ModelConfig, SketchAttnCfg

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab_size=152064,
    pattern=("attn",),
    n_superblocks=80,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    # padded kv-head TP regresses this arch: the 8-over-16 reshard triggers
    # SPMD involuntary rematerialization (t_coll 68→355 s). §Perf.
    attn_head_tp=False,
    sketch_attn=SketchAttnCfg(d_slots=2048, m=8, m_r=2),
    native_long_context=False,     # pure full attention → long_500k via AccumAttention
)
