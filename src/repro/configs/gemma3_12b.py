"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144,
5:1 local:global interleaving, 128k context (sliding window 1024 on local layers).
[hf:google/gemma-3-1b-pt family; unverified]"""
from repro.configs.base import ModelConfig, SketchAttnCfg

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,                  # gemma3 uses wide heads (16×256 ≠ d_model is intentional)
    d_ff=15360,
    vocab_size=262144,
    pattern=("attn_local",) * 5 + ("attn",),
    n_superblocks=8,
    window=1024,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    sketch_attn=SketchAttnCfg(d_slots=2048, m=8, m_r=2),
    # local layers are sub-quadratic; global layers use AccumAttention at 500k
    native_long_context=False,
)
