"""zamba2-7b [hybrid]: 81L d_model=3584 32H (MHA kv=32) d_ff=14336 vocab=32000,
Mamba2 backbone + shared attention blocks, ssm_state=64. [arXiv:2411.15242; unverified]

Realized pattern: 27 superblocks of (mamba2, mamba2, shared-attention+FFN); the
attention/FFN parameters are shared across all 27 occurrences (Zamba2's weight
sharing), Mamba2 parameters are per-block. Hybrid → long_500k native on Mamba2
path with AccumAttention on the shared-attention blocks."""
from repro.configs.base import ModelConfig, SSMCfg, SketchAttnCfg

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    pattern=("mamba2", "mamba2", "attn_shared"),
    n_superblocks=27,
    ssm=SSMCfg(d_state=64, head_dim=64, expand=2, conv_width=4, chunk=64),
    rope_theta=10000.0,
    sketch_attn=SketchAttnCfg(d_slots=1024, m=8, m_r=2),
    native_long_context=True,
)
