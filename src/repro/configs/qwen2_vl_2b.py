"""qwen2-vl-2b [vlm]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936,
M-RoPE, dynamic resolution. [arXiv:2409.12191; hf]

Backbone only per spec: the ViT frontend is a stub; input_specs() provides
precomputed patch embeddings (B, cond_len, d_model) prepended to the text
tokens. M-RoPE is realized as 1-D RoPE over the flattened sequence (the 3-D
position decomposition lives in the stubbed frontend) — noted in DESIGN.md."""
from repro.configs.base import ModelConfig, SketchAttnCfg

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    pattern=("attn",),
    n_superblocks=28,
    qkv_bias=True,
    frontend="vlm",
    cond_len=1024,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    sketch_attn=SketchAttnCfg(d_slots=1024, m=8, m_r=2),
    native_long_context=False,
)
