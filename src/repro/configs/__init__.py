"""Architecture registry: --arch <id> resolves here."""
from __future__ import annotations

from repro.configs.base import SHAPES, ModelConfig, ShapeSpec, reduced

from repro.configs.gemma3_12b import CONFIG as _gemma3
from repro.configs.qwen15_110b import CONFIG as _qwen110b
from repro.configs.stablelm_3b import CONFIG as _stablelm
from repro.configs.minitron_8b import CONFIG as _minitron
from repro.configs.xlstm_125m import CONFIG as _xlstm
from repro.configs.musicgen_medium import CONFIG as _musicgen
from repro.configs.moonshot_16b_a3b import CONFIG as _moonshot
from repro.configs.arctic_480b import CONFIG as _arctic
from repro.configs.zamba2_7b import CONFIG as _zamba2
from repro.configs.qwen2_vl_2b import CONFIG as _qwen2vl

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        _gemma3, _qwen110b, _stablelm, _minitron, _xlstm,
        _musicgen, _moonshot, _arctic, _zamba2, _qwen2vl,
    ]
}

ALIASES = {
    "gemma3-12b": "gemma3-12b",
    "qwen1.5-110b": "qwen1.5-110b",
    "qwen15-110b": "qwen1.5-110b",
    "stablelm-3b": "stablelm-3b",
    "minitron-8b": "minitron-8b",
    "xlstm-125m": "xlstm-125m",
    "musicgen-medium": "musicgen-medium",
    "moonshot-v1-16b-a3b": "moonshot-v1-16b-a3b",
    "moonshot-16b-a3b": "moonshot-v1-16b-a3b",
    "arctic-480b": "arctic-480b",
    "zamba2-7b": "zamba2-7b",
    "qwen2-vl-2b": "qwen2-vl-2b",
}


def get_config(arch: str) -> ModelConfig:
    key = ALIASES.get(arch, arch)
    if key not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[key]


__all__ = ["ARCHS", "SHAPES", "ModelConfig", "ShapeSpec", "get_config", "reduced"]
