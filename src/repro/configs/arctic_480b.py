"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) expert d_ff=4864
vocab=32000, MoE 128 experts top-2 + dense residual MLP.
[hf:Snowflake/snowflake-arctic-base; hf]"""
from repro.configs.base import ModelConfig, MoECfg, SketchAttnCfg

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    pattern=("attn",),
    n_superblocks=35,
    ffn="moe",
    moe=MoECfg(
        n_experts=128, top_k=2, d_ff_expert=4864,
        dense_residual=True, d_ff_dense=4864,
    ),
    rope_theta=10000.0,
    sketch_attn=SketchAttnCfg(d_slots=2048, m=8, m_r=2),
    native_long_context=False,
)
