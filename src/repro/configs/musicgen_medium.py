"""musicgen-medium [audio]: 48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048 —
decoder-only over EnCodec tokens. [arXiv:2306.05284; hf]

Backbone only per spec: the EnCodec/T5 frontend is a stub; input_specs() provides
precomputed conditioning frame embeddings (B, cond_len, d_model)."""
from repro.configs.base import ModelConfig, SketchAttnCfg

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    pattern=("attn",),
    n_superblocks=48,
    frontend="audio",
    cond_len=256,
    rope_theta=10000.0,
    sketch_attn=SketchAttnCfg(d_slots=1024, m=8, m_r=2),
    native_long_context=False,
)
