"""Config system: one dataclass describes every supported architecture.

A model is `n_superblocks` repetitions of a `pattern` of layer kinds, scanned
with `jax.lax.scan` (small HLO, fast multi-pod compiles). Layer kinds:

  attn         — global causal attention (+ FFN per `ffn`)
  attn_local   — sliding-window causal attention (+ FFN)
  attn_shared  — attention with parameters SHARED across all occurrences (Zamba2)
  mamba2       — Mamba-2 SSD mixer block (no separate FFN)
  mlstm        — xLSTM matrix-memory block
  slstm        — xLSTM scalar-memory block
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    dense_residual: bool = False     # Arctic-style parallel dense FFN
    d_ff_dense: int = 0
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 64                  # SSD intra-chunk length


@dataclasses.dataclass(frozen=True)
class SketchAttnCfg:
    """AccumAttention (paper technique) for long-context serving."""
    d_slots: int = 1024              # landmark slots (projection dimension d)
    m: int = 8                       # accumulations (prefill/landmark path)
    m_r: int = 2                     # streaming picks per token (decode path)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    pattern: Tuple[str, ...]
    n_superblocks: int
    head_dim: int = 0                # 0 → d_model // n_heads
    ffn: str = "dense"               # dense|moe|none
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    window: int = 1024               # attn_local sliding window
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    frontend: Optional[str] = None   # None|audio|vlm
    cond_len: int = 0                # frontend embedding length
    sketch_attn: SketchAttnCfg = SketchAttnCfg()
    norm_eps: float = 1e-6
    # which shapes support the exact long-context path (sub-quadratic mixers)
    native_long_context: bool = False
    # Pin head-aligned (padded) TP sharding on q/k/v inside attention. Wins
    # when flat (H·Dh)-column sharding splits head_dim and the score einsum
    # goes partial (arctic: −40 s/step of score all-reduces); loses when the
    # padded reshard itself triggers SPMD involuntary rematerialization
    # (qwen1.5-110b: +287 s/step). Tuned per arch in §Perf.
    attn_head_tp: bool = True
    # "default": DP/FSDP on (pod,data) + TP/EP on model.
    # "dp_only": no TP; batch and FSDP span every mesh axis. Right for small
    # models with sequential time-scans (xLSTM): TP on the gate projections
    # leaks sharded contractions into the per-timestep scan body, costing one
    # tuple all-reduce per token — DP-only removes every per-step collective.
    sharding_policy: str = "default"

    def __post_init__(self):
        assert len(self.pattern) * self.n_superblocks == self.n_layers, (
            f"{self.name}: pattern×superblocks != n_layers"
        )
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def has_attention(self) -> bool:
        return any(k.startswith("attn") for k in self.pattern)

    @property
    def attention_only(self) -> bool:
        return all(k.startswith("attn") for k in self.pattern)

    def scaled(self, **overrides) -> "ModelConfig":
        return dataclasses.replace(self, **overrides)


def reduced(cfg: ModelConfig) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests (one superblock, narrow)."""
    small_moe = None
    if cfg.moe is not None:
        small_moe = dataclasses.replace(
            cfg.moe, n_experts=min(cfg.moe.n_experts, 4),
            top_k=min(cfg.moe.top_k, 2), d_ff_expert=64,
            d_ff_dense=64 if cfg.moe.dense_residual else 0,
        )
    small_ssm = dataclasses.replace(cfg.ssm, head_dim=16, d_state=8, chunk=8) if cfg.ssm else None
    n_heads = min(cfg.n_heads, 4)
    n_kv = min(cfg.n_kv_heads, n_heads)
    return cfg.scaled(
        name=cfg.name + "-reduced",
        n_layers=len(cfg.pattern),
        n_superblocks=1,
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        moe=small_moe,
        ssm=small_ssm,
        window=16,
        cond_len=8 if cfg.frontend else 0,
        sketch_attn=SketchAttnCfg(d_slots=16, m=2, m_r=2),
    )


# ---------------------------------------------------------------------------
# Input shape suite (assigned to every architecture)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train|prefill|decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}
