"""Public entry points for the accum_apply kernel family.

This layer makes the kernels shape- and backend-agnostic:

  * ``interpret`` defaults to backend autodetection — compiled Mosaic on TPU,
    interpreter everywhere else (CPU CI, tests).
  * block sizes come from a MEASURED autotune cache: the first eager call at
    a (shape, dtype, backend) key times candidate tilings on the caller's
    real arrays and persists the winner to ``REPRO_AUTOTUNE_CACHE`` (default
    ``~/.cache/repro/autotune.json``); jitted/traced calls and disabled or
    corrupt caches fall back to the static table + VMEM-budget heuristic
    (``autotune.py``);
  * arbitrary shapes are zero-padded up to the block grid and sliced back
    (padded K rows/columns contribute nothing; padded sketch columns carry
    coef 0);
  * wide K is chunked along columns with ``jax.lax.scan`` so the jaxpr stays
    O(1) in the number of chunks — the seed's Python loop unrolled one
    pallas_call per chunk under jit;
  * ``sketch_both_kernel`` exposes the fused (K S, SᵀK S) single-sweep kernel,
    ``sketch_left_kernel`` applies Sᵀ M through the true left-apply kernel
    (M streamed in row tiles — no Mᵀ copy);
  * ``sketch_step_kernel`` is the single-slab accumulate entry point used by
    the progressive engine: a·C + K·T̃ in one fused launch (MXU path for the
    m → m+1 increment);
  * ``accum_grow_kernel`` is the BATCHED rank-B accumulate entry point:
    a·C + K·T for a B-slab batch block plus both d×d W pieces (TᵀKT, TᵀC)
    folded from the SAME single sweep over K — the engine's m → m+B growth
    reads K once instead of B times.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sketch import AccumSketch
from repro.kernels.accum_apply import autotune
from repro.resilience import faults
from repro.kernels.accum_apply.kernel import (
    accum_apply,
    accum_apply_left,
    accum_grow_slabs,
    accum_sketch_both,
    accum_step_slab,
    matfree_apply,
)
from repro.util import env_flag

MAX_COLS = 8192   # per-chunk K columns: bm·MAX_COLS·4B ≤ ~8MB VMEM at bm=256


def default_interpret() -> bool:
    """False (compiled Mosaic) on TPU, True (interpreter) elsewhere.

    Overridable with REPRO_PALLAS_INTERPRET=0/1 for A/B runs."""
    return env_flag("REPRO_PALLAS_INTERPRET", jax.default_backend() != "tpu")


def autotune_blocks(R: int, N: int, d: int, m: int, dtype,
                    *, interpret: bool | None = None) -> tuple[int, int]:
    """(bm, bd) for the gather→GEMM kernel: measured-cache hit → static table
    hit → VMEM-budget heuristic.

    This is the TABLE side only — it never times anything, so it is safe at
    trace time.  The entry points below measure candidate tilings on their
    real (concrete) arrays via ``autotune.measured_blocks`` and persist the
    winner, which this lookup then serves to every later (including jitted)
    call at the same (shape, dtype, backend) key.

    Heuristic: keep the K tile ≤ ~8 MiB of VMEM (bm·min(N, MAX_COLS)·itemsize)
    and make the GEMM lane dimension as wide as d allows (≤ 128 lanes)."""
    if interpret is None:
        interpret = default_interpret()
    hit = autotune.lookup("accum_apply", (R, N, d, m), dtype, interpret,
                          arity=2)
    if hit is not None:
        return hit
    key = (R, N, d, m, jnp.dtype(dtype).name)
    if key in autotune.STATIC_TABLE:
        return autotune.STATIC_TABLE[key]
    itemsize = jnp.dtype(dtype).itemsize
    ncols = min(N, MAX_COLS)
    bm = max(8, min(256, (8 * 1024 * 1024) // max(ncols * itemsize, 1)))
    bd = min(d, 128)
    return bm, bd


def _gemm_candidates(R: int, d: int, fallback: tuple[int, int]) -> list[tuple[int, int]]:
    """Candidate (bm, bd) tilings for the gather→GEMM family: the fallback
    plus a taller and a shorter row tile (the lane dimension is d-bound)."""
    bds = {fallback[1], min(d, 64), min(d, 128)}
    bms = {fallback[0], min(R, 128), min(R, 512)}
    cands = [(bm, bd) for bm in sorted(bms) for bd in sorted(bds)
             if bm >= 8 and bd >= 1]
    return cands[:6]


def _pad_rows(K: jax.Array, mult: int) -> jax.Array:
    pad = (-K.shape[0]) % mult
    return jnp.pad(K, ((0, pad), (0, 0))) if pad else K


def _pad_sketch(idx: jax.Array, coef: jax.Array, mult: int):
    """Pad sketch columns to a multiple of ``mult`` with idx 0 / coef 0 —
    zero-coefficient columns gather nothing and are sliced off the output."""
    pad = (-idx.shape[1]) % mult
    if pad:
        idx = jnp.pad(idx, ((0, 0), (0, pad)))
        coef = jnp.pad(coef, ((0, 0), (0, pad)))
    return idx, coef


def _apply_padded(K, idx, coef, *, bm, bd, interpret):
    """accum_apply on arbitrary (R, d): pad to the block grid, slice back."""
    R, _ = K.shape
    d = idx.shape[1]
    bm_e = min(bm, R)
    bd_e = min(bd, d)
    Kp = _pad_rows(K, bm_e)
    idx_p, coef_p = _pad_sketch(idx, coef, bd_e)
    out = accum_apply(Kp, idx_p, coef_p, bm=bm_e, bd=bd_e, interpret=interpret)
    return out[:R, :d]


def sketch_right_kernel(
    K: jax.Array, sk: AccumSketch, *, bm: int | None = None,
    bd: int | None = None, interpret: bool | None = None,
) -> jax.Array:
    """K S via the Pallas kernel; wide K is `lax.scan`ned over column chunks
    and the f32 partial products summed (the paper's accumulation identity).
    The scan keeps the jaxpr a single pallas_call regardless of N."""
    faults.fault_point("kernel.dispatch")
    if interpret is None:
        interpret = default_interpret()
    R, N = K.shape
    m, d = sk.indices.shape
    coef = sk.coef.astype(jnp.float32)
    if bm is None and bd is None:
        fb = autotune_blocks(R, N, d, m, K.dtype, interpret=interpret)
        # measure only the single-launch regime — the wide-K scan re-enters
        # this function per chunk and would nest measurements
        bm, bd = autotune.measured_blocks(
            "accum_apply", (R, N, d, m), K.dtype, interpret,
            _gemm_candidates(R, d, fb) if N <= MAX_COLS else [],
            lambda c: _apply_padded(K, sk.indices, coef, bm=c[0], bd=c[1],
                                    interpret=interpret),
            fb, concrete=autotune.is_concrete(K, sk.indices, coef))
    else:
        a_bm, a_bd = autotune_blocks(R, N, d, m, K.dtype, interpret=interpret)
        bm = a_bm if bm is None else bm
        bd = a_bd if bd is None else bd
    if N <= MAX_COLS:
        return _apply_padded(K, sk.indices, coef, bm=bm, bd=bd,
                             interpret=interpret)

    def _chunk_sketch(lo, hi):
        # indices outside [lo, hi) are redirected to column 0 with
        # coefficient 0 — the partial products then sum to the exact result
        inside = (sk.indices >= lo) & (sk.indices < hi)
        idx_c = jnp.where(inside, sk.indices - lo, 0).astype(jnp.int32)
        coef_c = jnp.where(inside, coef, 0.0)
        return idx_c, coef_c

    def body(acc, lo):
        idx_c, coef_c = _chunk_sketch(lo, lo + MAX_COLS)
        Kc = jax.lax.dynamic_slice_in_dim(K, lo, MAX_COLS, axis=1)
        part = _apply_padded(Kc, idx_c, coef_c, bm=bm, bd=bd,
                             interpret=interpret)
        return acc + part.astype(jnp.float32), None

    # scan the full-width chunks of K in place (no padded copy of K — this is
    # exactly the path where K is too big to duplicate), then fold in the
    # ragged tail chunk with one extra call
    nfull = N // MAX_COLS
    los = jnp.arange(nfull, dtype=jnp.int32) * MAX_COLS
    acc, _ = jax.lax.scan(body, jnp.zeros((R, d), jnp.float32), los)
    if N % MAX_COLS:
        lo = nfull * MAX_COLS
        idx_c, coef_c = _chunk_sketch(lo, N)
        acc = acc + _apply_padded(K[:, lo:], idx_c, coef_c, bm=bm, bd=bd,
                                  interpret=interpret).astype(jnp.float32)
    return acc.astype(K.dtype)


def sketch_left_kernel(
    sk: AccumSketch, M: jax.Array, *, bn: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Sᵀ M (d, c) via the true left-apply kernel, M streamed in row tiles.

    The earlier implementation computed (Mᵀ S)ᵀ, materializing Mᵀ — an
    O(n·c) transposed copy in a column-major layout the row-tiled kernel was
    never tuned for.  ``accum_apply_left`` keeps M row-major and accumulates
    the (d, c) output across row tiles instead.  Returns float32 (the output
    feeds d×d solves)."""
    faults.fault_point("kernel.dispatch")
    if interpret is None:
        interpret = default_interpret()
    N, c = M.shape
    d = sk.d
    coef = sk.coef.astype(jnp.float32)
    if bn is None:
        # row tile bounded by ~8 MiB of VMEM for the M tile; the interpreter
        # wants few large steps (per-step dispatch dominates there)
        bn = min(4096 if interpret else 2048,
                 max(8, (2 * 1024 * 1024) // max(c, 1)))
    bn_e = min(bn, N)
    Mp = _pad_rows(M, bn_e)
    idx_p, coef_p = _pad_sketch(sk.indices, coef, min(8, max(d, 1)))
    out = accum_apply_left(Mp, idx_p, coef_p, bn=bn_e, interpret=interpret)
    return out[:d]


def sketch_step_kernel(
    K: jax.Array, idx_row: jax.Array, coef_row: jax.Array, C: jax.Array,
    a: jax.Array, *, bm: int | None = None, bd: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Single-slab accumulate entry point: a·C + K·T̃ for one sub-sampling
    matrix described by ``idx_row``/``coef_row`` of shape (d,).

    The progressive engine's m → m+1 increment routes here so the column
    gather hits the MXU gather→GEMM path with the running C's rescale fused
    in.  Arbitrary shapes are padded to the block grid and sliced back; K
    wider than ``MAX_COLS`` falls back to the chunk-scanned ``accum_apply``
    for the gather and applies the rescale outside the kernel."""
    if interpret is None:
        interpret = default_interpret()
    R, N = K.shape
    d = idx_row.shape[0]
    a_bm, a_bd = autotune_blocks(R, N, d, 1, K.dtype, interpret=interpret)
    bm = a_bm if bm is None else bm
    bd = a_bd if bd is None else bd
    coef32 = coef_row.astype(jnp.float32)
    a_arr = jnp.asarray(a, jnp.float32).reshape((1,))
    if N > MAX_COLS:
        # chunk-scan path: reuse the wide-K machinery on a one-slab sketch
        one = AccumSketch(
            indices=idx_row[None, :].astype(jnp.int32),
            signs=jnp.sign(coef32)[None, :], probs=jnp.full((N,), 1.0 / N,
                                                            jnp.float32),
            n=N, coef_=coef32[None, :])
        G = sketch_right_kernel(K, one, bm=bm, bd=bd, interpret=interpret)
        return a_arr[0] * C + G.astype(C.dtype)
    bm_e = min(bm, R)
    bd_e = min(bd, d)
    Kp = _pad_rows(K, bm_e)
    Cp = _pad_rows(C, bm_e)
    idx_p, coef_p = _pad_sketch(idx_row[None, :].astype(jnp.int32),
                                coef32[None, :], bd_e)
    dpad = idx_p.shape[1] - d
    if dpad:
        Cp = jnp.pad(Cp, ((0, 0), (0, dpad)))
    out = accum_step_slab(Kp, idx_p, coef_p, Cp, a_arr, bm=bm_e, bd=bd_e,
                          interpret=interpret)
    return out[:R, :d]


def accum_grow_kernel(
    K: jax.Array, idx_blk: jax.Array, coef_blk: jax.Array, C: jax.Array,
    a: jax.Array, *, bm: int | None = None, bn: int | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Batched rank-B accumulate entry point: fold the B-slab batch block
    (idx/coef of shape (B, d), coefficients at the grown normalization) into
    the running C in ONE sweep over K, returning ``(C_new, TᵀG, TᵀC)`` with
    C_new = a·C + K·T and both d×d W pieces folded from the same pass —
    K is read once for all B slabs where B sequential ``sketch_step_kernel``
    calls read it B times.

    Arbitrary (R, N, d) are padded to the block grid and sliced back (padded
    rows/columns of K are zero and padded sketch columns carry coefficient 0,
    so every output is exact).  Block sizes come from the measured autotune
    cache when available."""
    if interpret is None:
        interpret = default_interpret()
    R, N = K.shape
    B, d = idx_blk.shape
    coef32 = coef_blk.astype(jnp.float32)
    a_arr = jnp.asarray(a, jnp.float32).reshape((1,))
    idx32 = idx_blk.astype(jnp.int32)

    def run(blocks):
        bm_e, bn_e = min(blocks[0], R), min(blocks[1], N)
        rpad, cpad = (-R) % bm_e, (-N) % bn_e
        Kp = jnp.pad(K, ((0, rpad), (0, cpad))) if (rpad or cpad) else K
        idx_p, coef_p = _pad_sketch(idx32, coef32, min(8, max(d, 1)))
        dpad = idx_p.shape[1] - d
        Cp = _pad_rows(C, bm_e)
        if dpad:
            Cp = jnp.pad(Cp, ((0, 0), (0, dpad)))
        Cn, TtG, TtC = accum_grow_slabs(Kp, idx_p, coef_p, Cp, a_arr,
                                        bm=bm_e, bn=bn_e, interpret=interpret)
        return Cn[:R, :d], TtG[:d, :d], TtC[:d, :d]

    if bm is None and bn is None:
        fb = autotune_both_blocks(N, interpret)
        bm, bn = autotune.measured_blocks(
            "accum_grow", (R, N, d, B), K.dtype, interpret,
            [fb, (256, min(N, 2048)), (min(R, 1024), min(N, 4096))],
            run, fb, concrete=autotune.is_concrete(K, idx_blk, coef_blk, C))
    else:
        fb = autotune_both_blocks(N, interpret)
        bm = fb[0] if bm is None else bm
        bn = fb[1] if bn is None else bn
    return run((bm, bn))


def expand_coef(coef: jax.Array, d: int) -> jax.Array:
    """(m, d) combination coefficients → the (m·d, d) block-sparse matrix Cmat
    with Cmat[i·d + j, j] = coef[i, j], so that S = E·Cmat for the (n, m·d)
    landmark selection matrix E and K S = K(·, landmarks)·Cmat.  Zero rows
    (padding) select nothing."""
    m = coef.shape[0]
    md = m * d
    cols = jnp.tile(jnp.arange(d), m)
    return (
        jnp.zeros((md, d), jnp.float32)
        .at[jnp.arange(md), cols]
        .set(coef.reshape(-1).astype(jnp.float32))
    )


def matfree_cols_kernel(
    Xq: jax.Array, landmarks: jax.Array, coef: jax.Array, *, kernel: str,
    bandwidth: float = 1.0, nu: float = 1.5, bm: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """C = K(Xq, X)·S straight from data rows via the fused Pallas kernel —
    the (tile, m·d) kernel block is evaluated in VMEM and contracted with the
    coefficient block in the same grid step; no n×n object ever exists.

    Xq: (nq, p) query rows; landmarks: (m·d, p) sampled rows X[sk.indices];
    coef: (m, d).  Arbitrary nq is row-padded to the tile and sliced back;
    the landmark count is sublane-padded with zero rows (zero coefficient
    rows contribute nothing).  Returns (nq, d) float32."""
    faults.fault_point("kernel.dispatch")
    if interpret is None:
        interpret = default_interpret()
    nq, p = Xq.shape
    m, d = coef.shape
    Cmat = expand_coef(coef, d)
    pad_md = (-(m * d)) % 8
    if pad_md:
        landmarks = jnp.pad(landmarks, ((0, pad_md), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, pad_md), (0, 0)))
    pad_d = (-d) % 8
    if pad_d:
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad_d)))

    def run(blocks):
        bm_e = min(blocks[0], nq)
        Xp = _pad_rows(Xq, bm_e)
        out = matfree_apply(Xp, landmarks, Cmat, kernel=kernel,
                            bandwidth=bandwidth, nu=nu, bm=bm_e,
                            interpret=interpret)
        return out[:nq, :d]

    if bm is None:
        # heuristic fallback: keep the f32 (bm, md) kernel slab + (bm, p)
        # tile ≲ 8 MiB of VMEM
        fb = (max(8, min(1024, (2 * 1024 * 1024) // max(m * d + p, 1))),)
        (bm,) = autotune.measured_blocks(
            "matfree_cols", (nq, p, d, m, kernel), Xq.dtype, interpret,
            [fb, (min(nq, 256),), (min(nq, 1024),)], run, fb,
            concrete=autotune.is_concrete(Xq, landmarks, coef))
    return run((bm,))


def autotune_both_blocks(n: int, interpret: bool, d: int = 0, m: int = 0,
                         dtype=jnp.float32) -> tuple[int, int]:
    """(bm, bn) for the fused single-sweep kernels: measured-cache hit first
    (when ``d``/``m`` identify the shape), else the PR-1 defaults — compiled
    TPU wants VMEM-sized tiles (bm·bn·4B ≤ 2 MiB); the interpreter wants few,
    large grid steps (per-step dispatch dominates there — measured 3–4× on
    the CPU benchmark host)."""
    if d and m:
        hit = autotune.lookup("sketch_both", (n, d, m), dtype, interpret,
                              arity=2)
        if hit is not None:
            return hit
    if interpret:
        return min(2048, n), min(4096, n)
    return 256, 2048


def sketch_both_kernel(
    K: jax.Array, sk: AccumSketch, *, bm: int | None = None,
    bn: int | None = None, interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused (C, W) = (K S, SᵀK S) in one sweep over square K (n, n).

    W accumulates across grid steps in the kernel — no second pass over C and
    no second HBM read. Arbitrary n and d are padded to the block grid (padded
    S rows are never indexed, so W is exact) and sliced back. W is float32."""
    faults.fault_point("kernel.dispatch")
    if interpret is None:
        interpret = default_interpret()
    n, n2 = K.shape
    assert n == n2, "sketch_both_kernel expects square K"
    d = sk.d
    coef = sk.coef.astype(jnp.float32)
    idx_p, coef_p = _pad_sketch(sk.indices, coef, min(8, max(sk.d, 1)))

    def run(blocks):
        bm_e, bn_e = min(blocks[0], n), min(blocks[1], n)
        # pad rows and columns of K to the (bm, bn) grid
        rpad, cpad = (-n) % bm_e, (-n) % bn_e
        Kp = jnp.pad(K, ((0, rpad), (0, cpad))) if (rpad or cpad) else K
        C, W = accum_sketch_both(Kp, idx_p, coef_p, bm=bm_e, bn=bn_e,
                                 interpret=interpret)
        return C[:n, :d], W[:d, :d]

    if bm is None and bn is None:
        fb = autotune_both_blocks(n, interpret, d, sk.m, K.dtype)
        blocks = autotune.measured_blocks(
            "sketch_both", (n, d, sk.m), K.dtype, interpret,
            [fb, (256, min(n, 2048)), (min(n, 1024), min(n, 4096))], run, fb,
            concrete=autotune.is_concrete(K, sk.indices, coef))
    else:
        fb = autotune_both_blocks(n, interpret, d, sk.m, K.dtype)
        blocks = (fb[0] if bm is None else bm, fb[1] if bn is None else bn)
    return run(blocks)
