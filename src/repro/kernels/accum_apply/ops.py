"""jit'd public wrapper for the accum_apply kernel: chunks wide K so each
Pallas tile fits VMEM, and exposes an AccumSketch-native entry point."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sketch import AccumSketch
from repro.kernels.accum_apply.kernel import accum_apply

MAX_COLS = 8192   # per-tile K columns: bm·MAX_COLS·4B ≤ ~8MB VMEM at bm=256


def sketch_right_kernel(
    K: jax.Array, sk: AccumSketch, *, bm: int = 256, bd: int = 8,
    interpret: bool = True,
) -> jax.Array:
    """K S via the Pallas kernel; splits K's columns into chunks and sums the
    per-chunk partial products (the paper's accumulation identity)."""
    R, N = K.shape
    coef = sk.coef.astype(jnp.float32)
    if N <= MAX_COLS:
        return accum_apply(K, sk.indices, coef, bm=bm, bd=bd, interpret=interpret)
    out = jnp.zeros((R, sk.d), K.dtype)
    for lo in range(0, N, MAX_COLS):
        hi = min(lo + MAX_COLS, N)
        # indices falling outside [lo, hi) are redirected to column 0 with
        # coefficient 0 — the partial products then sum to the exact result
        inside = (sk.indices >= lo) & (sk.indices < hi)
        idx_c = jnp.where(inside, sk.indices - lo, 0).astype(jnp.int32)
        coef_c = jnp.where(inside, coef, 0.0)
        out = out + accum_apply(K[:, lo:hi], idx_c, coef_c, bm=bm, bd=bd,
                                interpret=interpret).astype(out.dtype)
    return out
