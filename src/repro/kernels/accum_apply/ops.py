"""Public entry points for the accum_apply kernel family.

This layer makes the kernels shape- and backend-agnostic:

  * ``interpret`` defaults to backend autodetection — compiled Mosaic on TPU,
    interpreter everywhere else (CPU CI, tests).
  * block sizes come from a small autotune table keyed on
    (R, N, d, m, dtype) with a VMEM-budget heuristic fallback;
  * arbitrary shapes are zero-padded up to the block grid and sliced back
    (padded K rows/columns contribute nothing; padded sketch columns carry
    coef 0);
  * wide K is chunked along columns with ``jax.lax.scan`` so the jaxpr stays
    O(1) in the number of chunks — the seed's Python loop unrolled one
    pallas_call per chunk under jit;
  * ``sketch_both_kernel`` exposes the fused (K S, SᵀK S) single-sweep kernel,
    ``sketch_left_kernel`` applies Sᵀ M through the true left-apply kernel
    (M streamed in row tiles — no Mᵀ copy);
  * ``sketch_step_kernel`` is the single-slab accumulate entry point used by
    the progressive engine: a·C + K·T̃ in one fused launch (MXU path for the
    m → m+1 increment).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sketch import AccumSketch
from repro.kernels.accum_apply.kernel import (
    accum_apply,
    accum_apply_left,
    accum_sketch_both,
    accum_step_slab,
    matfree_apply,
)
from repro.util import env_flag

MAX_COLS = 8192   # per-chunk K columns: bm·MAX_COLS·4B ≤ ~8MB VMEM at bm=256


def default_interpret() -> bool:
    """False (compiled Mosaic) on TPU, True (interpreter) elsewhere.

    Overridable with REPRO_PALLAS_INTERPRET=0/1 for A/B runs."""
    return env_flag("REPRO_PALLAS_INTERPRET", jax.default_backend() != "tpu")


# Measured-good block sizes, keyed (R, N, d, m, dtype-name). N is the
# per-chunk width (≤ MAX_COLS). Fallback heuristic below.
_BLOCK_TABLE: dict[tuple[int, int, int, int, str], tuple[int, int]] = {
    (4096, 8192, 64, 4, "float32"): (256, 64),
    (4096, 8192, 64, 4, "bfloat16"): (256, 64),
    (8192, 8192, 64, 4, "float32"): (256, 64),
    (4096, 8192, 128, 4, "float32"): (256, 128),
    (4096, 4096, 64, 4, "float32"): (512, 64),
    (1024, 1024, 64, 4, "float32"): (256, 64),
}


def autotune_blocks(R: int, N: int, d: int, m: int, dtype) -> tuple[int, int]:
    """(bm, bd) for the gather→GEMM kernel: exact table hit, else heuristic.

    Heuristic: keep the K tile ≤ ~8 MiB of VMEM (bm·min(N, MAX_COLS)·itemsize)
    and make the GEMM lane dimension as wide as d allows (≤ 128 lanes)."""
    key = (R, N, d, m, jnp.dtype(dtype).name)
    if key in _BLOCK_TABLE:
        return _BLOCK_TABLE[key]
    itemsize = jnp.dtype(dtype).itemsize
    ncols = min(N, MAX_COLS)
    bm = max(8, min(256, (8 * 1024 * 1024) // max(ncols * itemsize, 1)))
    bd = min(d, 128)
    return bm, bd


def _pad_rows(K: jax.Array, mult: int) -> jax.Array:
    pad = (-K.shape[0]) % mult
    return jnp.pad(K, ((0, pad), (0, 0))) if pad else K


def _pad_sketch(idx: jax.Array, coef: jax.Array, mult: int):
    """Pad sketch columns to a multiple of ``mult`` with idx 0 / coef 0 —
    zero-coefficient columns gather nothing and are sliced off the output."""
    pad = (-idx.shape[1]) % mult
    if pad:
        idx = jnp.pad(idx, ((0, 0), (0, pad)))
        coef = jnp.pad(coef, ((0, 0), (0, pad)))
    return idx, coef


def _apply_padded(K, idx, coef, *, bm, bd, interpret):
    """accum_apply on arbitrary (R, d): pad to the block grid, slice back."""
    R, _ = K.shape
    d = idx.shape[1]
    bm_e = min(bm, R)
    bd_e = min(bd, d)
    Kp = _pad_rows(K, bm_e)
    idx_p, coef_p = _pad_sketch(idx, coef, bd_e)
    out = accum_apply(Kp, idx_p, coef_p, bm=bm_e, bd=bd_e, interpret=interpret)
    return out[:R, :d]


def sketch_right_kernel(
    K: jax.Array, sk: AccumSketch, *, bm: int | None = None,
    bd: int | None = None, interpret: bool | None = None,
) -> jax.Array:
    """K S via the Pallas kernel; wide K is `lax.scan`ned over column chunks
    and the f32 partial products summed (the paper's accumulation identity).
    The scan keeps the jaxpr a single pallas_call regardless of N."""
    if interpret is None:
        interpret = default_interpret()
    R, N = K.shape
    m, d = sk.indices.shape
    a_bm, a_bd = autotune_blocks(R, N, d, m, K.dtype)
    bm = a_bm if bm is None else bm
    bd = a_bd if bd is None else bd
    coef = sk.coef.astype(jnp.float32)
    if N <= MAX_COLS:
        return _apply_padded(K, sk.indices, coef, bm=bm, bd=bd,
                             interpret=interpret)

    def _chunk_sketch(lo, hi):
        # indices outside [lo, hi) are redirected to column 0 with
        # coefficient 0 — the partial products then sum to the exact result
        inside = (sk.indices >= lo) & (sk.indices < hi)
        idx_c = jnp.where(inside, sk.indices - lo, 0).astype(jnp.int32)
        coef_c = jnp.where(inside, coef, 0.0)
        return idx_c, coef_c

    def body(acc, lo):
        idx_c, coef_c = _chunk_sketch(lo, lo + MAX_COLS)
        Kc = jax.lax.dynamic_slice_in_dim(K, lo, MAX_COLS, axis=1)
        part = _apply_padded(Kc, idx_c, coef_c, bm=bm, bd=bd,
                             interpret=interpret)
        return acc + part.astype(jnp.float32), None

    # scan the full-width chunks of K in place (no padded copy of K — this is
    # exactly the path where K is too big to duplicate), then fold in the
    # ragged tail chunk with one extra call
    nfull = N // MAX_COLS
    los = jnp.arange(nfull, dtype=jnp.int32) * MAX_COLS
    acc, _ = jax.lax.scan(body, jnp.zeros((R, d), jnp.float32), los)
    if N % MAX_COLS:
        lo = nfull * MAX_COLS
        idx_c, coef_c = _chunk_sketch(lo, N)
        acc = acc + _apply_padded(K[:, lo:], idx_c, coef_c, bm=bm, bd=bd,
                                  interpret=interpret).astype(jnp.float32)
    return acc.astype(K.dtype)


def sketch_left_kernel(
    sk: AccumSketch, M: jax.Array, *, bn: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Sᵀ M (d, c) via the true left-apply kernel, M streamed in row tiles.

    The earlier implementation computed (Mᵀ S)ᵀ, materializing Mᵀ — an
    O(n·c) transposed copy in a column-major layout the row-tiled kernel was
    never tuned for.  ``accum_apply_left`` keeps M row-major and accumulates
    the (d, c) output across row tiles instead.  Returns float32 (the output
    feeds d×d solves)."""
    if interpret is None:
        interpret = default_interpret()
    N, c = M.shape
    d = sk.d
    coef = sk.coef.astype(jnp.float32)
    if bn is None:
        # row tile bounded by ~8 MiB of VMEM for the M tile; the interpreter
        # wants few large steps (per-step dispatch dominates there)
        bn = min(4096 if interpret else 2048,
                 max(8, (2 * 1024 * 1024) // max(c, 1)))
    bn_e = min(bn, N)
    Mp = _pad_rows(M, bn_e)
    idx_p, coef_p = _pad_sketch(sk.indices, coef, min(8, max(d, 1)))
    out = accum_apply_left(Mp, idx_p, coef_p, bn=bn_e, interpret=interpret)
    return out[:d]


def sketch_step_kernel(
    K: jax.Array, idx_row: jax.Array, coef_row: jax.Array, C: jax.Array,
    a: jax.Array, *, bm: int | None = None, bd: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Single-slab accumulate entry point: a·C + K·T̃ for one sub-sampling
    matrix described by ``idx_row``/``coef_row`` of shape (d,).

    The progressive engine's m → m+1 increment routes here so the column
    gather hits the MXU gather→GEMM path with the running C's rescale fused
    in.  Arbitrary shapes are padded to the block grid and sliced back; K
    wider than ``MAX_COLS`` falls back to the chunk-scanned ``accum_apply``
    for the gather and applies the rescale outside the kernel."""
    if interpret is None:
        interpret = default_interpret()
    R, N = K.shape
    d = idx_row.shape[0]
    a_bm, a_bd = autotune_blocks(R, N, d, 1, K.dtype)
    bm = a_bm if bm is None else bm
    bd = a_bd if bd is None else bd
    coef32 = coef_row.astype(jnp.float32)
    a_arr = jnp.asarray(a, jnp.float32).reshape((1,))
    if N > MAX_COLS:
        # chunk-scan path: reuse the wide-K machinery on a one-slab sketch
        one = AccumSketch(
            indices=idx_row[None, :].astype(jnp.int32),
            signs=jnp.sign(coef32)[None, :], probs=jnp.full((N,), 1.0 / N,
                                                            jnp.float32),
            n=N, coef_=coef32[None, :])
        G = sketch_right_kernel(K, one, bm=bm, bd=bd, interpret=interpret)
        return a_arr[0] * C + G.astype(C.dtype)
    bm_e = min(bm, R)
    bd_e = min(bd, d)
    Kp = _pad_rows(K, bm_e)
    Cp = _pad_rows(C, bm_e)
    idx_p, coef_p = _pad_sketch(idx_row[None, :].astype(jnp.int32),
                                coef32[None, :], bd_e)
    dpad = idx_p.shape[1] - d
    if dpad:
        Cp = jnp.pad(Cp, ((0, 0), (0, dpad)))
    out = accum_step_slab(Kp, idx_p, coef_p, Cp, a_arr, bm=bm_e, bd=bd_e,
                          interpret=interpret)
    return out[:R, :d]


def expand_coef(coef: jax.Array, d: int) -> jax.Array:
    """(m, d) combination coefficients → the (m·d, d) block-sparse matrix Cmat
    with Cmat[i·d + j, j] = coef[i, j], so that S = E·Cmat for the (n, m·d)
    landmark selection matrix E and K S = K(·, landmarks)·Cmat.  Zero rows
    (padding) select nothing."""
    m = coef.shape[0]
    md = m * d
    cols = jnp.tile(jnp.arange(d), m)
    return (
        jnp.zeros((md, d), jnp.float32)
        .at[jnp.arange(md), cols]
        .set(coef.reshape(-1).astype(jnp.float32))
    )


def matfree_cols_kernel(
    Xq: jax.Array, landmarks: jax.Array, coef: jax.Array, *, kernel: str,
    bandwidth: float = 1.0, nu: float = 1.5, bm: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """C = K(Xq, X)·S straight from data rows via the fused Pallas kernel —
    the (tile, m·d) kernel block is evaluated in VMEM and contracted with the
    coefficient block in the same grid step; no n×n object ever exists.

    Xq: (nq, p) query rows; landmarks: (m·d, p) sampled rows X[sk.indices];
    coef: (m, d).  Arbitrary nq is row-padded to the tile and sliced back;
    the landmark count is sublane-padded with zero rows (zero coefficient
    rows contribute nothing).  Returns (nq, d) float32."""
    if interpret is None:
        interpret = default_interpret()
    nq, p = Xq.shape
    m, d = coef.shape
    if bm is None:
        # keep the f32 (bm, md) kernel slab + (bm, p) tile ≲ 8 MiB of VMEM
        bm = max(8, min(1024, (2 * 1024 * 1024) // max(m * d + p, 1)))
    bm_e = min(bm, nq)
    Xp = _pad_rows(Xq, bm_e)
    Cmat = expand_coef(coef, d)
    pad_md = (-(m * d)) % 8
    if pad_md:
        landmarks = jnp.pad(landmarks, ((0, pad_md), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, pad_md), (0, 0)))
    pad_d = (-d) % 8
    if pad_d:
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad_d)))
    out = matfree_apply(Xp, landmarks, Cmat, kernel=kernel, bandwidth=bandwidth,
                        nu=nu, bm=bm_e, interpret=interpret)
    return out[:nq, :d]


def autotune_both_blocks(n: int, interpret: bool) -> tuple[int, int]:
    """(bm, bn) for the fused kernel. Compiled TPU wants VMEM-sized tiles
    (bm·bn·4B ≤ 2 MiB); the interpreter wants few, large grid steps (per-step
    dispatch dominates there — measured 3–4× on the CPU benchmark host)."""
    if interpret:
        return min(2048, n), min(4096, n)
    return 256, 2048


def sketch_both_kernel(
    K: jax.Array, sk: AccumSketch, *, bm: int | None = None,
    bn: int | None = None, interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused (C, W) = (K S, SᵀK S) in one sweep over square K (n, n).

    W accumulates across grid steps in the kernel — no second pass over C and
    no second HBM read. Arbitrary n and d are padded to the block grid (padded
    S rows are never indexed, so W is exact) and sliced back. W is float32."""
    if interpret is None:
        interpret = default_interpret()
    n, n2 = K.shape
    assert n == n2, "sketch_both_kernel expects square K"
    d = sk.d
    coef = sk.coef.astype(jnp.float32)
    a_bm, a_bn = autotune_both_blocks(n, interpret)
    bm_e = min(a_bm if bm is None else bm, n)
    bn_e = min(a_bn if bn is None else bn, n)
    # pad rows and columns of K to the (bm, bn) grid; pad d to the lane tile
    rpad = (-n) % bm_e
    cpad = (-n) % bn_e
    Kp = jnp.pad(K, ((0, rpad), (0, cpad))) if (rpad or cpad) else K
    idx_p, coef_p = _pad_sketch(sk.indices, coef, min(8, max(sk.d, 1)))
    C, W = accum_sketch_both(Kp, idx_p, coef_p, bm=bm_e, bn=bn_e,
                             interpret=interpret)
    return C[:n, :d], W[:d, :d]
