"""Pure-jnp oracle for the accum_apply kernel: K S via gather-accumulate.

out[r, j] = Σ_{i<m} coef[i, j] · K[r, idx[i, j]]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def accum_apply_ref(K: jax.Array, idx: jax.Array, coef: jax.Array) -> jax.Array:
    """K: (R, N); idx: (m, d) int32 in [0, N); coef: (m, d). Returns (R, d)."""
    cols = jnp.take(K, idx.reshape(-1), axis=1)             # (R, m·d)
    cols = cols.reshape(K.shape[0], *idx.shape)             # (R, m, d)
    return jnp.einsum("rmd,md->rd", cols.astype(jnp.float32),
                      coef.astype(jnp.float32)).astype(K.dtype)


def matfree_cols_ref(
    X: jax.Array, idx: jax.Array, coef: jax.Array, kernel_fn
) -> jax.Array:
    """Oracle for the matrix-free fused kernel: C = K(X, X)·S evaluated as the
    (n, m·d) kernel slab against the gathered landmarks, contracted with the
    combination coefficients.  One jnp pass, no chunking — CPU/interpret
    reference only (materializes the full slab).

    kernel_fn(A, B) -> (|A|, |B|) kernel matrix (``core.kernels_math``)."""
    landmarks = jnp.take(X, idx.reshape(-1), axis=0)        # (m·d, p)
    slab = kernel_fn(X, landmarks).astype(jnp.float32)      # (n, m·d)
    slab = slab.reshape(X.shape[0], *idx.shape)             # (n, m, d)
    return jnp.einsum("nmd,md->nd", slab, coef.astype(jnp.float32))


def sketch_both_ref(
    K: jax.Array, idx: jax.Array, coef: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Oracle for the fused kernel: C = K S, W = Sᵀ C (row gather of C).

    W is derived from the float32 C — the fused kernel folds SᵀC from its f32
    VMEM accumulator *before* casting C to the storage dtype, so the oracle
    must not round C first. Returns (C in K.dtype, W in float32)."""
    C32 = accum_apply_ref(K.astype(jnp.float32), idx, coef)
    rows = jnp.take(C32, idx.reshape(-1), axis=0)
    rows = rows.reshape(*idx.shape, C32.shape[1])           # (m, d, d)
    W = jnp.einsum("mdc,md->dc", rows, coef.astype(jnp.float32))
    return C32.astype(K.dtype), W


def accum_grow_ref(
    K: jax.Array, idx: jax.Array, coef: jax.Array, Cin: jax.Array, a: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Oracle for the batched-growth kernel: fold a B-slab batch block T
    (idx/coef of shape (B, d), coefficients at the GROWN normalization) into
    the running C with survivor rescale ``a``, and return the two d×d W
    pieces derived from the same G = K·T:

        C_new = a·Cin + G,   TᵀG = Tᵀ K T,   TᵀC = Tᵀ Cin.

    All three in float32 off the f32 G, matching the fused kernel's VMEM
    accumulator (the caller assembles W_new = a²W + a(TᵀC + TᵀCᵀ) + TᵀG)."""
    G = accum_apply_ref(K.astype(jnp.float32), idx, coef)
    C_new = jnp.asarray(a, jnp.float32) * Cin.astype(jnp.float32) + G
    TtG = sketch_left_ref(idx, coef, G)
    TtC = sketch_left_ref(idx, coef, Cin)
    return C_new.astype(Cin.dtype), TtG, TtC


def sketch_left_ref(idx: jax.Array, coef: jax.Array, M: jax.Array) -> jax.Array:
    """Oracle for the left-apply kernel: Sᵀ M via row gather + contraction.

    out[j, :] = Σ_{i<m} coef[i, j] · M[idx[i, j], :].  Returns float32."""
    rows = jnp.take(M, idx.reshape(-1), axis=0)             # (m·d, c)
    rows = rows.reshape(*idx.shape, M.shape[-1])            # (m, d, c)
    return jnp.einsum("mdc,md->dc", rows.astype(jnp.float32),
                      coef.astype(jnp.float32))
