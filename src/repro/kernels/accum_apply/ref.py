"""Pure-jnp oracle for the accum_apply kernel: K S via gather-accumulate.

out[r, j] = Σ_{i<m} coef[i, j] · K[r, idx[i, j]]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def accum_apply_ref(K: jax.Array, idx: jax.Array, coef: jax.Array) -> jax.Array:
    """K: (R, N); idx: (m, d) int32 in [0, N); coef: (m, d). Returns (R, d)."""
    cols = jnp.take(K, idx.reshape(-1), axis=1)             # (R, m·d)
    cols = cols.reshape(K.shape[0], *idx.shape)             # (R, m, d)
    return jnp.einsum("rmd,md->rd", cols.astype(jnp.float32),
                      coef.astype(jnp.float32)).astype(K.dtype)
