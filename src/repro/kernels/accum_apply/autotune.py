"""Measured autotune cache for the accum_apply kernel family.

PR 1's block sizes came from a hand-maintained static table keyed on exact
shapes, with a VMEM-budget heuristic for everything else — fine for the
benchmark anchors, wrong for any shape nobody measured.  This module replaces
that with a MEASURED cache:

  * the first eligible call at a (kernel, shape, dtype, backend) key times the
    candidate tilings once on the caller's real arrays and keeps the winner;
  * winners persist to a JSON cache (``REPRO_AUTOTUNE_CACHE``, default
    ``~/.cache/repro/autotune.json``) so later processes skip the measurement;
  * a corrupt, missing, or unwritable cache degrades silently to the static
    table / heuristic — autotuning must never be able to break a run.

Measurement only happens when it can be meaningful:

  * the entry point's arrays must be CONCRETE (under ``jit`` tracing the
    inputs are tracers and nothing can be timed — the cache/table answer is
    used instead, so jitted callers compile against the persisted winner);
  * ``REPRO_AUTOTUNE`` gates it (default: on for compiled TPU kernels, off in
    interpret mode, where timings measure the interpreter's dispatch, not the
    tiling — benchmarks force it on explicitly for the cold/warm numbers).

All reads go through ``os.environ`` at call time so tests can monkeypatch the
cache location and the gate without reloads.
"""
from __future__ import annotations

import json
import os
import pathlib
import time

import jax

from repro.util import env_flag

ENV_CACHE = "REPRO_AUTOTUNE_CACHE"
ENV_GATE = "REPRO_AUTOTUNE"

# Measured-good block sizes from the PR-1 benchmark host, keyed
# (R, N, d, m, dtype-name) — the FALLBACK when the measured cache has no
# entry and measurement is gated off (tracing, interpret mode, disabled).
STATIC_TABLE: dict[tuple[int, int, int, int, str], tuple[int, int]] = {
    (4096, 8192, 64, 4, "float32"): (256, 64),
    (4096, 8192, 64, 4, "bfloat16"): (256, 64),
    (8192, 8192, 64, 4, "float32"): (256, 64),
    (4096, 8192, 128, 4, "float32"): (256, 128),
    (4096, 4096, 64, 4, "float32"): (512, 64),
    (1024, 1024, 64, 4, "float32"): (256, 64),
}

# in-memory mirror of the JSON file, keyed by cache path so tests that
# repoint REPRO_AUTOTUNE_CACHE never see another file's entries
_MEM: dict[str, dict[str, list[int]]] = {}


def cache_path() -> pathlib.Path:
    env = os.environ.get(ENV_CACHE)
    if env:
        return pathlib.Path(env)
    return pathlib.Path.home() / ".cache" / "repro" / "autotune.json"


def measure_enabled() -> bool:
    """Measure by default only where timings are meaningful: compiled TPU.
    Interpret-mode timings rank interpreter dispatch, not tilings.
    Override with REPRO_AUTOTUNE=0/1."""
    return env_flag(ENV_GATE, jax.default_backend() == "tpu")


def _load(path: pathlib.Path) -> dict[str, list[int]]:
    key = str(path)
    if key in _MEM:
        return _MEM[key]
    from repro.resilience import faults

    entries: dict[str, list[int]] = {}
    try:
        faults.fault_point("autotune.load")  # simulated unreadable cache file
        raw = json.loads(path.read_text())
        # validate hard: a corrupt cache must fall back, not crash
        if isinstance(raw, dict):
            for k, v in raw.items():
                if (isinstance(k, str) and isinstance(v, list)
                        and all(isinstance(x, int) and x > 0 for x in v)):
                    entries[k] = v
    except FileNotFoundError:
        entries = {}  # a missing cache is the normal cold start, not a fault
    except faults.DeviceLost:
        raise  # simulated preemption is fatal, not a degradation
    except (OSError, ValueError, faults.FaultInjected) as e:
        # corrupt/unreadable cache: fall back to the static table — but
        # recorded, not silent (a fleet quietly losing its tunings is an
        # operational smell worth surfacing)
        from repro.resilience.degrade import global_health

        entries = {}
        global_health().record(
            "autotune.load", rung_from="measured-cache", rung_to="static-table",
            detail=repr(e),
        )
    _MEM[key] = entries
    return entries


def _store(path: pathlib.Path, entries: dict[str, list[int]]) -> None:
    """Best-effort atomic persist; an unwritable cache dir is not an error."""
    try:
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_name(path.name + f".tmp{os.getpid()}")
        tmp.write_text(json.dumps(entries, indent=2, sort_keys=True) + "\n")
        tmp.replace(path)
    except OSError:
        pass


def _key(kind: str, shape_key: tuple, dtype, interpret: bool) -> str:
    backend = jax.default_backend() + ("/interpret" if interpret else "")
    parts = [kind, *map(str, shape_key), jax.numpy.dtype(dtype).name, backend]
    return "|".join(parts)


def lookup(kind: str, shape_key: tuple, dtype, interpret: bool,
           arity: int | None = None) -> tuple[int, ...] | None:
    """The persisted winner for this key, or None (missing/corrupt cache).
    ``arity`` rejects entries of the wrong length — a hand-edited or
    stale-schema entry must fall back, not crash the caller's unpack."""
    entry = _load(cache_path()).get(_key(kind, shape_key, dtype, interpret))
    if not entry or (arity is not None and len(entry) != arity):
        return None
    return tuple(entry)


def record(kind: str, shape_key: tuple, dtype, interpret: bool,
           blocks: tuple[int, ...]) -> None:
    path = cache_path()
    entries = dict(_load(path))
    entries[_key(kind, shape_key, dtype, interpret)] = [int(b) for b in blocks]
    _MEM[str(path)] = entries
    _store(path, entries)


def _time_once(fn) -> float:
    """One warmup (compile) + one timed rep; failures rank last."""
    try:
        jax.block_until_ready(fn())
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        return time.perf_counter() - t0
    except Exception:
        return float("inf")


def measured_blocks(
    kind: str, shape_key: tuple, dtype, interpret: bool,
    candidates: list[tuple[int, ...]], bench_fn, fallback: tuple[int, ...],
    concrete: bool,
) -> tuple[int, ...]:
    """The autotune decision for one kernel call site.

    Resolution order: persisted/measured cache hit → (if ``concrete`` inputs
    and the gate allows) time ``bench_fn(blocks)`` for each candidate once,
    persist and return the winner → ``fallback`` (the static table /
    heuristic answer).  ``bench_fn`` runs the caller's actual kernel on its
    actual arrays, so the measurement is of the real workload."""
    hit = lookup(kind, shape_key, dtype, interpret, arity=len(fallback))
    if hit is not None:
        return hit
    if not concrete or not measure_enabled() or not candidates:
        return fallback
    candidates = list(dict.fromkeys(candidates))
    timings = [(_time_once(lambda c=c: bench_fn(c)), c) for c in candidates]
    best_t, best = min(timings, key=lambda tc: tc[0])
    if best_t == float("inf"):
        return fallback
    record(kind, shape_key, dtype, interpret, best)
    return best


def is_concrete(*arrays) -> bool:
    """True iff no argument is a tracer — the only situation where timing the
    kernel on the caller's arrays is possible."""
    return not any(isinstance(a, jax.core.Tracer) for a in arrays)
