"""Pallas TPU kernel: fused gather-accumulate K·S for accumulation sketches.

TPU adaptation (DESIGN.md §3): instead of a CPU-style sparse SpMM, the kernel
tiles K's rows into VMEM blocks and, for each output tile, accumulates the m
sub-sketches in VREGs. The sketch indices/coefs ride in as scalar-prefetch
operands (SMEM) so the column gather addresses are known before the tile loop
— the Pallas analogue of the paper's "few extra matrix additions".

Grid: (R/bm, d/bd). Per step:
  K block   (bm, N)  — rows resident in VMEM (wrapper chunks N when large)
  out block (bm, bd) — accumulated over m picks per output column
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, coef_ref, K_ref, out_ref, *, m: int, bd: int):
    j0 = pl.program_id(1) * bd
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    for jj in range(bd):                       # static loop over tile columns
        col_acc = jnp.zeros((K_ref.shape[0],), jnp.float32)
        for i in range(m):                     # accumulate the m sub-sketches
            c = coef_ref[i, j0 + jj]
            src = idx_ref[i, j0 + jj]
            col = pl.load(K_ref, (slice(None), pl.dslice(src, 1)))  # (bm, 1)
            col_acc = col_acc + c.astype(jnp.float32) * col[:, 0].astype(jnp.float32)
        acc = acc.at[:, jj].set(col_acc)
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bd", "interpret"))
def accum_apply(
    K: jax.Array, idx: jax.Array, coef: jax.Array, *,
    bm: int = 256, bd: int = 8, interpret: bool = True,
) -> jax.Array:
    """K: (R, N); idx/coef: (m, d). Returns K S (R, d).

    VMEM budget: bm × N × itemsize per K tile — the ops.py wrapper splits N
    into ≤8k-column chunks and sums partial results (addition commutes with
    the accumulation, same identity the paper uses)."""
    R, N = K.shape
    m, d = idx.shape
    bm = min(bm, R)
    bd = min(bd, d)
    assert R % bm == 0 and d % bd == 0, (R, bm, d, bd)
    grid = (R // bm, d // bd)
    return pl.pallas_call(
        functools.partial(_kernel, m=m, bd=bd),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,             # idx, coef in SMEM
            grid=grid,
            in_specs=[pl.BlockSpec((bm, N), lambda r, j, *_: (r, 0))],
            out_specs=pl.BlockSpec((bm, bd), lambda r, j, *_: (r, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((R, d), K.dtype),
        interpret=interpret,
    )(idx, coef, K)
