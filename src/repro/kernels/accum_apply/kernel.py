"""Pallas TPU kernels: vectorized gather→GEMM accumulation-sketch application.

Design (this file supersedes the seed's scalar-gather loop, kept below as
``accum_apply_scalar`` for benchmarking):

The accumulation sketch S = Σ_i S_(i) has m non-zeros per column, described by
``idx``/``coef`` of shape (m, d).  The seed kernel applied K·S one column and
one sub-sketch at a time with ``pl.load`` scalar gathers — O(m·d) serial VMEM
loads per tile, no MXU use.  The rewrite turns the sparse application into a
dense GEMM the MXU can chew on:

  1. per output tile, materialize the (N, bd) *coefficient block* of S in VMEM
     by comparing a broadcasted row-iota against the prefetched indices
     (one-hot build: m vectorized compares, no scatter);
  2. contract K_tile (bm, N) with that block via ``jax.lax.dot_general`` with
     ``preferred_element_type=float32`` — a (bm, N) × (N, bd) MXU matmul.

The index/coef slices still ride in via scalar prefetch (SMEM) so they are
resident before the tile loop, as in the seed.

``accum_sketch_both`` fuses the two sketch applications of the paper's §3.3,

    C = K S          (n, d)
    W = Sᵀ K S = SᵀC (d, d)

into ONE grid sweep over K: the (R/bm, N/bn) grid accumulates C row-tiles in a
f32 VMEM scratch across column chunks, and on each row-tile's last chunk folds
SᵀC into the (d, d) output revisited by every grid step.  This avoids a second
pass over — and a second HBM read of — C.

VMEM budget (f32, defaults bm=256, bd=64, N≤8192 per chunk):
  accum_apply:      K tile 256×8192×4 = 8 MiB  + one-hot 8192×64×4 = 2 MiB
                    + out 256×64×4 = 64 KiB                      ≈ 10.1 MiB
  accum_sketch_both (bn=2048, d≤512): K tile 2 MiB + S chunk 512 KiB
                    + acc/C/S-rows 3×(256·d·4) + W d²·4          ≲ 4 MiB
both under the ~16 MiB/core budget.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _coef_block(idx_ref, coef_ref, *, base, nrows: int, j0, ncols: int, m: int):
    """(nrows, ncols) dense block of S covering S rows [base, base+nrows) and
    columns [j0, j0+ncols), built from the SMEM-prefetched (m, d) idx/coef.

    One-hot build: a broadcasted row-iota is compared against each sub-sketch's
    index vector; matches deposit that sub-sketch's coefficient.  Colliding
    draws (same index, same column, different i) sum, exactly like Σ_i S_(i).
    """
    rid = jax.lax.broadcasted_iota(jnp.int32, (nrows, ncols), 0) + base
    blk = jnp.zeros((nrows, ncols), jnp.float32)
    for i in range(m):
        idx_v = jnp.stack([idx_ref[i, j0 + jj] for jj in range(ncols)])
        cf_v = jnp.stack([coef_ref[i, j0 + jj] for jj in range(ncols)])
        blk = blk + jnp.where(
            rid == idx_v[None, :], cf_v[None, :].astype(jnp.float32), 0.0
        )
    return blk


# --------------------------------------------------------------------------- #
# K·S — vectorized gather→GEMM
# --------------------------------------------------------------------------- #

def _gemm_kernel(idx_ref, coef_ref, K_ref, out_ref, *, m: int, bd: int):
    j0 = pl.program_id(1) * bd
    sblk = _coef_block(idx_ref, coef_ref, base=0, nrows=K_ref.shape[1],
                       j0=j0, ncols=bd, m=m)                      # (N, bd)
    out_ref[...] = jax.lax.dot_general(
        K_ref[...].astype(jnp.float32), sblk,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bd", "interpret"))
def accum_apply(
    K: jax.Array, idx: jax.Array, coef: jax.Array, *,
    bm: int = 256, bd: int = 64, interpret: bool = True,
) -> jax.Array:
    """K: (R, N); idx/coef: (m, d). Returns K S (R, d) via MXU GEMM tiles.

    Shapes must tile exactly (R % bm == 0, d % bd == 0) — the ops.py wrappers
    pad arbitrary shapes and chunk N (addition commutes with the accumulation,
    the same identity the paper uses)."""
    R, N = K.shape
    m, d = idx.shape
    bm = min(bm, R)
    bd = min(bd, d)
    assert R % bm == 0 and d % bd == 0, (R, bm, d, bd)
    grid = (R // bm, d // bd)
    return pl.pallas_call(
        functools.partial(_gemm_kernel, m=m, bd=bd),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,             # idx, coef in SMEM
            grid=grid,
            in_specs=[pl.BlockSpec((bm, N), lambda r, j, *_: (r, 0))],
            out_specs=pl.BlockSpec((bm, bd), lambda r, j, *_: (r, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((R, d), K.dtype),
        interpret=interpret,
    )(idx, coef, K)


# --------------------------------------------------------------------------- #
# fused (K·S, Sᵀ·K·S) — one sweep over K
# --------------------------------------------------------------------------- #

def _both_kernel(idx_ref, coef_ref, K_ref, C_ref, W_ref, acc_ref,
                 *, m: int, bm: int, bn: int, d: int):
    r, c = pl.program_id(0), pl.program_id(1)
    nc = pl.num_programs(1)

    # S chunk for the columns of K in this grid step: S rows [c·bn, (c+1)·bn).
    # Indices outside the chunk simply never match the offset iota — the
    # column-chunked partial products need no explicit masking.
    scols = _coef_block(idx_ref, coef_ref, base=c * bn, nrows=bn,
                        j0=0, ncols=d, m=m)                       # (bn, d)
    part = jax.lax.dot_general(
        K_ref[...].astype(jnp.float32), scols,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                             # (bm, d)

    @pl.when(c == 0)
    def _init():
        acc_ref[...] = part

    @pl.when(c > 0)
    def _accum():
        acc_ref[...] = acc_ref[...] + part

    @pl.when(c == nc - 1)
    def _finalize():
        C_tile = acc_ref[...]
        C_ref[...] = C_tile.astype(C_ref.dtype)
        # fold this row-tile's contribution Sᵀ_tile · C_tile into W while the
        # tile is still VMEM-resident — no second pass, no HBM re-read of C
        srows = _coef_block(idx_ref, coef_ref, base=r * bm, nrows=bm,
                            j0=0, ncols=d, m=m)                   # (bm, d)
        wpart = jax.lax.dot_general(
            srows, C_tile,
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                         # (d, d)

        @pl.when(r == 0)
        def _w_init():
            W_ref[...] = wpart

        @pl.when(r > 0)
        def _w_accum():
            W_ref[...] = W_ref[...] + wpart


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def accum_sketch_both(
    K: jax.Array, idx: jax.Array, coef: jax.Array, *,
    bm: int = 256, bn: int = 2048, interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Fused (C, W) = (K S, SᵀK S) for (logically square) K in one grid sweep.

    Grid (R/bm, N/bn), column chunks innermost: C row-tiles accumulate over
    chunks in a f32 scratch; each row-tile's last chunk writes C and folds
    SᵀC into the (d, d) W output, which every step revisits (block (0, 0)).
    K may arrive rectangular from zero-padding as long as every sketch index
    is < min(R, N) — padded rows of S are all-zero and contribute nothing.
    W is returned in float32 (it feeds a d×d solve, not a matmul chain)."""
    R, N = K.shape
    m, d = idx.shape
    bm = min(bm, R)
    bn = min(bn, N)
    assert R % bm == 0 and N % bn == 0, (R, N, bm, bn)
    grid = (R // bm, N // bn)
    return pl.pallas_call(
        functools.partial(_both_kernel, m=m, bm=bm, bn=bn, d=d),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[pl.BlockSpec((bm, bn), lambda r, c, *_: (r, c))],
            out_specs=[
                pl.BlockSpec((bm, d), lambda r, c, *_: (r, 0)),
                pl.BlockSpec((d, d), lambda r, c, *_: (0, 0)),
            ],
            scratch_shapes=[pltpu.VMEM((bm, d), jnp.float32)],
        ),
        out_shape=(
            jax.ShapeDtypeStruct((R, d), K.dtype),
            jax.ShapeDtypeStruct((d, d), jnp.float32),
        ),
        interpret=interpret,
    )(idx, coef, K)


# --------------------------------------------------------------------------- #
# Sᵀ·M — true left-apply, M streamed in ROW tiles (no Mᵀ copy)
# --------------------------------------------------------------------------- #

def _left_kernel(idx_ref, coef_ref, M_ref, out_ref, *, m: int, bn: int, d: int):
    t = pl.program_id(0)
    # dense (bn, d) block of S covering S rows [t·bn, (t+1)·bn): each sketch
    # index lands in exactly one row tile, so the per-tile partial products
    # Sᵀ_tile · M_tile sum to Sᵀ M with no masking
    sblk = _coef_block(idx_ref, coef_ref, base=t * bn, nrows=bn,
                       j0=0, ncols=d, m=m)                        # (bn, d)
    part = jax.lax.dot_general(
        sblk, M_ref[...].astype(jnp.float32),
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                             # (d, c)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = part

    @pl.when(t > 0)
    def _accum():
        out_ref[...] = out_ref[...] + part


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def accum_apply_left(
    M: jax.Array, idx: jax.Array, coef: jax.Array, *,
    bn: int = 2048, interpret: bool = True,
) -> jax.Array:
    """Sᵀ M for M of shape (N, c) → (d, c), streaming M in ROW tiles.

    The transpose-free counterpart of ``accum_apply``: M keeps its row-major
    layout (the layout the row-tiled kernels produce C in), each grid step
    contracts the tile's dense (bn, d) one-hot block of S against the (bn, c)
    M tile, and the (d, c) output is revisited and accumulated across steps —
    the same pattern as the fused kernel's W accumulation.  N must tile by bn
    (the ops.py wrapper pads)."""
    N, c = M.shape
    m, d = idx.shape
    bn = min(bn, N)
    assert N % bn == 0, (N, bn)
    grid = (N // bn,)
    return pl.pallas_call(
        functools.partial(_left_kernel, m=m, bn=bn, d=d),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,             # idx, coef in SMEM
            grid=grid,
            in_specs=[pl.BlockSpec((bn, c), lambda t, *_: (t, 0))],
            out_specs=pl.BlockSpec((d, c), lambda t, *_: (0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((d, c), jnp.float32),
        interpret=interpret,
    )(idx, coef, M)


# --------------------------------------------------------------------------- #
# single-slab progressive step — C ← a·C + K·T̃ in one fused pass
# --------------------------------------------------------------------------- #

def _step_kernel(idx_ref, coef_ref, a_ref, K_ref, Cin_ref, out_ref, *, bd: int):
    j0 = pl.program_id(1) * bd
    sblk = _coef_block(idx_ref, coef_ref, base=0, nrows=K_ref.shape[1],
                       j0=j0, ncols=bd, m=1)                       # (N, bd)
    g = jax.lax.dot_general(
        K_ref[...].astype(jnp.float32), sblk,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                              # (bm, bd)
    rescaled = a_ref[0].astype(jnp.float32) * Cin_ref[...].astype(jnp.float32)
    out_ref[...] = (rescaled + g).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bd", "interpret"))
def accum_step_slab(
    K: jax.Array, idx: jax.Array, coef: jax.Array, Cin: jax.Array,
    a: jax.Array, *, bm: int = 256, bd: int = 64, interpret: bool = True,
) -> jax.Array:
    """One progressive-accumulation increment: a·Cin + K·T̃ for a SINGLE
    sub-sampling slab (idx/coef of shape (1, d), rescale scalar ``a`` of
    shape (1,) riding in SMEM via scalar prefetch).

    Same gather→GEMM formulation as ``accum_apply`` (the m=1 one-hot block
    feeds the MXU) with the running C's rescale fused into the tile write, so
    the engine's m → m+1 step is one kernel launch and one read of C."""
    R, N = K.shape
    _, d = idx.shape
    bm = min(bm, R)
    bd = min(bd, d)
    assert R % bm == 0 and d % bd == 0, (R, bm, d, bd)
    assert Cin.shape == (R, d), (Cin.shape, R, d)
    grid = (R // bm, d // bd)
    return pl.pallas_call(
        functools.partial(_step_kernel, bd=bd),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,             # idx, coef, a in SMEM
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, N), lambda r, j, *_: (r, 0)),
                pl.BlockSpec((bm, bd), lambda r, j, *_: (r, j)),
            ],
            out_specs=pl.BlockSpec((bm, bd), lambda r, j, *_: (r, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((R, d), Cin.dtype),
        interpret=interpret,
    )(idx, coef, a, K, Cin)


# --------------------------------------------------------------------------- #
# batched rank-B progressive growth — B slabs folded in ONE sweep over K
# --------------------------------------------------------------------------- #

def _grow_kernel(idx_ref, coef_ref, a_ref, K_ref, Cin_ref, C_ref, TtG_ref,
                 TtC_ref, acc_ref, *, m: int, bm: int, bn: int, d: int):
    r, c = pl.program_id(0), pl.program_id(1)
    nc = pl.num_programs(1)

    # T chunk for this grid step's K columns: T rows [c·bn, (c+1)·bn).  The B
    # slabs enter as ONE (m=B)-row coefficient block already normalized for
    # the grown size t+B — the per-step sqrt(k/(k+1)) survivor rescales
    # telescope into the single scalar ``a`` applied to Cin below.
    scols = _coef_block(idx_ref, coef_ref, base=c * bn, nrows=bn,
                        j0=0, ncols=d, m=m)                       # (bn, d)
    part = jax.lax.dot_general(
        K_ref[...].astype(jnp.float32), scols,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                             # (bm, d)

    @pl.when(c == 0)
    def _init():
        acc_ref[...] = part

    @pl.when(c > 0)
    def _accum():
        acc_ref[...] = acc_ref[...] + part

    @pl.when(c == nc - 1)
    def _finalize():
        G_tile = acc_ref[...]                                     # K·T row tile
        Cin_tile = Cin_ref[...].astype(jnp.float32)
        C_ref[...] = (a_ref[0].astype(jnp.float32) * Cin_tile
                      + G_tile).astype(C_ref.dtype)
        # fold BOTH d×d W pieces while the tiles are VMEM-resident:
        # TᵀK T = Tᵀ(K T) = ΣᵣTᵣᵀ Gᵣ and TᵀC_old = ΣᵣTᵣᵀ Cinᵣ — no second
        # pass over K, G, or C
        trows = _coef_block(idx_ref, coef_ref, base=r * bm, nrows=bm,
                            j0=0, ncols=d, m=m)                   # (bm, d)
        tg = jax.lax.dot_general(
            trows, G_tile, dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        tc = jax.lax.dot_general(
            trows, Cin_tile, dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

        @pl.when(r == 0)
        def _w_init():
            TtG_ref[...] = tg
            TtC_ref[...] = tc

        @pl.when(r > 0)
        def _w_accum():
            TtG_ref[...] = TtG_ref[...] + tg
            TtC_ref[...] = TtC_ref[...] + tc


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def accum_grow_slabs(
    K: jax.Array, idx: jax.Array, coef: jax.Array, Cin: jax.Array,
    a: jax.Array, *, bm: int = 256, bn: int = 2048, interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Batched rank-B progressive increment in ONE grid sweep over K:

        C_new = a·Cin + K·T        (n, d)
        TᵀG   = Tᵀ K T             (d, d)   — from the G tiles, in-kernel
        TᵀC   = Tᵀ Cin             (d, d)   — from the Cin tiles, in-kernel

    where T is the B-slab batch block (idx/coef of shape (B, d), coefficients
    normalized for the grown size) and ``a`` the telescoped survivor rescale,
    riding in SMEM via scalar prefetch.  The caller assembles
    W_new = a²·W + a·(TᵀC + TᵀCᵀ) + TᵀG — every W piece comes out of the same
    single pass that produced C, so folding B slabs reads K exactly once
    (B sequential ``accum_step_slab`` launches read it B times).

    Grid (R/bm, N/bn), column chunks innermost, same accumulation scheme as
    ``accum_sketch_both``; K may be rectangular from padding as long as every
    index is < min(R, N)."""
    R, N = K.shape
    m, d = idx.shape
    bm = min(bm, R)
    bn = min(bn, N)
    assert R % bm == 0 and N % bn == 0, (R, N, bm, bn)
    assert Cin.shape == (R, d), (Cin.shape, R, d)
    grid = (R // bm, N // bn)
    return pl.pallas_call(
        functools.partial(_grow_kernel, m=m, bm=bm, bn=bn, d=d),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,             # idx, coef, a in SMEM
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bn), lambda r, c, *_: (r, c)),
                pl.BlockSpec((bm, d), lambda r, c, *_: (r, 0)),
            ],
            out_specs=[
                pl.BlockSpec((bm, d), lambda r, c, *_: (r, 0)),
                pl.BlockSpec((d, d), lambda r, c, *_: (0, 0)),
                pl.BlockSpec((d, d), lambda r, c, *_: (0, 0)),
            ],
            scratch_shapes=[pltpu.VMEM((bm, d), jnp.float32)],
        ),
        out_shape=(
            jax.ShapeDtypeStruct((R, d), Cin.dtype),
            jax.ShapeDtypeStruct((d, d), jnp.float32),
            jax.ShapeDtypeStruct((d, d), jnp.float32),
        ),
        interpret=interpret,
    )(idx, coef, a, K, Cin)


# --------------------------------------------------------------------------- #
# matrix-free C = K(X, X)·S — fused kernel-eval → GEMM, K never materialized
# --------------------------------------------------------------------------- #

def _kernel_eval(d2: jax.Array, kernel: str, bandwidth: float, nu: float) -> jax.Array:
    """Elementwise PSD kernel on squared distances, mirroring
    ``core/kernels_math.py`` EXACTLY (same guards, same closed forms) so the
    matrix-free path is bit-compatible with a materialized K."""
    if kernel == "gaussian":
        return jnp.exp(-d2 / (2.0 * bandwidth**2))
    r = jnp.sqrt(d2 + 1e-30)
    if kernel == "laplacian":
        return jnp.exp(-r / bandwidth)
    if kernel == "matern":
        r = r / bandwidth
        if nu == 0.5:
            return jnp.exp(-r)
        if nu == 1.5:
            c = math.sqrt(3.0)
            return (1.0 + c * r) * jnp.exp(-c * r)
        if nu == 2.5:
            c = math.sqrt(5.0)
            return (1.0 + c * r + 5.0 * r * r / 3.0) * jnp.exp(-c * r)
        raise ValueError(f"unsupported nu={nu}")
    raise ValueError(f"unknown kernel {kernel}")


def _matfree_kernel(X_ref, L_ref, Cm_ref, out_ref, *, kernel: str,
                    bandwidth: float, nu: float):
    """Per row tile: evaluate the (bm, md) kernel block K(X_tile, L) in VMEM
    via the pairwise-sqdist + closed-form formulation and immediately contract
    it with the (md, d) combination-coefficient matrix — gather→eval→GEMM,
    never allocating an n×anything-beyond-md buffer."""
    x = X_ref[...].astype(jnp.float32)                             # (bm, p)
    l = L_ref[...].astype(jnp.float32)                             # (md, p)
    x2 = jnp.sum(x * x, axis=1)[:, None]
    l2 = jnp.sum(l * l, axis=1)[None, :]
    xl = jax.lax.dot_general(
        x, l, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                              # (bm, md)
    d2 = jnp.maximum(x2 + l2 - 2.0 * xl, 0.0)
    kv = _kernel_eval(d2, kernel, bandwidth, nu)
    out_ref[...] = jax.lax.dot_general(
        kv, Cm_ref[...].astype(jnp.float32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("kernel", "bandwidth", "nu", "bm", "interpret"))
def matfree_apply(
    X: jax.Array, L: jax.Array, Cmat: jax.Array, *, kernel: str,
    bandwidth: float = 1.0, nu: float = 1.5, bm: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """C = K(X, L)·Cmat without materializing any n×n object.

    X: (n, p) query rows; L: (md, p) landmark rows (the sketch's sampled
    points, zero-padded rows allowed); Cmat: (md, d) expanded combination
    coefficients (entry (i·d+j, j) = coef[i, j]; padded rows are all-zero so
    padded landmarks contribute nothing regardless of their kernel value).
    The grid streams X in (bm, p) row tiles — peak VMEM per step is the tile,
    the landmark block, and the (bm, md) kernel slab, independent of n.

    n must tile by bm (the ops.py wrapper pads); returns (n, d) f32."""
    n, p = X.shape
    md, d = Cmat.shape
    assert L.shape == (md, p), (L.shape, md, p)
    bm = min(bm, n)
    assert n % bm == 0, (n, bm)
    grid = (n // bm,)
    return pl.pallas_call(
        functools.partial(_matfree_kernel, kernel=kernel, bandwidth=bandwidth,
                          nu=nu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, p), lambda r: (r, 0)),
            pl.BlockSpec((md, p), lambda r: (0, 0)),
            pl.BlockSpec((md, d), lambda r: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=interpret,
    )(X, L, Cmat)


# --------------------------------------------------------------------------- #
# seed scalar-gather kernel — kept as the benchmark baseline
# --------------------------------------------------------------------------- #

def _scalar_kernel(idx_ref, coef_ref, K_ref, out_ref, *, m: int, bd: int):
    j0 = pl.program_id(1) * bd
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    for jj in range(bd):                       # static loop over tile columns
        col_acc = jnp.zeros((K_ref.shape[0],), jnp.float32)
        for i in range(m):                     # accumulate the m sub-sketches
            c = coef_ref[i, j0 + jj]
            src = idx_ref[i, j0 + jj]
            col = pl.load(K_ref, (slice(None), pl.dslice(src, 1)))  # (bm, 1)
            col_acc = col_acc + c.astype(jnp.float32) * col[:, 0].astype(jnp.float32)
        acc = acc.at[:, jj].set(col_acc)
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bd", "interpret"))
def accum_apply_scalar(
    K: jax.Array, idx: jax.Array, coef: jax.Array, *,
    bm: int = 256, bd: int = 8, interpret: bool = True,
) -> jax.Array:
    """The seed's scalar per-column gather loop (no MXU). Benchmarks only —
    `benchmarks/kernel_bench.py` times it against `accum_apply` to track the
    gather→GEMM speedup in BENCH_kernels.json."""
    R, N = K.shape
    m, d = idx.shape
    bm = min(bm, R)
    bd = min(bd, d)
    assert R % bm == 0 and d % bd == 0, (R, bm, d, bd)
    grid = (R // bm, d // bd)
    return pl.pallas_call(
        functools.partial(_scalar_kernel, m=m, bd=bd),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[pl.BlockSpec((bm, N), lambda r, j, *_: (r, 0))],
            out_specs=pl.BlockSpec((bm, bd), lambda r, j, *_: (r, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((R, d), K.dtype),
        interpret=interpret,
    )(idx, coef, K)
