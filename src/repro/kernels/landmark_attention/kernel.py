"""Pallas TPU kernel: the O(S·L) stage of AccumAttention (sketched attention).

out = softmax(q k̃ᵀ/√Dh) @ M, with L = d_slots landmarks. The landmark set is
small by construction (that is the paper's point), so k̃ and M stay resident in
VMEM across the whole grid while q streams through in (bq, Dh) tiles — one
softmax pass per tile, no online-softmax bookkeeping needed (full row of
logits fits in VREGs). MXU-aligned: bq, L, Dh all multiples of the 128 lane
width in production configs.

Grid: (S/bq,). Per step:  q tile (bq, Dh) · k̃ᵀ (Dh, L) → logits (bq, L)
                          softmax → p · M (L, Dv) → out tile (bq, Dv)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, kt_ref, M_ref, out_ref, *, scale: float):
    q = q_ref[...].astype(jnp.float32)
    kt = kt_ref[...].astype(jnp.float32)
    logits = jax.lax.dot_general(
        q, kt, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                             # (bq, L)
    mx = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - mx)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jax.lax.dot_general(
        p, M_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "interpret"))
def landmark_attention(
    q: jax.Array, kt: jax.Array, M: jax.Array, *,
    bq: int = 256, interpret: bool = True,
) -> jax.Array:
    """q: (S, Dh); kt: (L, Dh); M: (L, Dv) → (S, Dv)."""
    S, Dh = q.shape
    L, Dv = M.shape
    assert kt.shape == (L, Dh)
    bq = min(bq, S)
    assert S % bq == 0, (S, bq)
    scale = 1.0 / (Dh ** 0.5)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale),
        grid=(S // bq,),
        in_specs=[
            pl.BlockSpec((bq, Dh), lambda i: (i, 0)),
            pl.BlockSpec((L, Dh), lambda i: (0, 0)),   # landmarks VMEM-resident
            pl.BlockSpec((L, Dv), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, Dv), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((S, Dv), q.dtype),
        interpret=interpret,
    )(q, kt, M)
