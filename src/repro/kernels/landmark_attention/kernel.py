"""Pallas TPU kernels: the O(S·L) stages of AccumAttention (sketched attention).

`landmark_attention` — out = softmax(q k̃ᵀ/√Dh + bias) @ M, with L = d_slots
landmarks. The landmark set is small by construction (that is the paper's
point), so k̃ and M stay resident in VMEM across the whole grid while q streams
through in (bq, Dh) tiles — one softmax pass per tile, no online-softmax
bookkeeping needed (full row of logits fits in VREGs). The bias lane carries
the decode path's log-mass correction (and −1e30 padding/empty-slot masks), so
the same kernel serves `sketch_decode_attend` and the prefill F-stage.

`landmark_stats` — the fused single-sweep variant for `accum_attention`: ONE
pass over the key/value sequence computes BOTH

    W    = softmax(q̃ k̃ᵀ/√Dh)          (L, L)   — landmark row, kt resident
    BmV  = softmax(q̃ Kᵀ/√Dh) · V       (L, Dv)  — online-softmax accumulation

The F·M product cannot join this sweep: M = W⁺(BmV) needs the completed W
(Newton–Schulz pseudo-inverse) before any F row can be applied — the fusion
boundary is data dependence, not tiling. What the fusion buys is never
materializing the (L, S) Bm softmax: running (max, denom, acc) live in VMEM
scratch across S tiles, flash-attention style.

Grids are strict here (S % block == 0, MXU-aligned dims in production);
`ops.py` pads arbitrary shapes and masks the padding via the scalar-prefetch
valid counts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(q_ref, kt_ref, M_ref, b_ref, out_ref, *, scale: float):
    q = q_ref[...].astype(jnp.float32)
    kt = kt_ref[...].astype(jnp.float32)
    logits = jax.lax.dot_general(
        q, kt, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale + b_ref[...]                                # (bq, L) + (1, L)
    mx = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - mx)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jax.lax.dot_general(
        p, M_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "interpret"))
def landmark_attention(
    q: jax.Array, kt: jax.Array, M: jax.Array, bias: jax.Array | None = None, *,
    bq: int = 256, interpret: bool | None = None,
) -> jax.Array:
    """q: (S, Dh); kt: (L, Dh); M: (L, Dv); bias: (L,) f32 or None → (S, Dv).

    Strict-grid kernel (S % bq == 0) — `ops.landmark_attend` is the padded,
    autotuned entry point. `interpret=None` autodetects the backend
    (compiled Mosaic on TPU, interpreter elsewhere)."""
    if interpret is None:
        from repro.kernels.accum_apply.ops import default_interpret

        interpret = default_interpret()
    S, Dh = q.shape
    L, Dv = M.shape
    assert kt.shape == (L, Dh)
    bq = min(bq, S)
    assert S % bq == 0, (S, bq)
    if bias is None:
        bias = jnp.zeros((L,), jnp.float32)
    scale = 1.0 / (Dh ** 0.5)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale),
        grid=(S // bq,),
        in_specs=[
            pl.BlockSpec((bq, Dh), lambda i: (i, 0)),
            pl.BlockSpec((L, Dh), lambda i: (0, 0)),   # landmarks VMEM-resident
            pl.BlockSpec((L, Dv), lambda i: (0, 0)),
            pl.BlockSpec((1, L), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, Dv), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((S, Dv), q.dtype),
        interpret=interpret,
    )(q, kt, M, bias.astype(jnp.float32)[None, :])


def _stats_kernel(nv_ref, qt_ref, kt_ref, k_ref, v_ref, W_ref, BmV_ref,
                  m_ref, d_ref, acc_ref, *, bs: int, scale: float):
    i = pl.program_id(0)
    ns = pl.num_programs(0)
    qt = qt_ref[...].astype(jnp.float32)

    @pl.when(i == 0)
    def _init():
        # landmark-row softmax W while k̃ is VMEM-resident; padded landmark
        # columns (index ≥ nv_ref[1]) masked to −inf
        kt = kt_ref[...].astype(jnp.float32)
        wl = jax.lax.dot_general(
            qt, kt, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale
        lcol = jax.lax.broadcasted_iota(jnp.int32, wl.shape, 1)
        wl = jnp.where(lcol < nv_ref[1], wl, -1e30)
        mw = jnp.max(wl, axis=-1, keepdims=True)
        pw = jnp.exp(wl - mw)
        W_ref[...] = (pw / jnp.sum(pw, axis=-1, keepdims=True)).astype(W_ref.dtype)
        m_ref[...] = jnp.full(m_ref.shape, -1e30, jnp.float32)
        d_ref[...] = jnp.zeros(d_ref.shape, jnp.float32)
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)

    # online-softmax fold of this S tile into (max, denom, Bm·V accumulator)
    kb = k_ref[...].astype(jnp.float32)
    logits = jax.lax.dot_general(
        qt, kb, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                             # (L, bs)
    col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1) + i * bs
    logits = jnp.where(col < nv_ref[0], logits, -1e30)    # padded keys → −inf
    m_old = m_ref[:, :1]
    m_new = jnp.maximum(m_old, jnp.max(logits, axis=-1, keepdims=True))
    corr = jnp.exp(m_old - m_new)
    p = jnp.exp(logits - m_new)
    d_new = d_ref[:, :1] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    d_ref[...] = jnp.broadcast_to(d_new, d_ref.shape)

    @pl.when(i == ns - 1)
    def _finalize():
        BmV_ref[...] = (acc_ref[...] / d_ref[:, :1]).astype(BmV_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_valid", "l_valid", "bs", "interpret"))
def landmark_stats(
    qt: jax.Array, kt: jax.Array, k: jax.Array, v: jax.Array, *,
    n_valid: int, l_valid: int, bs: int = 512, interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused (W, Bm·V) in one sweep over the S axis (see module docstring).

    qt, kt: (L, Dh); k: (S, Dh); v: (S, Dv). `n_valid` / `l_valid` are the
    un-padded S / L extents (padded keys and landmark columns are masked to
    −inf; padded landmark ROWS produce garbage rows the caller slices off).
    Returns (W (L, L) f32, BmV (L, Dv) f32). Strict grid: S % bs == 0."""
    if interpret is None:
        from repro.kernels.accum_apply.ops import default_interpret

        interpret = default_interpret()
    L, Dh = qt.shape
    S, Dv = v.shape
    assert kt.shape == (L, Dh) and k.shape == (S, Dh)
    bs = min(bs, S)
    assert S % bs == 0, (S, bs)
    scale = 1.0 / (Dh ** 0.5)
    nv = jnp.asarray([n_valid, l_valid], jnp.int32)
    return pl.pallas_call(
        functools.partial(_stats_kernel, bs=bs, scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(S // bs,),
            in_specs=[
                pl.BlockSpec((L, Dh), lambda i, *_: (0, 0)),
                pl.BlockSpec((L, Dh), lambda i, *_: (0, 0)),
                pl.BlockSpec((bs, Dh), lambda i, *_: (i, 0)),
                pl.BlockSpec((bs, Dv), lambda i, *_: (i, 0)),
            ],
            out_specs=[
                pl.BlockSpec((L, L), lambda i, *_: (0, 0)),
                pl.BlockSpec((L, Dv), lambda i, *_: (0, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((L, 1), jnp.float32),
                pltpu.VMEM((L, 1), jnp.float32),
                pltpu.VMEM((L, Dv), jnp.float32),
            ],
        ),
        out_shape=(
            jax.ShapeDtypeStruct((L, L), jnp.float32),
            jax.ShapeDtypeStruct((L, Dv), jnp.float32),
        ),
        interpret=interpret,
    )(nv, qt, kt, k, v)
