"""Pure-jnp oracle for the landmark_attention kernel.

out = softmax(q k̃ᵀ / √Dh) @ M   — the O(S·d_landmark) stage of AccumAttention
(M = W⁺ (B V) is precomputed; see core/sketched_attention.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def landmark_attention_ref(
    q: jax.Array, kt: jax.Array, M: jax.Array, bias: jax.Array | None = None
) -> jax.Array:
    """q: (S, Dh); kt: (L, Dh); M: (L, Dv); bias: (L,) or None. Returns (S, Dv)."""
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    logits = q.astype(jnp.float32) @ kt.T.astype(jnp.float32) * scale
    if bias is not None:
        logits = logits + bias.astype(jnp.float32)[None, :]
    p = jax.nn.softmax(logits, axis=-1)
    return (p @ M.astype(jnp.float32)).astype(q.dtype)


def landmark_stats_ref(
    qt: jax.Array, kt: jax.Array, k: jax.Array, v: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Oracle for the fused sweep: (W, Bm·V) in plain jnp.

    qt, kt: (L, Dh); k: (S, Dh); v: (S, Dv) →
    (softmax(q̃k̃ᵀ/√Dh) (L, L) f32, softmax(q̃Kᵀ/√Dh)·V (L, Dv) f32)."""
    scale = 1.0 / jnp.sqrt(jnp.asarray(qt.shape[-1], jnp.float32))
    W = jax.nn.softmax(
        qt.astype(jnp.float32) @ kt.T.astype(jnp.float32) * scale, axis=-1
    )
    Bm = jax.nn.softmax(
        qt.astype(jnp.float32) @ k.T.astype(jnp.float32) * scale, axis=-1
    )
    return W, Bm @ v.astype(jnp.float32)
