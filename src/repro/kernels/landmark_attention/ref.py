"""Pure-jnp oracle for the landmark_attention kernel.

out = softmax(q k̃ᵀ / √Dh) @ M   — the O(S·d_landmark) stage of AccumAttention
(M = W⁺ (B V) is precomputed; see core/sketched_attention.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def landmark_attention_ref(q: jax.Array, kt: jax.Array, M: jax.Array) -> jax.Array:
    """q: (S, Dh); kt: (L, Dh); M: (L, Dv). Returns (S, Dv)."""
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    logits = q.astype(jnp.float32) @ kt.T.astype(jnp.float32) * scale
    p = jax.nn.softmax(logits, axis=-1)
    return (p @ M.astype(jnp.float32)).astype(q.dtype)
