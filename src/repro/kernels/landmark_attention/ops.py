"""Padded, autotuned entry points for the landmark-attention kernel family.

Mirrors `kernels/accum_apply/ops.py` (the PR 1/5 treatment):

  * ``interpret`` defaults to backend autodetection (compiled Mosaic on TPU,
    interpreter on CPU CI);
  * arbitrary shapes are padded to the block grid and sliced back — S rows of
    q pad with zeros (independent rows, sliced off), landmark L pads with
    −1e30 bias / masked columns so padded landmarks get exactly zero softmax
    weight;
  * block sizes come from the SAME measured autotune cache as the KRR kernels
    (`kernels/accum_apply/autotune.py`, kinds ``landmark_attention`` /
    ``landmark_stats``): first eager call times the candidates on the real
    arrays and persists the winner to ``REPRO_AUTOTUNE_CACHE``;
  * ``accum_attention_kernel`` is the full fused pipeline:
    ``landmark_stats`` (ONE sweep over S for W + online-softmax Bm·V — the
    (L, S) Bm matrix is never materialized) → Newton–Schulz W⁺ (small, plain
    XLA) → ``landmark_attend`` for the O(S·L) F-stage.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sketch import AccumSketch
from repro.core.sketched_attention import _newton_schulz_pinv, landmark_pool
from repro.kernels.accum_apply import autotune
from repro.kernels.accum_apply.ops import default_interpret
from repro.resilience import faults
from repro.kernels.landmark_attention.kernel import (
    landmark_attention,
    landmark_stats,
)

NEG_INF = -1e30


def _pad_to(x: jax.Array, axis: int, mult: int, value=0.0) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _bq_candidates(S: int, fallback: int) -> list[tuple[int, ...]]:
    cands = sorted({min(b, S) for b in (128, 256, 512, 1024)} | {fallback})
    return [(b,) for b in cands if b >= 8]


def landmark_attend(
    q: jax.Array, kt: jax.Array, M: jax.Array, bias: jax.Array | None = None, *,
    bq: int | None = None, interpret: bool | None = None,
) -> jax.Array:
    """softmax(q k̃ᵀ/√Dh + bias) @ M for arbitrary (S, L) — padded + autotuned.

    q: (S, Dh); kt: (L, Dh); M: (L, Dv); bias: (L,) f32 or None (the decode
    path folds its log-mass correction and empty-slot masks in here).
    Returns (S, Dv) in q's dtype."""
    S, Dh = q.shape
    L, Dv = M.shape
    if interpret is None:
        interpret = default_interpret()
    if bias is None:
        bias = jnp.zeros((L,), jnp.float32)
    fallback = min(256, max(8, S))
    if bq is None:
        key = (S, Dh, L, Dv)
        (bq,) = autotune.measured_blocks(
            "landmark_attention", key, q.dtype, interpret,
            _bq_candidates(S, fallback),
            lambda blocks: _attend_padded(
                q, kt, M, bias, bq=blocks[0], interpret=interpret
            ),
            (fallback,),
            autotune.is_concrete(q, kt, M, bias),
        )
    return _attend_padded(q, kt, M, bias, bq=bq, interpret=interpret)


def _attend_padded(q, kt, M, bias, *, bq, interpret):
    S, L = q.shape[0], M.shape[0]
    bq = min(bq, S)
    qp = _pad_to(q, 0, bq)                      # padded q rows: sliced off
    # padded landmarks: −inf bias ⇒ exactly zero softmax weight
    ktp = _pad_to(kt, 0, 8)
    Mp = _pad_to(M, 0, 8)
    bp = _pad_to(bias.astype(jnp.float32), 0, 8, value=NEG_INF)
    out = landmark_attention(qp, ktp, Mp, bp, bq=bq, interpret=interpret)
    return out[:S]


def landmark_stats_fused(
    qt: jax.Array, kt: jax.Array, k: jax.Array, v: jax.Array, *,
    bs: int | None = None, interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused (W, Bm·V) for arbitrary (S, L) — padded + autotuned.

    qt, kt: (L, Dh); k: (S, Dh); v: (S, Dv). One sweep over S computes both
    the landmark-row softmax W = softmax(q̃k̃ᵀ) and the online-softmax
    accumulation of softmax(q̃Kᵀ)·V. Returns (W (L, L), BmV (L, Dv)) f32."""
    L, Dh = qt.shape
    S, Dv = v.shape
    if interpret is None:
        interpret = default_interpret()
    fallback = min(512, max(8, S))
    if bs is None:
        key = (S, Dh, L, Dv)
        (bs,) = autotune.measured_blocks(
            "landmark_stats", key, k.dtype, interpret,
            _bq_candidates(S, fallback),
            lambda blocks: _stats_padded(
                qt, kt, k, v, bs=blocks[0], interpret=interpret
            ),
            (fallback,),
            autotune.is_concrete(qt, kt, k, v),
        )
    return _stats_padded(qt, kt, k, v, bs=bs, interpret=interpret)


def _stats_padded(qt, kt, k, v, *, bs, interpret):
    L, S = qt.shape[0], k.shape[0]
    bs = min(bs, S)
    W, BmV = landmark_stats(
        _pad_to(qt, 0, 8), _pad_to(kt, 0, 8), _pad_to(k, 0, bs), _pad_to(v, 0, bs),
        n_valid=S, l_valid=L, bs=bs, interpret=interpret,
    )
    return W[:L, :L], BmV[:L]


def accum_attention_kernel(
    q: jax.Array, k: jax.Array, v: jax.Array, sk: AccumSketch, *,
    bq: int | None = None, pinv_iters: int = 6, interpret: bool | None = None,
) -> jax.Array:
    """Full sketched attention (B, H, S, Dh) with both hot stages in Pallas.

    Stages (matching core.sketched_attention.accum_attention):
      k̃/q̃ = landmark pools;
      (W, BmV) = `landmark_stats` — ONE fused sweep over S (no (L, S) Bm);
      M = W⁺ · BmV  [small d×d, plain XLA Newton–Schulz];
      out = softmax(QK̃ᵀ)·M — `landmark_attend` [Pallas, O(S·L)].
    The F·M stage cannot fuse into the sweep: M depends on the completed W.

    This entry visits the `kernel.dispatch` fault site (the per-stage helpers
    deliberately do not — they run inside jitted decode, where recovery is the
    engine's health screen, not an eager ladder)."""
    faults.fault_point("kernel.dispatch")
    if interpret is None:
        interpret = default_interpret()
    kt = landmark_pool(k, sk, normalize=True)
    qt = landmark_pool(q, sk, normalize=True)

    B, H = q.shape[:2]
    qf = q.reshape((B * H,) + q.shape[2:])
    kf = k.reshape((B * H,) + k.shape[2:])
    vf = v.reshape((B * H,) + v.shape[2:])
    ktf = kt.reshape((B * H,) + kt.shape[2:])
    qtf = qt.reshape((B * H,) + qt.shape[2:])
    W, BmV = jax.vmap(
        lambda a, b, c, d: landmark_stats_fused(a, b, c, d, interpret=interpret)
    )(qtf, ktf, kf, vf)
    M = _newton_schulz_pinv(W, pinv_iters) @ BmV                    # (BH,L,Dv)
    out = jax.vmap(
        lambda a, b, c: landmark_attend(a, b, c, bq=bq, interpret=interpret)
    )(qf, ktf, M.astype(q.dtype))
    return out.reshape(q.shape[:2] + out.shape[1:])
