"""jit'd wrapper: batched/multi-head AccumAttention using the Pallas kernel for
the O(S·L) landmark stage (vmapped over batch×head)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sketch import AccumSketch
from repro.core.sketched_attention import _newton_schulz_pinv, landmark_pool
from repro.kernels.landmark_attention.kernel import landmark_attention


def accum_attention_kernel(
    q: jax.Array, k: jax.Array, v: jax.Array, sk: AccumSketch, *,
    bq: int = 256, pinv_iters: int = 6, interpret: bool = True,
) -> jax.Array:
    """Full sketched attention (B, H, S, Dh) with the hot stage in Pallas.

    Stages (matching core.sketched_attention.accum_attention):
      k̃/q̃ = landmark pools;  W = softmax(q̃k̃ᵀ);  Bm = softmax(q̃Kᵀ);
      M = W⁺(Bm V)  [small, plain XLA];  out = softmax(QK̃ᵀ)M  [Pallas].
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    f32 = jnp.float32
    kt = landmark_pool(k, sk, normalize=True)
    qt = landmark_pool(q, sk, normalize=True)
    W = jax.nn.softmax((qt.astype(f32) @ jnp.swapaxes(kt, -1, -2).astype(f32)) * scale, axis=-1)
    Bm = jax.nn.softmax((qt.astype(f32) @ jnp.swapaxes(k, -1, -2).astype(f32)) * scale, axis=-1)
    M = _newton_schulz_pinv(W, pinv_iters) @ (Bm @ v.astype(f32))      # (B,H,L,Dv)

    B, H = q.shape[:2]
    qf = q.reshape((B * H,) + q.shape[2:])
    ktf = kt.reshape((B * H,) + kt.shape[2:])
    Mf = M.astype(q.dtype).reshape((B * H,) + M.shape[2:])
    out = jax.vmap(
        lambda a, b, c: landmark_attention(a, b, c, bq=bq, interpret=interpret)
    )(qf, ktf, Mf)
    return out.reshape(q.shape[:2] + out.shape[1:])
