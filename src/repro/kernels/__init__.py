"""Custom accelerator kernels (OPTIONAL layer).

Add ``<name>/kernel.py`` + ``ops.py`` + ``ref.py`` ONLY for compute
hot-spots the paper itself optimizes with a custom kernel; leave this
package empty if the paper has none. Current members: ``accum_apply``
(sketch application, KRR path) and ``landmark_attention`` (sketched
attention decode/prefill stages, serving path).
"""
