"""Render a pytest junit XML report as a GitHub job-summary markdown table.

Usage (CI):  python scripts/junit_summary.py pytest-junit.xml >> "$GITHUB_STEP_SUMMARY"

Prints a one-line verdict plus, for every failed/errored test, its id and the
first lines of the failure message — so a red matrix leg is readable from the
summary tab without scrolling raw pytest logs.
"""
from __future__ import annotations

import sys
import xml.etree.ElementTree as ET


def main(path: str) -> int:
    root = ET.parse(path).getroot()
    suites = [root] if root.tag == "testsuite" else list(root)
    tests = failures = errors = skipped = 0
    bad: list[tuple[str, str, str]] = []
    total_time = 0.0
    for suite in suites:
        tests += int(suite.get("tests", 0))
        failures += int(suite.get("failures", 0))
        errors += int(suite.get("errors", 0))
        skipped += int(suite.get("skipped", 0))
        total_time += float(suite.get("time", 0.0))
        for case in suite.iter("testcase"):
            for kind in ("failure", "error"):
                node = case.find(kind)
                if node is None:
                    continue
                test_id = f"{case.get('classname', '?')}::{case.get('name', '?')}"
                msg = (node.get("message") or node.text or "").strip()
                first = "\n".join(msg.splitlines()[:8])
                bad.append((kind.upper(), test_id, first))

    passed = tests - failures - errors - skipped
    verdict = "✅ green" if not bad else f"❌ {failures} failed / {errors} errored"
    print("## Tier-1 tests\n")
    print(f"{verdict} — {passed} passed, {skipped} skipped, "
          f"{tests} total in {total_time:.0f}s\n")
    for kind, test_id, msg in bad:
        print(f"<details><summary>{kind}: <code>{test_id}</code></summary>\n")
        print("```")
        print(msg)
        print("```\n</details>\n")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1] if len(sys.argv) > 1 else "pytest-junit.xml"))
