"""Markdown link checker (stdlib only) for the repo's relative links.

Scans the given markdown files for inline links/images and reference
definitions, and verifies every RELATIVE target resolves to an existing file
or directory (external http(s)/mailto links and pure #anchors are skipped;
a #fragment on a relative link is checked against the target file's
headings).  Exit 1 with a per-link report on any dangling target.

Usage: python scripts/check_links.py README.md docs/*.md ...
"""
from __future__ import annotations

import pathlib
import re
import sys

INLINE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
REFDEF = re.compile(r"^\s*\[[^\]]+\]:\s+(\S+)", re.M)
HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.M)
EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def slugify(heading: str) -> str:
    """GitHub-style anchor slug: lowercase, strip punctuation, dashes."""
    h = re.sub(r"[`*_~\[\]()!]", "", heading.strip().lower())
    h = re.sub(r"[^\w\- ]", "", h)
    return re.sub(r"\s+", "-", h).strip("-")


def anchors_of(path: pathlib.Path) -> set[str]:
    return {slugify(m.group(1)) for m in HEADING.finditer(path.read_text())}


def check_file(md: pathlib.Path) -> list[str]:
    text = md.read_text()
    problems = []
    targets = [m.group(1) for m in INLINE.finditer(text)]
    targets += [m.group(1) for m in REFDEF.finditer(text)]
    for raw in targets:
        if raw.startswith(EXTERNAL) or raw.startswith("#"):
            continue
        target, _, frag = raw.partition("#")
        resolved = (md.parent / target).resolve()
        if not resolved.exists():
            problems.append(f"{md}: dangling link -> {raw}")
        elif frag and resolved.is_file() and resolved.suffix == ".md":
            if slugify(frag) not in anchors_of(resolved):
                problems.append(f"{md}: missing anchor -> {raw}")
    return problems


def main(argv: list[str]) -> int:
    if not argv:
        print("usage: check_links.py FILE.md [FILE.md ...]", file=sys.stderr)
        return 2
    problems = []
    for name in argv:
        p = pathlib.Path(name)
        if not p.exists():
            problems.append(f"{name}: file not found")
            continue
        problems += check_file(p)
    for line in problems:
        print(line)
    print(f"checked {len(argv)} file(s): "
          f"{'FAIL' if problems else 'ok'} ({len(problems)} problem(s))")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
