"""Shared benchmark utilities: the paper's synthetic bimodal data generator
(appendix D settings) and timing helpers."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def bimodal_data(key, n: int, gamma: float = 0.6, noise_sd: float = 0.5):
    """The paper's bimodal distribution over R³ (appendix D.2):
    with prob n/(n+n^γ): Unif[0,1]³; with prob n^γ/(n+n^γ): pdf ∏(5−2x_j) on
    [2, 2.5]³. True f*(x) = g(‖x‖/3) with the paper's quartic g."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p2 = n**gamma / (n + n**gamma)
    n2 = max(int(round(n * p2)), 4)
    x1 = jax.random.uniform(k1, (n - n2, 3))
    # inverse-CDF for pdf 2(5-2x)/9? — the paper's pdf ∏(5−2x_j), x_j ∈ [2,2.5]:
    # CDF F(x) = (5x − x² − 6)/1.25·... sample via rejection for fidelity
    # accept elementwise by resampling columns; cheap approximation: weight-free
    # inverse transform:  F⁻¹(p) = (5 − sqrt(25 − 4(6 + 1.125p)))/2 · …
    p = jax.random.uniform(k2, (n2, 3))
    x2 = 2.5 - 0.5 * jnp.sqrt(1.0 - p * (1.0 - (4.0 / 9.0)))  # linear-pdf inverse
    X = jnp.concatenate([x1, x2], axis=0)

    def g(x):
        return 1.6 * jnp.abs((x - 0.4) * (x - 0.6)) - x * (x - 1) * (x - 2) - 0.5

    f = g(jnp.linalg.norm(X, axis=1) / 3.0)
    y = f + noise_sd * jax.random.normal(k4, (n,))
    return X, y, f


def timeit(fn, *args, reps: int = 3, warmup: int = 1) -> float:
    """Median wall-time (s) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
