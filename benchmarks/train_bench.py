"""End-to-end training-step throughput on CPU for reduced configs (one per
family) — tokens/s and the gradient-compression bytes saving."""
from __future__ import annotations

import jax

from benchmarks.common import emit, timeit
from repro.configs import ARCHS, reduced
from repro.data.pipeline import DataConfig, global_batch
from repro.models import init_params
from repro.optim.compress import CompressConfig
from repro.train.step import TrainConfig, init_train_state, train_step


def main():
    key = jax.random.PRNGKey(0)
    for arch in ["stablelm-3b", "moonshot-v1-16b-a3b", "xlstm-125m", "zamba2-7b"]:
        cfg = reduced(ARCHS[arch])
        tc = TrainConfig()
        state = init_train_state(init_params(key, cfg), tc)
        dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=4)
        toks, labs = global_batch(dc, 0)
        fn = jax.jit(lambda s, t, l: train_step(s, t, l, jax.numpy.int32(0), cfg, tc))
        t = timeit(lambda: fn(state, toks, labs)[1]["loss"])
        tokens = dc.global_batch * dc.seq_len
        emit(f"train_step_{arch}", t * 1e6, f"tokens_per_s={tokens/max(t,1e-9):.0f}")

    # compression bytes saving on a realistic grad pytree
    cfg = reduced(ARCHS["stablelm-3b"])
    cc = CompressConfig(ratio=0.125, m=4, min_rows=64)
    tc = TrainConfig(compress=cc)
    state = init_train_state(init_params(key, cfg), tc)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=4)
    toks, labs = global_batch(dc, 0)
    fn = jax.jit(lambda s, t, l: train_step(s, t, l, jax.numpy.int32(0), cfg, tc))
    _, mets = fn(state, toks, labs)
    emit("sketched_grad_compression", 0.0,
         f"allreduce_bytes_ratio={float(mets['compress_ratio']):.3f}")


if __name__ == "__main__":
    main()
