"""Resilience-layer bench: what the safety net costs when nothing is failing.

Three claims, measured:

  * GUARDS — the fault-site + degradation-ladder wrappers on the kernel hot
    path (`sketch_both` with `use_kernel=True`) and the in-graph solve ladder
    (`solve_psd_ladder` vs a bare single-attempt Cholesky) at the
    ``BENCH_kernels.json`` anchor shape.  Acceptance: < 5% overhead — the
    guards are a dict lookup + a counter when no plan is armed, and the solve
    ladder's `while_loop` never iterates on healthy input.
  * CKPT — `ckpt.save` / `ckpt.restore` wall-clock across a state-size ladder
    (the atomic tmp-write + rename + msgpack encode cost per MB).
  * RESUME — `Engine.generate` resumed from a mid-request checkpoint vs the
    same request cold (prefill + full decode): the payoff side of the
    checkpoint ledger.

Run:   PYTHONPATH=src python -m benchmarks.run resilience
Smoke: PYTHONPATH=src python -m benchmarks.run resilience --smoke

Writes ``BENCH_resilience.json`` at the repo root.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.checkpoint import ckpt
from repro.configs import get_config, reduced
from repro.core import apply as A
from repro.core.sketch import make_accum_sketch
from repro.kernels.accum_apply.ops import sketch_both_kernel
from repro.models.model import init_params
from repro.resilience import faults
from repro.resilience.degrade import ladder_call, solve_psd_ladder
from repro.serve.engine import Engine, ServeConfig
from repro.util import env_flag

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
BENCH_PATH = REPO_ROOT / "BENCH_resilience.json"

# guard shapes match BENCH_kernels.json's anchor so the < 5% acceptance is
# checked where the kernel numbers live; ckpt sizes in MB of f32 state
FULL = dict(n=4096, d=64, m=4, solve_d=512, ckpt_mb=[1, 16, 64],
            L=32, n_new=16, ckpt_every=4, batch=2)
SMOKE = dict(n=256, d=16, m=2, solve_d=64, ckpt_mb=[1],
             L=8, n_new=6, ckpt_every=2, batch=2)


def bench_config() -> tuple[dict, int]:
    """(shape dict, reps) — smoke honors REPRO_BENCH_SMOKE like every suite."""
    if env_flag("REPRO_BENCH_SMOKE", False):
        return SMOKE, 1
    return FULL, 3


def bench_guards(results: dict, shapes: dict, reps: int) -> None:
    """Fault-site + ladder wrapper cost on the kernel hot path, and the
    in-graph solve ladder vs a bare Cholesky, at the kernels anchor shape."""
    n, d, m = shapes["n"], shapes["d"], shapes["m"]
    key = jax.random.PRNGKey(0)
    X = jax.random.uniform(jax.random.PRNGKey(1), (n, 8))
    K = jnp.exp(-((X[:, None, :] - X[None, :, :]) ** 2).sum(-1) / 0.5)
    sk = make_accum_sketch(key, n, d, m)

    # overhead is a small difference of two timings — take more reps than the
    # suite default so interpret-mode jitter doesn't swamp it
    g_reps = max(reps, 5)
    faults.reset()
    t_guarded = timeit(
        lambda: A.sketch_both(K, sk, use_kernel=True), reps=g_reps, warmup=1
    )
    # the same rung with the resilience machinery stubbed out — the pre-layer
    # baseline the < 5% acceptance is measured against
    orig = faults.fault_point
    faults.fault_point = lambda site: None
    try:
        t_bare = timeit(
            lambda: sketch_both_kernel(K, sk), reps=g_reps, warmup=1
        )
    finally:
        faults.fault_point = orig
    over_kernel = t_guarded / t_bare - 1.0

    sd = shapes["solve_d"]
    Am = jax.random.uniform(jax.random.PRNGKey(2), (sd, sd))
    M = Am @ Am.T / sd + jnp.eye(sd)
    b = jnp.ones((sd,))
    ladder = jax.jit(lambda M, b: solve_psd_ladder(M, b)[0])

    def bare_solve(M, b):
        from jax.scipy.linalg import cho_factor, cho_solve

        j0 = 1e-8 * (jnp.trace(M) / sd + 1e-30)
        return cho_solve(cho_factor(M + j0 * jnp.eye(sd), lower=True), b)

    bare = jax.jit(bare_solve)
    t_ladder = timeit(lambda: ladder(M, b), reps=g_reps, warmup=1)
    t_solve = timeit(lambda: bare(M, b), reps=g_reps, warmup=1)
    over_solve = t_ladder / t_solve - 1.0

    # the wrapper in isolation, amortized over an empty thunk — the absolute
    # per-dispatch floor (µs), independent of how big the kernel is
    z = jnp.zeros(())
    t_wrap = timeit(
        lambda: ladder_call("kernel.dispatch", (("noop", lambda: z),)),
        reps=max(reps, 3), warmup=1,
    )

    results["guards"] = {
        "kernel_anchor": {"n": n, "d": d, "m": m},
        "kernel_guarded_s": t_guarded, "kernel_bare_s": t_bare,
        "kernel_overhead_frac": over_kernel,
        "solve_d": sd, "solve_ladder_s": t_ladder, "solve_bare_s": t_solve,
        "solve_overhead_frac": over_solve,
        "ladder_call_floor_s": t_wrap,
    }
    emit("resilience_guard_kernel", t_guarded * 1e6,
         f"overhead={over_kernel * 100:.2f}%")
    emit("resilience_guard_solve", t_ladder * 1e6,
         f"overhead={over_solve * 100:.2f}%")
    emit("resilience_ladder_floor", t_wrap * 1e6, "empty thunk")


def bench_ckpt(results: dict, shapes: dict, reps: int) -> None:
    """save/restore latency across a state-size ladder (atomic write + msgpack
    encode per MB)."""
    rows: dict = {}
    for mb in shapes["ckpt_mb"]:
        n_f32 = mb * (1 << 20) // 4
        tree = {
            "a": jnp.arange(n_f32 // 2, dtype=jnp.float32),
            "b": {"c": jnp.ones((n_f32 // 2,), jnp.bfloat16),
                  "step": jnp.int32(7)},
        }
        with tempfile.TemporaryDirectory() as td:
            t_save = timeit(
                lambda s=iter(range(10 ** 6)): ckpt.save(
                    td, tree, step=next(s), keep_last=2
                ),
                reps=reps, warmup=1,
            )
            t_restore = timeit(
                lambda: ckpt.restore(td, tree)[0], reps=reps, warmup=1
            )
        rows[f"{mb}MB"] = {"save_s": t_save, "restore_s": t_restore}
        emit("resilience_ckpt_save", t_save * 1e6, f"state={mb}MB")
        emit("resilience_ckpt_restore", t_restore * 1e6, f"state={mb}MB")
    results["ckpt"] = rows


def bench_resume(results: dict, shapes: dict, reps: int) -> None:
    """Resumed generate (from the mid-request snapshot) vs the same request
    cold — what a preemption costs with and without the checkpoint.

    Each timed run gets a fresh copy of the pristine mid-request directory
    (resuming writes new checkpoints, so reusing one directory would make the
    second rep a no-op) and a fresh Engine — a resumed process pays its own
    trace/compile either way, so cold runs use fresh engines too."""
    cfg = reduced(get_config("stablelm-3b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, L, n_new = shapes["batch"], shapes["L"], shapes["n_new"]
    prompts = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (B, L), 0, cfg.vocab_size)
    )

    def engine(ckdir):
        sc = ServeConfig(
            max_len=L + n_new + 2, use_sketch=True, temperature=0.7, seed=3,
            ckpt_dir=ckdir, ckpt_every=shapes["ckpt_every"],
        )
        return Engine(cfg, params, sc)

    def once(fn) -> float:
        t0 = time.perf_counter()
        fn()
        return time.perf_counter() - t0

    with tempfile.TemporaryDirectory() as td:
        # write the checkpoint trail once, then keep only a mid-request step
        pristine = pathlib.Path(td) / "pristine"
        engine(str(pristine)).generate(prompts, n_new, request_id="r")
        req = pristine / "r"
        steps = ckpt.committed_steps(req)
        mid = steps[len(steps) // 2]
        for s in steps:
            if s != mid:
                shutil.rmtree(ckpt._step_dir(str(req), s))

        t_res = []
        for i in range(reps):
            work = pathlib.Path(td) / f"run{i}"
            shutil.copytree(pristine, work)
            t_res.append(once(
                lambda w=work: engine(str(w)).generate(
                    prompts, n_new, request_id="r")))
        t_resume = float(np.median(t_res))
    t_cold = float(np.median(
        [once(lambda: engine(None).generate(prompts, n_new))
         for _ in range(reps)]))
    results["resume"] = {
        "L": L, "n_new": n_new, "resume_from_step": int(mid),
        "resume_s": t_resume, "cold_s": t_cold,
        "speedup": t_cold / t_resume,
    }
    emit("resilience_resume", t_resume * 1e6,
         f"from step {mid}, {t_cold / t_resume:.2f}x vs cold")
    emit("resilience_cold", t_cold * 1e6, f"L={L} n_new={n_new}")


def main() -> None:
    """Entry point for ``benchmarks.run resilience``."""
    shapes, reps = bench_config()
    results: dict = {}
    bench_guards(results, shapes, reps)
    bench_ckpt(results, shapes, reps)
    bench_resume(results, shapes, reps)
    payload = {
        "host": {
            "backend": jax.default_backend(),
            "device": str(jax.devices()[0]),
            "jax": jax.__version__,
        },
        "config": shapes,
        "smoke": env_flag("REPRO_BENCH_SMOKE", False),
        "results": results,
    }
    BENCH_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    emit("bench_json", 0.0, f"wrote {BENCH_PATH.name}")


if __name__ == "__main__":
    main()
