"""Paper Figure 2 reproduction: approximation error ‖f̂_S − f̂_n‖²_n vs sample
size for m ∈ {1, 2, 8, 32} and the Gaussian sketch (m=∞).

Paper settings (appendix D.2), scaled to CPU budget: Gaussian kernel with
bandwidth 1.5·n^{-1/7}, λ = 0.5·n^{-4/7}, d = 1.5·n^{3/7}, bimodal data.
Expected outcome (the paper's claim): m=1 (Nyström) is orders of magnitude
worse; a medium m closes most of the gap to Gaussian sketching.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import bimodal_data, emit
from repro.core import (
    get_kernel,
    insample_error,
    krr_exact_fitted,
    krr_sketched_fit,
    krr_sketched_fit_dense,
    make_accum_sketch,
    make_gaussian_sketch,
)


def run(ns=(500, 1000, 2000), ms=(1, 2, 8, 32), reps: int = 5, verbose=True):
    key = jax.random.PRNGKey(0)
    rows = []
    for n in ns:
        X, y, f = bimodal_data(jax.random.fold_in(key, n), n)
        bw = 1.5 * n ** (-1 / 7)
        lam = 0.5 * n ** (-4 / 7)
        d = int(1.5 * n ** (3 / 7))
        kern = get_kernel("gaussian", bandwidth=bw)
        K = kern(X, X)
        fn = krr_exact_fitted(K, y, lam)
        est_err = float(insample_error(fn, f))
        out = {"n": n, "d": d, "krr_vs_fstar": est_err}
        for m in ms:
            errs = [
                float(insample_error(
                    krr_sketched_fit(K, y, lam,
                                     make_accum_sketch(jax.random.fold_in(key, 97 * n + 31 * m + r), n, d, m)
                                     ).fitted, fn))
                for r in range(reps)
            ]
            out[f"m={m}"] = float(np.mean(errs))
        errs = [
            float(insample_error(
                krr_sketched_fit_dense(K, y, lam,
                                       make_gaussian_sketch(jax.random.fold_in(key, 7 * n + r), n, d)
                                       ).fitted, fn))
            for r in range(reps)
        ]
        out["gaussian"] = float(np.mean(errs))
        rows.append(out)
        if verbose:
            parts = " ".join(f"{k}={v:.3e}" for k, v in out.items() if k not in ("n", "d"))
            print(f"# fig2 n={n} d={d}: {parts}")
    return rows


def main():
    rows = run()
    # CSV summary (name, us_per_call→error ratio proxy, derived)
    for r in rows:
        ratio_m1 = r["m=1"] / max(r["gaussian"], 1e-30)
        ratio_m32 = r["m=32"] / max(r["gaussian"], 1e-30)
        emit(f"fig2_n{r['n']}", 0.0,
             f"nystrom/gauss={ratio_m1:.1f}x accum_m32/gauss={ratio_m32:.2f}x")
    return rows


if __name__ == "__main__":
    main()
