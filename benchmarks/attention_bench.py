"""Long-context serving bench: exact vs AccumSketch-compressed decode.

Two claims, measured:

  * PREFILL — the batched one-dispatch prefill (`prefill_with_cache`) vs the
    seed's token-by-token loop (L jitted dispatches) at the 4k-context anchor.
    Acceptance: ≥ 5× wall-clock.
  * DECODE — tokens/s and cache bytes for exact KV vs sketched decode across
    a 4k → 512k context ladder. The sketched cache is O(d_slots) — its bytes
    are FLAT in context length while the exact cache grows linearly (the
    paper's fixed-effective-size accumulation claim, transported to serving).

Decode steps are timed against a cache of the target length (contents don't
affect cost — the masked attention reads every slot either way), so the 512k
row doesn't require a 512k prefill on the CPU bench host.

Run:   PYTHONPATH=src python -m benchmarks.run attention
Smoke: PYTHONPATH=src python -m benchmarks.run attention --smoke
       (tiny shapes, 1 rep — CI's configuration; JSON tagged "smoke": true)

Writes ``BENCH_attention.json`` at the repo root.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.configs import get_config, reduced
from repro.configs.base import SketchAttnCfg
from repro.models.model import init_params
from repro.serve.engine import Engine, ServeConfig
from repro.util import env_flag

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
BENCH_PATH = REPO_ROOT / "BENCH_attention.json"

# reduced stablelm-3b (attention-only pattern) with a production-shaped slot
# budget: d_slots fixed while the context ladder grows past it
FULL = dict(prefill_ctx=4096, decode_ctxs=[4096, 32768, 131072, 524288],
            d_slots=256, m_r=2, n_new=16, batch=1)
SMOKE = dict(prefill_ctx=128, decode_ctxs=[1024, 4096],
             d_slots=64, m_r=2, n_new=4, batch=1)


def bench_config() -> tuple[dict, int]:
    """(shape dict, reps) — smoke honors REPRO_BENCH_SMOKE like every suite."""
    if env_flag("REPRO_BENCH_SMOKE", False):
        return SMOKE, 1
    return FULL, 2


def _engine(cfg_b, max_len: int, use_sketch: bool, params) -> Engine:
    sc = ServeConfig(max_len=max_len, use_sketch=use_sketch)
    return Engine(cfg_b, params, sc)


def _cache_bytes(cache) -> int:
    return int(sum(x.nbytes for x in jax.tree_util.tree_leaves(cache)))


def bench_prefill(results: dict, cfg_b, params, shapes: dict, reps: int) -> None:
    """Batched one-dispatch prefill vs the sequential token loop (sketched
    cache — the serving configuration the tentpole targets)."""
    L, B = shapes["prefill_ctx"], shapes["batch"]
    eng = _engine(cfg_b, L + shapes["n_new"], True, params)
    prompts = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (B, L), 0, cfg_b.vocab_size)
    )
    t_batched = timeit(
        lambda: eng.prefill_tokens(eng.new_cache(B), prompts)[1],
        reps=reps, warmup=1,
    )
    # the sequential loop is L jitted dispatches; one rep is plenty (and the
    # warmup call already compiled the shared decode step)
    t_seq = timeit(
        lambda: eng.prefill_tokens_sequential(eng.new_cache(B), prompts)[1],
        reps=1, warmup=0,
    )
    speedup = t_seq / t_batched
    results["prefill"] = {
        "ctx": L, "batch": B,
        "sequential_s": t_seq, "batched_s": t_batched, "speedup": speedup,
    }
    emit("serve_prefill_sequential", t_seq * 1e6, f"ctx={L}")
    emit("serve_prefill_batched", t_batched * 1e6, f"speedup={speedup:.1f}x")


def bench_decode(results: dict, cfg_b, params, shapes: dict, reps: int) -> None:
    """tokens/s + cache bytes across the context ladder, both cache flavors."""
    B, n_new = shapes["batch"], shapes["n_new"]
    ladder: dict = {}
    for ctx in shapes["decode_ctxs"]:
        row: dict = {}
        for flavor, use_sketch in (("exact", False), ("sketched", True)):
            eng = _engine(cfg_b, ctx + n_new, use_sketch, params)
            cache = eng.new_cache(B)
            tok = jnp.zeros((B,), jnp.int32)
            t = timeit(
                lambda e=eng, c=cache, k=tok, p=ctx: e._decode(
                    e.params, c, k, jnp.int32(p), n_steps=n_new
                )[0],
                reps=reps, warmup=1,
            )
            row[flavor] = {
                "tokens_per_s": B * n_new / t,
                "cache_bytes": _cache_bytes(cache),
            }
            emit(f"serve_decode_{flavor}", t / n_new * 1e6,
                 f"ctx={ctx} tok/s={row[flavor]['tokens_per_s']:.1f}")
        row["cache_ratio"] = row["exact"]["cache_bytes"] / row["sketched"]["cache_bytes"]
        ladder[str(ctx)] = row
    results["decode"] = ladder


def main() -> None:
    """Entry point for ``benchmarks.run attention``."""
    shapes, reps = bench_config()
    base = reduced(get_config("stablelm-3b"))
    cfg_b = dataclasses.replace(
        base,
        sketch_attn=SketchAttnCfg(
            d_slots=shapes["d_slots"], m=base.sketch_attn.m, m_r=shapes["m_r"]
        ),
    )
    params = init_params(jax.random.PRNGKey(0), cfg_b)
    results: dict = {}
    bench_prefill(results, cfg_b, params, shapes, reps)
    bench_decode(results, cfg_b, params, shapes, reps)
    payload = {
        "host": {
            "backend": jax.default_backend(),
            "device": str(jax.devices()[0]),
            "jax": jax.__version__,
        },
        "config": shapes,
        "smoke": env_flag("REPRO_BENCH_SMOKE", False),
        "results": results,
    }
    BENCH_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    emit("bench_json", 0.0, f"wrote {BENCH_PATH.name}")


if __name__ == "__main__":
    main()
