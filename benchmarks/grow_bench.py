"""Batched vs sequential growth: the engine's cost model, measured.

Times the two ways of growing a sketch m → m+B:

  * SEQUENTIAL — B ``accum_step`` launches, each a full sweep over K (the
    Pallas gather→GEMM path reads every K tile per step) or a full
    kernel-evaluation pass over X on the matrix-free path;
  * BATCHED — ONE ``accum_grow_batched`` pass folding all B slabs, with the
    survivor rescales telescoped into the tile writes and both d×d W pieces
    gathered from the same sweep.

Also times the doubling-schedule growth 1 → m_max on the matrix-free path
(O(log m) passes vs m passes — the pass counts land in the JSON next to the
wall times) and the measured autotune cache cold (first call measures the
candidate tilings) vs warm (persisted winner served from the JSON cache).

Run:   PYTHONPATH=src python -m benchmarks.run grow
Smoke: PYTHONPATH=src python -m benchmarks.run grow --smoke
       (tiny shapes, 1 rep — CI's configuration; JSON tagged "smoke": true)

Writes ``BENCH_grow.json`` at the repo root.
"""
from __future__ import annotations

import json
import os
import pathlib
import tempfile

import jax

from benchmarks.common import bimodal_data, emit, timeit
from repro.core import apply as A
from repro.core.kernel_op import KernelOperator
from repro.core.sketch import make_accum_sketch
from repro.kernels.accum_apply import autotune
from repro.kernels.accum_apply.ops import default_interpret, sketch_right_kernel
from repro.util import env_flag

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
BENCH_PATH = REPO_ROOT / "BENCH_grow.json"

# The acceptance anchor: dense Pallas path at n=4096, d=64, B=8 (each
# sequential step re-reads all of K; the batch reads it once).  The matfree
# sweep grows 1 → m_max at n up to 131072, where a dense K cannot exist.
FULL = dict(n_dense=4096, d=64, B=8, m_max=32, ns_matfree=[4096, 131072],
            bandwidth=0.75)
SMOKE = dict(n_dense=256, d=16, B=4, m_max=8, ns_matfree=[256, 1024],
             bandwidth=0.75)


def bench_config() -> tuple[dict, int]:
    if env_flag("REPRO_BENCH_SMOKE", False):
        return SMOKE, 1
    return FULL, 2


def bench_dense_anchor(results: dict, cfg: dict, reps: int) -> None:
    """B sequential Pallas step launches vs one batched launch on dense K."""
    key = jax.random.PRNGKey(0)
    n, d, B = cfg["n_dense"], cfg["d"], cfg["B"]
    K = jax.random.normal(key, (n, n))
    K = 0.5 * (K + K.T)
    state = A.accum_init(key, n, d, B)

    def seq(K, s):
        for _ in range(B):
            s = A.accum_step(K, s, use_kernel=True)
        return s.C, s.W

    def bat(K, s):
        s = A.accum_grow_batched(K, s, B, use_kernel=True)
        return s.C, s.W

    t_seq = timeit(jax.jit(seq), K, state, reps=reps)
    t_bat = timeit(jax.jit(bat), K, state, reps=reps)
    speedup = t_seq / max(t_bat, 1e-9)
    tag = f"n{n}_d{d}_B{B}_f32"
    emit(f"grow_sequential_{tag}", t_seq * 1e6,
         f"{B} accum_step launches ({B} reads of K)")
    emit(f"grow_batched_{tag}", t_bat * 1e6,
         f"one accum_grow pass; seq/batched={speedup:.1f}x")
    results[f"grow_sequential_{tag}"] = {"us": t_seq * 1e6, "passes": B}
    results[f"grow_batched_{tag}"] = {
        "us": t_bat * 1e6, "passes": 1, "speedup_vs_sequential": speedup}


def bench_matfree_growth(results: dict, cfg: dict, reps: int) -> None:
    """Growing 1 → m_max matrix-free: m_max unit passes vs the doubling
    ladder's O(log m) passes — same kernel-eval count, one X sweep per batch
    instead of per slab."""
    key = jax.random.PRNGKey(1)
    d, m_max = cfg["d"], cfg["m_max"]
    schedule = A.doubling_schedule(0, m_max)
    for n in cfg["ns_matfree"]:
        X, _, _ = bimodal_data(jax.random.fold_in(key, n), n)
        op = KernelOperator(X, "gaussian", bandwidth=cfg["bandwidth"])
        this_reps = 1 if n >= 65536 else reps

        def seq(X_, s, op=op):
            return A.accum_grow(KernelOperator(X_, op.kernel, op.bandwidth),
                                s, m_max, use_kernel=False).C

        def bat(X_, s, op=op):
            o = KernelOperator(X_, op.kernel, op.bandwidth)
            for b in schedule:
                s = A.accum_grow_batched(o, s, b, use_kernel=False)
            return s.C

        state = A.accum_init(key, n, d, m_max)
        t_seq = timeit(jax.jit(seq), X, state, reps=this_reps)
        t_bat = timeit(jax.jit(bat), X, state, reps=this_reps)
        speedup = t_seq / max(t_bat, 1e-9)
        tag = f"n{n}_d{d}_m{m_max}"
        emit(f"grow_matfree_sequential_{tag}", t_seq * 1e6,
             f"{m_max} kernel-eval passes over X")
        emit(f"grow_matfree_doubling_{tag}", t_bat * 1e6,
             f"{len(schedule)} passes (O(log m)); seq/batched={speedup:.1f}x")
        results[f"grow_matfree_sequential_{tag}"] = {
            "us": t_seq * 1e6, "passes": m_max}
        results[f"grow_matfree_doubling_{tag}"] = {
            "us": t_bat * 1e6, "passes": len(schedule),
            "speedup_vs_sequential": speedup}


def bench_autotune_cold_warm(results: dict, cfg: dict, reps: int) -> None:
    """First call at a key measures the candidate tilings (cold); every later
    call is a cache hit (warm).  Uses a throwaway cache file so the run never
    touches — or depends on — the user's persisted cache."""
    key = jax.random.PRNGKey(2)
    n, d = cfg["n_dense"], cfg["d"]
    K = jax.random.normal(key, (n, n))
    sk = make_accum_sketch(key, n, d, max(cfg["B"] // 2, 1))
    saved = {k: os.environ.get(k) for k in (autotune.ENV_CACHE, autotune.ENV_GATE)}
    with tempfile.TemporaryDirectory() as tmp:
        os.environ[autotune.ENV_CACHE] = str(pathlib.Path(tmp) / "autotune.json")
        os.environ[autotune.ENV_GATE] = "1"
        try:
            t_cold = timeit(lambda: sketch_right_kernel(K, sk), reps=1,
                            warmup=0)
            t_warm = timeit(lambda: sketch_right_kernel(K, sk), reps=reps)
            blocks = autotune.lookup("accum_apply", (n, n, d, sk.m), K.dtype,
                                     default_interpret())
        finally:
            for k, v in saved.items():
                if v is None:
                    os.environ.pop(k, None)
                else:
                    os.environ[k] = v
    emit("autotune_cold", t_cold * 1e6,
         f"first call: measures candidates, persists winner {blocks}")
    emit("autotune_warm", t_warm * 1e6,
         f"cache hit; cold/warm={t_cold / max(t_warm, 1e-9):.1f}x")
    results["autotune_cold"] = {"us": t_cold * 1e6, "winner": list(blocks or ())}
    results["autotune_warm"] = {"us": t_warm * 1e6}


def main() -> None:
    cfg, reps = bench_config()
    results: dict = {}
    bench_dense_anchor(results, cfg, reps)
    bench_matfree_growth(results, cfg, reps)
    bench_autotune_cold_warm(results, cfg, reps)
    payload = {
        "host": {
            "backend": jax.default_backend(),
            "device": str(jax.devices()[0]),
            "jax": jax.__version__,
        },
        "config": cfg,
        "smoke": env_flag("REPRO_BENCH_SMOKE", False),
        "results": results,
    }
    BENCH_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    emit("bench_json", 0.0, f"wrote {BENCH_PATH.name}")


if __name__ == "__main__":
    main()
