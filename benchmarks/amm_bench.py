"""AMM extension benchmark (paper conclusion): sketched AᵀB error/time vs d, m."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, timeit
from repro.core import amm, amm_error, make_accum_sketch


def main():
    key = jax.random.PRNGKey(0)
    n, p, q = 8192, 64, 64
    # structured (shared low-rank factor) matrices — the regime the paper's
    # kernel applications live in; i.i.d.-noise AᵀB has no signal to preserve
    U = jax.random.normal(key, (n, 8)) / 8**0.5
    A = (U @ jax.random.normal(jax.random.fold_in(key, 2), (8, p))
         + 0.1 * jax.random.normal(jax.random.fold_in(key, 3), (n, p)))
    B = (U @ jax.random.normal(jax.random.fold_in(key, 4), (8, q))
         + 0.1 * jax.random.normal(jax.random.fold_in(key, 5), (n, q)))
    t_exact = timeit(jax.jit(lambda a, b: a.T @ b), A, B)
    for d, m in [(256, 1), (256, 4), (1024, 1), (1024, 4)]:
        sk = make_accum_sketch(jax.random.fold_in(key, d + m), n, d, m)
        t = timeit(jax.jit(amm), A, B, sk)
        errs = [
            float(amm_error(A, B, make_accum_sketch(jax.random.fold_in(key, 77 * r + d + m), n, d, m)))
            for r in range(5)
        ]
        emit(f"amm_d{d}_m{m}", t * 1e6,
             f"rel_err={np.mean(errs):.3f} exact/sketch_time={t_exact/max(t,1e-9):.1f}x")


if __name__ == "__main__":
    main()
