"""Matrix-free sketching benchmark: the O(n²) memory wall, removed.

Runs the ``KernelOperator`` pipeline — C = K S and W = SᵀKS streamed straight
from the dataset, K never materialized — at n far beyond what a dense n×n
kernel matrix allows on this host, including a full KRR fit + predict at
n = 131072 (the dense path is *refused* at that shape: the f32 Gram matrix
alone is 64 GiB and the sqdist intermediates triple it).  At a small anchor
shape the dense and matrix-free paths are timed side by side, and the JSON
records the dense-vs-matfree memory table the README anchors to.

Run:   PYTHONPATH=src python -m benchmarks.run matfree
Smoke: PYTHONPATH=src python -m benchmarks.run matfree --smoke
       (tiny shapes, 1 rep — CI's configuration; JSON tagged "smoke": true)

Writes ``BENCH_matfree.json`` at the repo root.
"""
from __future__ import annotations

import json
import pathlib

import jax

from benchmarks.common import bimodal_data, emit, timeit
from repro.core import apply as A
from repro.core.kernel_op import KernelOperator
from repro.core.krr import krr_sketched_fit
from repro.core.sketch import make_accum_sketch
from repro.util import env_flag

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
BENCH_PATH = REPO_ROOT / "BENCH_matfree.json"

# n sweep; the last entry is far past the dense wall (64 GiB Gram matrix)
FULL = dict(ns=[4096, 16384, 131072], d=64, m=4, n_test=2048, bandwidth=0.75,
            lam=1e-3)
SMOKE = dict(ns=[256, 1024], d=16, m=2, n_test=64, bandwidth=0.75, lam=1e-3)


def bench_config() -> tuple[dict, int]:
    if env_flag("REPRO_BENCH_SMOKE", False):
        return SMOKE, 1
    return FULL, 2


def _mem_row(n: int, p: int, d: int) -> dict:
    """Bytes a dense K needs vs what the matrix-free path ever holds
    (the dataset + C; the streamed kernel slab is chunk-bounded)."""
    return {
        "dense_K_bytes": 4 * n * n,
        "matfree_bytes": 4 * n * (p + d),
        "ratio": (4 * n * n) / max(4 * n * (p + d), 1),
    }


def main() -> None:
    cfg, reps = bench_config()
    d, m = cfg["d"], cfg["m"]
    key = jax.random.PRNGKey(0)
    results: dict = {}
    memory: dict = {}
    top_n = max(cfg["ns"])

    for n in cfg["ns"]:
        X, y, _ = bimodal_data(jax.random.fold_in(key, n), n)
        p = X.shape[1]
        Xt = X[: cfg["n_test"]] + 0.01
        op = KernelOperator(X, "gaussian", bandwidth=cfg["bandwidth"])
        sk = make_accum_sketch(jax.random.fold_in(key, 2 * n), n, d, m)
        tag = f"n{n}_d{d}_m{m}"
        memory[tag] = _mem_row(n, p, d)
        this_reps = 1 if n >= 65536 else reps

        t_cw = timeit(
            jax.jit(lambda o, s: o.sketch_both(s, use_kernel=False)), op, sk,
            reps=this_reps)
        emit(f"matfree_sketch_both_{tag}", t_cw * 1e6,
             f"streamed C,W; K never formed (dense would be "
             f"{memory[tag]['dense_K_bytes'] / 2**30:.1f} GiB)")
        results[f"matfree_sketch_both_{tag}"] = {"us": t_cw * 1e6}

        def fit_predict(op=op, y=y, sk=sk, Xt=Xt):
            model = krr_sketched_fit(op, y, cfg["lam"], sk, use_kernel=False)
            return model.predict(Xt)

        t_fit = timeit(fit_predict, reps=this_reps)
        emit(f"matfree_krr_fit_predict_{tag}", t_fit * 1e6,
             f"fit+predict({cfg['n_test']}) straight from X")
        results[f"matfree_krr_fit_predict_{tag}"] = {"us": t_fit * 1e6}

        if n == min(cfg["ns"]):
            # dense comparison only at the smallest shape (it's the slow one)
            K = op.dense(force=True)
            t_dense = timeit(
                jax.jit(lambda K, s: A.sketch_both(K, s, use_kernel=False)),
                K, sk, reps=this_reps)
            emit(f"dense_sketch_both_{tag}", t_dense * 1e6,
                 f"materialized K path; matfree/dense={t_cw / max(t_dense, 1e-9):.2f}x time, "
                 f"{memory[tag]['ratio']:.0f}x memory")
            results[f"dense_sketch_both_{tag}"] = {"us": t_dense * 1e6}
            del K

    # the acceptance claim: the dense path is refused at the top shape
    X, _, _ = bimodal_data(jax.random.fold_in(key, top_n), top_n)
    refused = None
    try:
        KernelOperator(X, "gaussian", bandwidth=cfg["bandwidth"]).dense()
    except ValueError as e:
        refused = str(e)
    if refused is None and top_n > 32768:
        raise RuntimeError("dense() should have been refused at the top shape")
    emit("dense_refused_at_top_n", 0.0,
         f"n={top_n}: {'refused' if refused else 'allowed (small smoke shape)'}")

    payload = {
        "host": {
            "backend": jax.default_backend(),
            "device": str(jax.devices()[0]),
            "jax": jax.__version__,
        },
        "config": cfg,
        "smoke": env_flag("REPRO_BENCH_SMOKE", False),
        "results": results,
        "memory": memory,
        "dense_refused_at_top_n": refused is not None,
        "dense_refusal_message": refused,
    }
    BENCH_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    emit("bench_json", 0.0, f"wrote {BENCH_PATH.name}")


if __name__ == "__main__":
    main()
