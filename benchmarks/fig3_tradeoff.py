"""Paper Figure 3/4 reproduction: accuracy–efficiency trade-off of
  Gaussian sketching | very sparse RP | Nyström (m=1) | accumulation (m=4)
on held-out test error vs wall-clock training time.

The paper uses UCI datasets (RQA/CASP/GAS); offline we use the same bimodal
synthetic family (the hard high-incoherence case the paper motivates with) and
the paper's Matérn-1.5 kernel settings: λ = 0.9·n^{-(3+dX)/(3+2dX)},
d = 1.5·n^{dX/(3+2dX)} with dX=3. Expected: accumulation m=4 ≈ Gaussian
accuracy at ≈ Nyström runtime.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bimodal_data, emit
from repro.core import (
    get_kernel,
    krr_sketched_fit_dense,
    krr_sketched_fit_matfree,
    make_accum_sketch,
    make_gaussian_sketch,
    make_nystrom_sketch,
    make_sparse_rp,
)


def _test_err(model, Xt, ft):
    pred = model.predict(Xt)
    return float(jnp.mean((pred - ft) ** 2))


def run(ns=(1000, 2000, 4000), reps: int = 3, verbose=True):
    key = jax.random.PRNGKey(1)
    dX = 3
    rows = []
    for n in ns:
        X, y, f = bimodal_data(jax.random.fold_in(key, n), int(n * 1.25))
        Xt, ft = X[n:], f[n:]
        X, y = X[:n], y[:n]
        lam = 0.9 * n ** (-(3 + dX) / (3 + 2 * dX))
        d = int(1.5 * n ** (dX / (3 + 2 * dX)))
        kern = get_kernel("matern", bandwidth=1.0, nu=1.5)
        out = {"n": n, "d": d}
        K = None

        def dense_fit(S):
            nonlocal K
            if K is None:
                K = kern(X, X)
            return krr_sketched_fit_dense(K, y, lam, S, X, kern)

        methods = {
            "gaussian": lambda r: dense_fit(make_gaussian_sketch(jax.random.fold_in(key, r), n, d)),
            "sparse_rp": lambda r: dense_fit(make_sparse_rp(jax.random.fold_in(key, r + 50), n, d)),
            "nystrom": lambda r: krr_sketched_fit_matfree(
                X, y, lam, make_nystrom_sketch(jax.random.fold_in(key, r + 100), n, d), kern),
            "accum_m4": lambda r: krr_sketched_fit_matfree(
                X, y, lam, make_accum_sketch(jax.random.fold_in(key, r + 150), n, d, 4), kern),
        }
        for name, fit in methods.items():
            errs, times = [], []
            for r in range(reps):
                t0 = time.perf_counter()
                model = fit(r)
                jax.block_until_ready(model.theta)
                times.append(time.perf_counter() - t0)
                errs.append(_test_err(model, Xt, ft))
            out[name] = (float(np.mean(errs)), float(np.median(times)))
        rows.append(out)
        if verbose:
            s = " ".join(f"{k}:err={v[0]:.4f},t={v[1]*1e3:.0f}ms"
                         for k, v in out.items() if isinstance(v, tuple))
            print(f"# fig3 n={n} d={d}: {s}")
    return rows


def main():
    rows = run()
    for r in rows:
        g, ny, ac = r["gaussian"], r["nystrom"], r["accum_m4"]
        emit(
            f"fig3_n{r['n']}", ac[1] * 1e6,
            f"accum_err/gauss_err={ac[0]/max(g[0],1e-30):.2f} "
            f"accum_time/nystrom_time={ac[1]/max(ny[1],1e-9):.2f} "
            f"gauss_time/accum_time={g[1]/max(ac[1],1e-9):.1f}",
        )
    return rows


if __name__ == "__main__":
    main()
