"""Benchmark harness: one module per paper table/figure + system benches.
Prints ``name,us_per_call,derived`` CSV rows. Suites that track a perf
trajectory (``kernels``, ``matfree``, ``grow``, ``distributed``) also write a
BENCH_*.json at the repo root — old-vs-new kernel and structural-vs-dense
timings live in ``BENCH_kernels.json``; the matrix-free operator's
past-the-n²-wall numbers (KRR at n = 131072, dense refused) live in
``BENCH_matfree.json``; batched-vs-sequential growth and the autotune
cold/warm timings live in ``BENCH_grow.json``; the sharded weak/strong
scaling table (per-device C ∝ 1/D) lives in ``BENCH_distributed.json`` (run
that suite under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``);
the sampling-scheme zoo's error-vs-m curves (uniform / leverage / poisson on
the KRR anchor) live in ``BENCH_schemes.json``; the serving-layer numbers —
batched-vs-sequential prefill at the 4k anchor plus exact-vs-sketched decode
tokens/s and cache bytes across a 4k → 512k context ladder — live in
``BENCH_attention.json``; the resilience-layer numbers — fault-guard /
degradation-ladder overhead on the kernel hot path (< 5% acceptance),
checkpoint save/restore latency vs state size, and resumed-vs-cold generate —
live in ``BENCH_resilience.json``.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig2 amm   # subset
  PYTHONPATH=src python -m benchmarks.run kernels    # refresh BENCH_kernels.json
  PYTHONPATH=src python -m benchmarks.run grow       # refresh BENCH_grow.json

``--smoke`` runs suites that honor it (``kernels``, ``matfree``, ``grow``,
``distributed``, ``schemes``, ``attention``) at tiny
shapes with a single rep — CI uses it to regenerate the JSONs on every PR
without timing out; they are tagged ``"smoke": true`` so real trajectory
numbers are never overwritten by CI artifacts.
"""
from __future__ import annotations

import os
import sys
import traceback

from benchmarks import amm_bench, attention_bench, distributed_bench
from benchmarks import falkon_bench, fig1_toy
from benchmarks import fig2_approx_error, fig3_tradeoff, grow_bench
from benchmarks import kernel_bench, matfree_bench, resilience_bench
from benchmarks import roofline, schemes_bench, train_bench

SUITES = {
    "fig1": fig1_toy.main,          # paper Fig. 1 (toy tradeoff)
    "fig2": fig2_approx_error.main, # paper Fig. 2 (approx error vs m)
    "fig3": fig3_tradeoff.main,     # paper Fig. 3/4 (accuracy–efficiency)
    "falkon": falkon_bench.main,    # paper appendix D.3 (Falkon-style PCG)
    "amm": amm_bench.main,          # paper §5 extension
    "kernels": kernel_bench.main,   # Pallas kernels + O(nmd) claim
    "matfree": matfree_bench.main,  # matrix-free operator: past the n² wall
    "grow": grow_bench.main,        # batched rank-B growth + autotune cache
    "schemes": schemes_bench.main,  # sampling-scheme zoo: error vs m
    "attention": attention_bench.main,  # serving: prefill speedup + decode ladder
    "distributed": distributed_bench.main,  # sharded (C, W): weak/strong scaling
    "resilience": resilience_bench.main,  # guard overhead + ckpt/resume latency
    "train": train_bench.main,      # end-to-end step throughput
    "roofline": roofline.main,      # dry-run roofline table
}


def main() -> None:
    argv = sys.argv[1:]
    if "--smoke" in argv:
        # must be set before any suite builds its shapes (they read it lazily)
        os.environ["REPRO_BENCH_SMOKE"] = "1"
        argv = [a for a in argv if a != "--smoke"]
    picks = argv or list(SUITES)
    print("name,us_per_call,derived")
    failed = []
    for name in picks:
        try:
            SUITES[name]()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED suites: {failed}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
