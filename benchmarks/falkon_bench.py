"""Paper appendix D.3: the accumulation sketch combined with a Falkon-style
preconditioned-CG solver. Compares

  direct      — Woodbury Cholesky solve of (SᵀK²S + nλSᵀKS)θ = SᵀKy
  falkon-pcg  — preconditioned CG on the same system (matrix-free matvecs,
                d×d Cholesky preconditioner; `krr_sketched_fit_pcg`)

at the paper's hyper-parameters on the bimodal distribution. The claim checked
(paper §3.3): accumulation keeps the Falkon preconditioner d×d where a vanilla
m·d-landmark Nyström needs (md)×(md) — so the PCG path matches the direct
path's accuracy at O(n·m·d·iters) with no O(d³)-dominated assembly.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import bimodal_data, emit
from repro.core import (
    get_kernel,
    insample_error,
    krr_exact_fitted,
    krr_sketched_fit_matfree,
    krr_sketched_fit_pcg,
    make_accum_sketch,
)


def run(ns=(1000, 2000, 4000), reps: int = 3, verbose: bool = True):
    key = jax.random.PRNGKey(5)
    rows = []
    for n in ns:
        X, y, f = bimodal_data(jax.random.fold_in(key, n), n)
        lam = 0.5 * n ** (-4 / 7)
        d = int(1.5 * n ** (3 / 7))
        kern = get_kernel("gaussian", bandwidth=1.5 * n ** (-1 / 7))
        fn = krr_exact_fitted(kern(X, X), y, lam) if n <= 4000 else None
        for name, fit in [
            ("direct", lambda sk: krr_sketched_fit_matfree(X, y, lam, sk, kern)),
            ("falkon_pcg", lambda sk: krr_sketched_fit_pcg(
                X, y, lam, sk, kern, iters=40)),
        ]:
            errs, ts = [], []
            for r in range(reps):
                sk = make_accum_sketch(jax.random.fold_in(key, 97 * r), n, d, m=4)
                t0 = time.perf_counter()
                model = fit(sk)
                jax.block_until_ready(model.fitted)
                ts.append(time.perf_counter() - t0)
                if fn is not None:
                    errs.append(float(insample_error(model.fitted, fn)))
            emit(
                f"falkon_{name}_n{n}",
                np.median(ts) * 1e6,
                f"err={np.mean(errs):.3e}" if errs else "",
            )
            rows.append((n, name, np.mean(errs) if errs else float("nan")))
    # the PCG estimator must match the direct solve statistically
    by = {}
    for n, name, e in rows:
        by.setdefault(n, {})[name] = e
    for n, d_ in by.items():
        assert d_["falkon_pcg"] < 4.0 * d_["direct"] + 1e-6, (n, d_)
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
