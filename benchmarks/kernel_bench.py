"""Microbenchmarks: Pallas kernels (interpret mode — correctness-path timing)
vs their XLA reference implementations, plus the structural-vs-dense sketch
application speedup (the paper's O(nmd) claim measured)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core.apply import sketch_right
from repro.core.sketch import make_accum_sketch
from repro.kernels.accum_apply.ref import accum_apply_ref
from repro.kernels.landmark_attention.ref import landmark_attention_ref


def main():
    key = jax.random.PRNGKey(0)

    # --- paper claim: structural K·S is O(nmd), dense K·S is O(n²d) -------- #
    n, d, m = 4096, 64, 4
    K = jax.random.normal(key, (n, n))
    sk = make_accum_sketch(key, n, d, m)
    S = sk.dense()
    t_struct = timeit(jax.jit(lambda K, sk: sketch_right(K, sk)), K, sk)
    t_dense = timeit(jax.jit(lambda K, S: K @ S), K, S)
    emit("sketch_right_structural", t_struct * 1e6,
         f"dense/structural={t_dense/max(t_struct,1e-9):.1f}x n={n} d={d} m={m}")
    emit("sketch_right_dense", t_dense * 1e6, "")

    # --- Pallas kernel oracle timings (XLA ref path; kernel itself runs in
    #     interpret mode on CPU, timed in tests for correctness only) ------- #
    t_ref = timeit(jax.jit(accum_apply_ref), K[:, :1024], sk.indices % 1024, sk.coef)
    emit("accum_apply_ref_1024", t_ref * 1e6, "oracle path")

    S_len, Dh, L = 4096, 128, 256
    q = jax.random.normal(key, (S_len, Dh))
    kt = jax.random.normal(key, (L, Dh))
    M = jax.random.normal(key, (L, Dh))
    t_lm = timeit(jax.jit(landmark_attention_ref), q, kt, M)
    # exact attention for comparison: O(S²) vs O(S·L)
    kfull = jax.random.normal(key, (S_len, Dh))
    t_full = timeit(
        jax.jit(lambda q, k: jax.nn.softmax(q @ k.T / Dh**0.5, axis=-1) @ k), q, kfull
    )
    emit("landmark_attention_ref", t_lm * 1e6,
         f"exact/landmark={t_full/max(t_lm,1e-9):.1f}x S={S_len} L={L}")


if __name__ == "__main__":
    main()
