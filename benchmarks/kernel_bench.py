"""Microbenchmarks for the accum_apply kernel family.

Times the seed scalar-gather Pallas kernel against the vectorized gather→GEMM
rewrite, the fused (K S, SᵀK S) single-sweep kernel against the two-pass
composition, the structural-vs-dense sketch application (the paper's O(nmd)
claim), and the progressive engine's O(n·d) incremental step against the
from-scratch recompute — then writes the results to ``BENCH_kernels.json`` at
the repo root so the perf trajectory is tracked across PRs.

Run:   PYTHONPATH=src python -m benchmarks.run kernels
Smoke: PYTHONPATH=src python -m benchmarks.run kernels --smoke
       (tiny shapes, 1 rep — the CI bench-smoke job's configuration; the JSON
       is tagged "smoke": true so it never masquerades as trajectory numbers)
"""
from __future__ import annotations

import json
import pathlib

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import apply as A
from repro.core.apply import sketch_right
from repro.core.sketch import make_accum_sketch
from repro.kernels.accum_apply.kernel import accum_apply, accum_apply_scalar
from repro.kernels.accum_apply.ops import (
    autotune_blocks,
    sketch_both_kernel,
    sketch_left_kernel,
    sketch_right_kernel,
)
from repro.kernels.landmark_attention.ref import landmark_attention_ref
from repro.util import env_flag

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
BENCH_PATH = REPO_ROOT / "BENCH_kernels.json"

# The anchor shape every PR's numbers are compared at (f32).
ANCHOR = dict(R=4096, N=8192, d=64, m=4)
SMOKE_ANCHOR = dict(R=256, N=512, d=16, m=2)


def bench_config() -> tuple[dict, int]:
    """(anchor shapes, reps) — tiny and single-rep under ``--smoke``."""
    if env_flag("REPRO_BENCH_SMOKE", False):
        return SMOKE_ANCHOR, 1
    return ANCHOR, 3


def bench_accum_apply(results: dict, anchor: dict, reps: int) -> None:
    """Seed scalar-loop kernel vs vectorized gather→GEMM at the anchor shape."""
    key = jax.random.PRNGKey(0)
    R, N, d, m = anchor["R"], anchor["N"], anchor["d"], anchor["m"]
    K = jax.random.normal(key, (R, N))
    sk = make_accum_sketch(key, N, d, m)
    coef = sk.coef.astype(jnp.float32)
    bm, bd = autotune_blocks(R, N, d, m, jnp.float32)

    t_new = timeit(
        lambda: accum_apply(K, sk.indices, coef, bm=bm, bd=bd, interpret=True),
        reps=reps)
    # seed defaults: bm=256, bd=8, scalar per-column gather loop
    t_old = timeit(
        lambda: accum_apply_scalar(K, sk.indices, coef, bm=256, bd=8,
                                   interpret=True), reps=min(reps, 2))
    speedup = t_old / max(t_new, 1e-9)
    tag = f"R{R}_N{N}_d{d}_m{m}_f32"
    emit(f"accum_apply_gemm_{tag}", t_new * 1e6, f"scalar/gemm={speedup:.1f}x")
    emit(f"accum_apply_scalar_{tag}", t_old * 1e6, "seed baseline")
    results[f"accum_apply_gemm_{tag}"] = {
        "us": t_new * 1e6, "speedup_vs_scalar": speedup, "blocks": [bm, bd]}
    results[f"accum_apply_scalar_{tag}"] = {"us": t_old * 1e6}


def bench_fused_both(results: dict, anchor: dict, reps: int) -> None:
    """Fused single-sweep (C, W) vs the two-pass kernel composition."""
    key = jax.random.PRNGKey(1)
    n, d, m = anchor["R"], anchor["d"], anchor["m"]
    K = jax.random.normal(key, (n, n))
    K = 0.5 * (K + K.T)
    sk = make_accum_sketch(key, n, d, m)

    def two_pass():
        C = sketch_right_kernel(K, sk)
        return C, sketch_left_kernel(sk, C)

    t_fused = timeit(lambda: sketch_both_kernel(K, sk), reps=reps)
    t_two = timeit(two_pass, reps=reps)
    speedup = t_two / max(t_fused, 1e-9)
    tag = f"n{n}_d{d}_m{m}_f32"
    emit(f"sketch_both_fused_{tag}", t_fused * 1e6,
         f"two_pass/fused={speedup:.2f}x")
    emit(f"sketch_both_two_pass_{tag}", t_two * 1e6, "")
    results[f"sketch_both_fused_{tag}"] = {
        "us": t_fused * 1e6, "speedup_vs_two_pass": speedup}
    results[f"sketch_both_two_pass_{tag}"] = {"us": t_two * 1e6}


def bench_structural_vs_dense(results: dict, anchor: dict, reps: int) -> None:
    """Paper claim: structural K·S is O(nmd), dense K·S is O(n²d)."""
    key = jax.random.PRNGKey(2)
    n, d, m = anchor["R"], anchor["d"], anchor["m"]
    K = jax.random.normal(key, (n, n))
    sk = make_accum_sketch(key, n, d, m)
    S = sk.dense()
    t_struct = timeit(jax.jit(lambda K, sk: sketch_right(K, sk)), K, sk,
                      reps=reps)
    t_dense = timeit(jax.jit(lambda K, S: K @ S), K, S, reps=reps)
    speedup = t_dense / max(t_struct, 1e-9)
    emit("sketch_right_structural", t_struct * 1e6,
         f"dense/structural={speedup:.1f}x n={n} d={d} m={m}")
    emit("sketch_right_dense", t_dense * 1e6, "")
    results["sketch_right_structural"] = {
        "us": t_struct * 1e6, "speedup_vs_dense": speedup}
    results["sketch_right_dense"] = {"us": t_dense * 1e6}


def bench_landmark_ref(results: dict, anchor: dict, reps: int) -> None:
    key = jax.random.PRNGKey(3)
    S_len, Dh, L = anchor["R"], 128, 256
    q = jax.random.normal(key, (S_len, Dh))
    kt = jax.random.normal(key, (L, Dh))
    M = jax.random.normal(key, (L, Dh))
    t_lm = timeit(jax.jit(landmark_attention_ref), q, kt, M, reps=reps)
    kfull = jax.random.normal(key, (S_len, Dh))
    t_full = timeit(
        jax.jit(lambda q, k: jax.nn.softmax(q @ k.T / Dh**0.5, axis=-1) @ k),
        q, kfull, reps=reps)
    emit("landmark_attention_ref", t_lm * 1e6,
         f"exact/landmark={t_full/max(t_lm,1e-9):.1f}x S={S_len} L={L}")
    results["landmark_attention_ref"] = {
        "us": t_lm * 1e6, "speedup_vs_exact": t_full / max(t_lm, 1e-9)}


def bench_progressive_step(results: dict, anchor: dict, reps: int) -> None:
    """Engine increment (O(n·d)) vs from-scratch (C, W) recompute (O(n·m·d))
    at the final m — the tentpole claim of the progressive accumulation
    engine: growing m costs one slab, not a re-sketch."""
    key = jax.random.PRNGKey(4)
    n, d, m = anchor["R"], anchor["d"], max(anchor["m"], 2)
    K = jax.random.normal(key, (n, n))
    K = 0.5 * (K + K.T)
    state = A.accum_grow(K, A.accum_init(key, n, d, m), m - 1,
                         use_kernel=False)
    step = jax.jit(lambda K, s: A.accum_step(K, s, use_kernel=False))
    sk = make_accum_sketch(key, n, d, m)
    t_step = timeit(step, K, state, reps=reps)
    t_scratch = timeit(
        jax.jit(lambda K, sk: A.sketch_both(K, sk, use_kernel=False)), K, sk,
        reps=reps)
    speedup = t_scratch / max(t_step, 1e-9)
    tag = f"n{n}_d{d}_m{m}_f32"
    emit(f"accum_step_incremental_{tag}", t_step * 1e6,
         f"scratch/step={speedup:.1f}x")
    emit(f"accum_recompute_scratch_{tag}", t_scratch * 1e6, "")
    results[f"accum_step_incremental_{tag}"] = {
        "us": t_step * 1e6, "speedup_vs_scratch": speedup}
    results[f"accum_recompute_scratch_{tag}"] = {"us": t_scratch * 1e6}


def main() -> None:
    anchor, reps = bench_config()
    results: dict = {}
    bench_accum_apply(results, anchor, reps)
    bench_fused_both(results, anchor, reps)
    bench_structural_vs_dense(results, anchor, reps)
    bench_landmark_ref(results, anchor, reps)
    bench_progressive_step(results, anchor, reps)
    payload = {
        "host": {
            "backend": jax.default_backend(),
            "device": str(jax.devices()[0]),
            "jax": jax.__version__,
        },
        "anchor": anchor,
        "smoke": env_flag("REPRO_BENCH_SMOKE", False),
        "results": results,
    }
    BENCH_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    emit("bench_json", 0.0, f"wrote {BENCH_PATH.name}")


if __name__ == "__main__":
    main()
