"""Microbenchmarks for the accum_apply kernel family.

Times the seed scalar-gather Pallas kernel against the vectorized gather→GEMM
rewrite, the fused (K S, SᵀK S) single-sweep kernel against the two-pass
composition, and the structural-vs-dense sketch application (the paper's
O(nmd) claim) — then writes the results to ``BENCH_kernels.json`` at the repo
root so the perf trajectory is tracked across PRs.

Run:  PYTHONPATH=src python -m benchmarks.run kernels
"""
from __future__ import annotations

import json
import pathlib

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core.apply import sketch_right
from repro.core.sketch import make_accum_sketch
from repro.kernels.accum_apply.kernel import accum_apply, accum_apply_scalar
from repro.kernels.accum_apply.ops import (
    autotune_blocks,
    sketch_both_kernel,
    sketch_left_kernel,
    sketch_right_kernel,
)
from repro.kernels.landmark_attention.ref import landmark_attention_ref

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
BENCH_PATH = REPO_ROOT / "BENCH_kernels.json"

# The anchor shape every PR's numbers are compared at (f32).
ANCHOR = dict(R=4096, N=8192, d=64, m=4)


def bench_accum_apply(results: dict) -> None:
    """Seed scalar-loop kernel vs vectorized gather→GEMM at the anchor shape."""
    key = jax.random.PRNGKey(0)
    R, N, d, m = ANCHOR["R"], ANCHOR["N"], ANCHOR["d"], ANCHOR["m"]
    K = jax.random.normal(key, (R, N))
    sk = make_accum_sketch(key, N, d, m)
    coef = sk.coef.astype(jnp.float32)
    bm, bd = autotune_blocks(R, N, d, m, jnp.float32)

    t_new = timeit(
        lambda: accum_apply(K, sk.indices, coef, bm=bm, bd=bd, interpret=True))
    # seed defaults: bm=256, bd=8, scalar per-column gather loop
    t_old = timeit(
        lambda: accum_apply_scalar(K, sk.indices, coef, bm=256, bd=8,
                                   interpret=True), reps=2)
    speedup = t_old / max(t_new, 1e-9)
    tag = f"R{R}_N{N}_d{d}_m{m}_f32"
    emit(f"accum_apply_gemm_{tag}", t_new * 1e6, f"scalar/gemm={speedup:.1f}x")
    emit(f"accum_apply_scalar_{tag}", t_old * 1e6, "seed baseline")
    results[f"accum_apply_gemm_{tag}"] = {
        "us": t_new * 1e6, "speedup_vs_scalar": speedup, "blocks": [bm, bd]}
    results[f"accum_apply_scalar_{tag}"] = {"us": t_old * 1e6}


def bench_fused_both(results: dict) -> None:
    """Fused single-sweep (C, W) vs the two-pass kernel composition."""
    key = jax.random.PRNGKey(1)
    n, d, m = 4096, ANCHOR["d"], ANCHOR["m"]
    K = jax.random.normal(key, (n, n))
    K = 0.5 * (K + K.T)
    sk = make_accum_sketch(key, n, d, m)

    def two_pass():
        C = sketch_right_kernel(K, sk)
        return C, sketch_left_kernel(sk, C)

    t_fused = timeit(lambda: sketch_both_kernel(K, sk))
    t_two = timeit(two_pass)
    speedup = t_two / max(t_fused, 1e-9)
    tag = f"n{n}_d{d}_m{m}_f32"
    emit(f"sketch_both_fused_{tag}", t_fused * 1e6,
         f"two_pass/fused={speedup:.2f}x")
    emit(f"sketch_both_two_pass_{tag}", t_two * 1e6, "")
    results[f"sketch_both_fused_{tag}"] = {
        "us": t_fused * 1e6, "speedup_vs_two_pass": speedup}
    results[f"sketch_both_two_pass_{tag}"] = {"us": t_two * 1e6}


def bench_structural_vs_dense(results: dict) -> None:
    """Paper claim: structural K·S is O(nmd), dense K·S is O(n²d)."""
    key = jax.random.PRNGKey(2)
    n, d, m = 4096, 64, 4
    K = jax.random.normal(key, (n, n))
    sk = make_accum_sketch(key, n, d, m)
    S = sk.dense()
    t_struct = timeit(jax.jit(lambda K, sk: sketch_right(K, sk)), K, sk)
    t_dense = timeit(jax.jit(lambda K, S: K @ S), K, S)
    speedup = t_dense / max(t_struct, 1e-9)
    emit("sketch_right_structural", t_struct * 1e6,
         f"dense/structural={speedup:.1f}x n={n} d={d} m={m}")
    emit("sketch_right_dense", t_dense * 1e6, "")
    results["sketch_right_structural"] = {
        "us": t_struct * 1e6, "speedup_vs_dense": speedup}
    results["sketch_right_dense"] = {"us": t_dense * 1e6}


def bench_landmark_ref(results: dict) -> None:
    key = jax.random.PRNGKey(3)
    S_len, Dh, L = 4096, 128, 256
    q = jax.random.normal(key, (S_len, Dh))
    kt = jax.random.normal(key, (L, Dh))
    M = jax.random.normal(key, (L, Dh))
    t_lm = timeit(jax.jit(landmark_attention_ref), q, kt, M)
    kfull = jax.random.normal(key, (S_len, Dh))
    t_full = timeit(
        jax.jit(lambda q, k: jax.nn.softmax(q @ k.T / Dh**0.5, axis=-1) @ k),
        q, kfull)
    emit("landmark_attention_ref", t_lm * 1e6,
         f"exact/landmark={t_full/max(t_lm,1e-9):.1f}x S={S_len} L={L}")
    results["landmark_attention_ref"] = {
        "us": t_lm * 1e6, "speedup_vs_exact": t_full / max(t_lm, 1e-9)}


def main() -> None:
    results: dict = {}
    bench_accum_apply(results)
    bench_fused_both(results)
    bench_structural_vs_dense(results)
    bench_landmark_ref(results)
    payload = {
        "host": {
            "backend": jax.default_backend(),
            "device": str(jax.devices()[0]),
            "jax": jax.__version__,
        },
        "anchor": ANCHOR,
        "results": results,
    }
    BENCH_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    emit("bench_json", 0.0, f"wrote {BENCH_PATH.name}")


if __name__ == "__main__":
    main()
