"""Sampling-scheme zoo: error-vs-m curves for uniform / leverage / poisson.

The paper's accumulation argument (§3) is scheme-agnostic: ANY unbiased
sub-sampling design with E[S SᵀK] = K telescopes across slabs, so the same
engine runs

  * ``uniform``  — i.i.d. uniform column draws (the paper's baseline);
  * ``leverage`` — ridge-leverage probabilities estimated MATRIX-FREE from
    the sketch itself (``core.schemes.state_leverage_probs``) and refined
    between doubling batches, so no O(n³) oracle is ever formed;
  * ``poisson``  — independent Bernoulli row inclusion (Horvitz–Thompson
    normalized), the classic survey-sampling design.

For each scheme × m this suite grows a sketch with the progressive engine
(``grow_sketch_both``, doubling schedule, tol=None) on the bimodal KRR
anchor, solves sketched KRR, and records the in-sample error against the
exact KRR fit — medians over ``seeds`` independent draws.  The headline
derived quantity: the smallest m at which each scheme matches the UNIFORM
scheme's error at m = m_anchor (leverage gets there at m ≤ m_anchor/2 on
the full configuration).

Run:   PYTHONPATH=src python -m benchmarks.run schemes
Smoke: PYTHONPATH=src python -m benchmarks.run schemes --smoke
       (tiny shapes, 2 seeds — CI's configuration; JSON tagged "smoke": true)

Writes ``BENCH_schemes.json`` at the repo root.
"""
from __future__ import annotations

import json
import pathlib

import jax
import numpy as np

from benchmarks.common import bimodal_data, emit
from repro.core import apply as A
from repro.core import krr as R
from repro.core.kernels_math import gaussian_kernel
from repro.core.schemes import SCHEMES
from repro.util import env_flag

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
BENCH_PATH = REPO_ROOT / "BENCH_schemes.json"

# The acceptance anchor: n=2048 bimodal KRR at bandwidth 0.5, fit ridge 1e-5.
# In this regime the m=1 sketch is noise-dominated (uniform error falls ~1.4x
# from m=1 to m=16), so the scheme choice is visible; leverage scores are
# estimated at the engine's coarse scheme_lam=1e-3 (statistical dimension
# ≈ 24 ≈ d — the resolution a d-column sketch can actually capture).
FULL = dict(n=2048, d=16, bandwidth=0.5, lam=1e-5, ms=[1, 2, 4, 8, 16, 32],
            m_anchor=16, seeds=10)
SMOKE = dict(n=256, d=8, bandwidth=0.5, lam=1e-4, ms=[1, 2, 4],
             m_anchor=4, seeds=2)


def bench_config() -> dict:
    """Return the FULL or SMOKE shape dict (``REPRO_BENCH_SMOKE`` selects)."""
    return SMOKE if env_flag("REPRO_BENCH_SMOKE", False) else FULL


def error_curves(cfg: dict) -> dict[str, list[float]]:
    """Median in-sample error vs m for every scheme, on the KRR anchor."""
    X, y, _ = bimodal_data(jax.random.PRNGKey(0), cfg["n"])
    K = gaussian_kernel(X, X, cfg["bandwidth"])
    exact = R.krr_exact_fitted(K, y, cfg["lam"])

    def one(scheme: str, m: int, seed: int) -> float:
        sk, C, W, _ = A.grow_sketch_both(
            jax.random.PRNGKey(100 + seed), K, cfg["d"], m_max=m, tol=None,
            scheme=scheme)
        model = R.krr_sketched_fit(K, y, cfg["lam"], sk)
        return float(R.insample_error(model.fitted, exact))

    curves: dict[str, list[float]] = {}
    for scheme in SCHEMES:
        curves[scheme] = [
            float(np.median([one(scheme, m, s) for s in range(cfg["seeds"])]))
            for m in cfg["ms"]
        ]
    return curves


def crossing_m(curve: list[float], ms: list[int], target: float) -> int | None:
    """Smallest m in ``ms`` whose error is ≤ ``target`` (None if never)."""
    for m, e in zip(ms, curve):
        if e <= target:
            return m
    return None


def main() -> None:
    """Run the scheme zoo and write ``BENCH_schemes.json``."""
    cfg = bench_config()
    curves = error_curves(cfg)
    ms = cfg["ms"]
    anchor_err = curves["uniform"][ms.index(cfg["m_anchor"])]
    results: dict = {}
    for scheme in SCHEMES:
        cross = crossing_m(curves[scheme], ms, anchor_err)
        tag = f"n{cfg['n']}_d{cfg['d']}"
        emit(f"schemes_{scheme}_{tag}", 0.0,
             "err@m=" + " ".join(f"{m}:{e:.2e}" for m, e in zip(ms, curves[scheme]))
             + f"; matches uniform@m={cfg['m_anchor']} at m={cross}")
        results[scheme] = {
            "ms": ms,
            "median_insample_error": curves[scheme],
            "m_matching_uniform_anchor": cross,
        }
    results["uniform_anchor"] = {"m": cfg["m_anchor"], "error": anchor_err}
    payload = {
        "host": {
            "backend": jax.default_backend(),
            "device": str(jax.devices()[0]),
            "jax": jax.__version__,
        },
        "config": cfg,
        "smoke": env_flag("REPRO_BENCH_SMOKE", False),
        "results": results,
    }
    BENCH_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    emit("bench_json", 0.0, f"wrote {BENCH_PATH.name}")


if __name__ == "__main__":
    main()
