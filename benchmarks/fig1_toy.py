"""Paper Figure 1 reproduction (toy illustration): error + runtime of
Gaussian / Nyström / accumulation(m=5) under the appendix D.1 settings
(Matérn-0.5 kernel, λ = 0.3·n^{-4/7}, d = 1.3·n^{3/7}, γ = 0.5)."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import bimodal_data, emit
from repro.core import (
    get_kernel,
    insample_error,
    krr_exact_fitted,
    krr_sketched_fit,
    krr_sketched_fit_dense,
    make_accum_sketch,
    make_gaussian_sketch,
    make_nystrom_sketch,
)


def run(ns=(1000, 2000), reps=3, verbose=True):
    key = jax.random.PRNGKey(2)
    rows = []
    for n in ns:
        X, y, f = bimodal_data(jax.random.fold_in(key, n), n, gamma=0.5)
        lam = 0.3 * n ** (-4 / 7)
        d = int(1.3 * n ** (3 / 7))
        kern = get_kernel("matern", bandwidth=1.0, nu=0.5)
        K = kern(X, X)
        fn = krr_exact_fitted(K, y, lam)
        out = {"n": n, "d": d}
        for name, mk in {
            "nystrom": lambda r: krr_sketched_fit(K, y, lam, make_nystrom_sketch(jax.random.fold_in(key, r), n, d)),
            "accum_m5": lambda r: krr_sketched_fit(K, y, lam, make_accum_sketch(jax.random.fold_in(key, r + 9), n, d, 5)),
            "gaussian": lambda r: krr_sketched_fit_dense(K, y, lam, make_gaussian_sketch(jax.random.fold_in(key, r + 18), n, d)),
        }.items():
            errs, ts = [], []
            for r in range(reps):
                t0 = time.perf_counter()
                mod = mk(r)
                jax.block_until_ready(mod.fitted)
                ts.append(time.perf_counter() - t0)
                errs.append(float(insample_error(mod.fitted, fn)))
            out[name] = (float(np.mean(errs)), float(np.median(ts)))
        rows.append(out)
        if verbose:
            s = " ".join(f"{k}:err={v[0]:.2e},t={v[1]*1e3:.0f}ms"
                         for k, v in out.items() if isinstance(v, tuple))
            print(f"# fig1 n={n} d={d}: {s}")
    return rows


def main():
    rows = run()
    for r in rows:
        emit(f"fig1_n{r['n']}", r["accum_m5"][1] * 1e6,
             f"err_ratio_vs_nystrom={r['accum_m5'][0]/max(r['nystrom'][0],1e-30):.3f}")
    return rows


if __name__ == "__main__":
    main()
