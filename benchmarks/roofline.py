"""Roofline table from the dry-run sweep (results/dryrun.jsonl): per
(arch × shape × mesh) the three terms, dominant bottleneck, and the
MODEL_FLOPS/HLO_FLOPs usefulness ratio. Regenerate cells with:
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out results/dryrun.jsonl
"""
from __future__ import annotations

import json
import os

from benchmarks.common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun.jsonl")


def load(path=RESULTS):
    if not os.path.exists(path):
        return []
    recs = {}
    for line in open(path):
        r = json.loads(line)
        recs[(r["arch"], r["shape"], r["mesh"])] = r   # last write wins
    return list(recs.values())


def main():
    recs = load()
    if not recs:
        print("# no dry-run results found; run repro.launch.dryrun first")
        return
    ok = [r for r in recs if r.get("ok")]
    fails = [r for r in recs if not r.get("ok")]
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        emit(
            f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
            r["bound_ms"] * 1e3 if "bound_ms" in r else max(
                r["t_compute"], r["t_memory"], r["t_collective"]) * 1e6,
            f"dom={r['dominant']} frac={r['roofline_fraction']:.3f} "
            f"useful={r['useful_fraction']:.3f} peakGB={r['peak_mem_bytes']/1e9:.1f}",
        )
    print(f"# roofline cells ok={len(ok)} failed={len(fails)}")
    for r in fails:
        print(f"# FAILED {r['arch']}/{r['shape']}/{r['mesh']}: {r.get('error')}")


if __name__ == "__main__":
    main()
