"""Multi-device sharded sketching benchmark: weak + strong scaling of the
data-parallel (C, W) computation at the matfree anchor shapes.

Strong scaling: fixed n, grow the device count D — wall time per sharded
``sketch_both`` and KRR fit, with the per-device peak C slab shrinking ∝ 1/D
(the acceptance claim: each device holds only its ceil(n/D)·d rows of C, and
its share of the kernel-eval tiles).  Weak scaling: n ∝ D at fixed per-device
rows — time should stay ~flat while total n grows past what one device's C
slab budget would allow.

Device counts are the powers of two ≤ ``jax.device_count()`` — run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (set BEFORE the first
jax import; the CI bench-smoke leg does) to emulate 8 devices on CPU.  On a
single unforced device only D=1 runs, which still exercises the shard_map
plumbing.

Run:   XLA_FLAGS=--xla_force_host_platform_device_count=8 \
           PYTHONPATH=src python -m benchmarks.run distributed
Smoke: append --smoke (tiny shapes, 1 rep; JSON tagged "smoke": true).

Writes ``BENCH_distributed.json`` at the repo root.
"""
from __future__ import annotations

import json
import pathlib

import jax

from benchmarks.common import bimodal_data, emit, timeit
from repro.core import distributed as D
from repro.core.krr import krr_sketched_fit
from repro.core.kernel_op import KernelOperator
from repro.core.sketch import make_accum_sketch
from repro.util import env_flag

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
BENCH_PATH = REPO_ROOT / "BENCH_distributed.json"

# the matfree anchor shape (BENCH_matfree.json's mid n) for strong scaling;
# weak scaling holds n/D at base_rows
FULL = dict(n_strong=16384, base_rows=4096, d=64, m=4, n_test=2048,
            bandwidth=0.75, lam=1e-3)
SMOKE = dict(n_strong=1024, base_rows=256, d=16, m=2, n_test=64,
             bandwidth=0.75, lam=1e-3)


def bench_config() -> tuple[dict, int]:
    if env_flag("REPRO_BENCH_SMOKE", False):
        return SMOKE, 1
    return FULL, 2


def device_counts() -> list[int]:
    avail = jax.device_count()
    out = []
    dd = 1
    while dd <= min(avail, 8):
        out.append(dd)
        dd *= 2
    return out


def _per_device_C_bytes(n: int, d: int, Dn: int) -> int:
    return (-(-n // Dn)) * d * 4


def main() -> None:
    cfg, reps = bench_config()
    d, m = cfg["d"], cfg["m"]
    key = jax.random.PRNGKey(0)
    counts = device_counts()
    results: dict = {}
    memory: dict = {}

    # ---- strong scaling: fixed n, growing D --------------------------------- #
    n = cfg["n_strong"]
    X, y, _ = bimodal_data(jax.random.fold_in(key, n), n)
    op = KernelOperator(X, "gaussian", bandwidth=cfg["bandwidth"])
    sk = make_accum_sketch(jax.random.fold_in(key, 2 * n), n, d, m)
    Xt = X[: cfg["n_test"]] + 0.01
    for Dn in counts:
        mesh = D.make_data_mesh(Dn)
        Xs = D.shard_rows(X, mesh)
        ops = KernelOperator(Xs, "gaussian", bandwidth=cfg["bandwidth"])
        tag = f"strong_n{n}_D{Dn}"
        memory[tag] = {
            "per_device_C_bytes": _per_device_C_bytes(n, d, Dn),
            "ratio_vs_D1": _per_device_C_bytes(n, d, 1)
            / _per_device_C_bytes(n, d, Dn),
        }
        t_cw = timeit(
            jax.jit(lambda o, s, mesh=mesh: o.sketch_both(s, mesh=mesh)),
            ops, sk, reps=reps)
        emit(f"dist_sketch_both_{tag}", t_cw * 1e6,
             f"per-device C {memory[tag]['per_device_C_bytes'] / 2**20:.2f} MiB "
             f"({memory[tag]['ratio_vs_D1']:.0f}x below D=1)")
        results[f"dist_sketch_both_{tag}"] = {"us": t_cw * 1e6}

        def fit_predict(o=ops, yy=y, s=sk, Xq=Xt, mesh=mesh):
            model = krr_sketched_fit(o, yy, cfg["lam"], s, mesh=mesh)
            return model.predict(Xq, mesh=mesh)

        t_fit = timeit(fit_predict, reps=reps)
        emit(f"dist_krr_fit_predict_{tag}", t_fit * 1e6,
             f"sharded fit+predict({cfg['n_test']})")
        results[f"dist_krr_fit_predict_{tag}"] = {"us": t_fit * 1e6}

    # ---- weak scaling: n = base_rows · D ------------------------------------ #
    for Dn in counts:
        n_w = cfg["base_rows"] * Dn
        Xw, yw, _ = bimodal_data(jax.random.fold_in(key, 7 * n_w), n_w)
        opw = KernelOperator(Xw, "gaussian", bandwidth=cfg["bandwidth"])
        skw = make_accum_sketch(jax.random.fold_in(key, 3 * n_w), n_w, d, m)
        mesh = D.make_data_mesh(Dn)
        tag = f"weak_rows{cfg['base_rows']}_D{Dn}"
        memory[tag] = {
            "n": n_w,
            "per_device_C_bytes": _per_device_C_bytes(n_w, d, Dn),
        }
        t_cw = timeit(
            jax.jit(lambda o, s, mesh=mesh: o.sketch_both(s, mesh=mesh)),
            opw, skw, reps=reps)
        emit(f"dist_sketch_both_{tag}", t_cw * 1e6,
             f"n={n_w}: per-device C fixed at "
             f"{memory[tag]['per_device_C_bytes'] / 2**20:.2f} MiB")
        results[f"dist_sketch_both_{tag}"] = {"us": t_cw * 1e6, "n": n_w}

    payload = {
        "host": {
            "backend": jax.default_backend(),
            "device": str(jax.devices()[0]),
            "device_count": jax.device_count(),
            "jax": jax.__version__,
        },
        "config": cfg,
        "device_counts": counts,
        "smoke": env_flag("REPRO_BENCH_SMOKE", False),
        "results": results,
        "memory": memory,
    }
    BENCH_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    emit("bench_json", 0.0, f"wrote {BENCH_PATH.name}")


if __name__ == "__main__":
    main()
